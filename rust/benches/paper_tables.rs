//! `cargo bench` target: regenerate every simulation-backed table/figure
//! of the paper and time the regeneration itself. (The training-backed
//! figures — fig12/14/15/16/table5 — run via `antler bench all` and the
//! examples; they need `make artifacts` and real SGD, so they are not
//! part of the default bench loop.)

use antler::bench::{bench_fn, run_driver};
use antler::util::cli::Args;

fn main() {
    let args = Args::parse(
        ["bench", "--max-graphs", "300"].iter().map(|s| s.to_string()),
    );
    for id in ["fig3", "fig7", "fig8", "table3", "fig9", "fig10", "fig11", "table4"] {
        println!("\n################ {id} ################");
        bench_fn(&format!("regen/{id}"), 0, 1, || {
            run_driver(id, &args).expect("driver runs");
        });
    }
}
