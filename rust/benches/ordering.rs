//! `cargo bench` target: the ordering solvers (Table 3's machinery).
//! Custom harness (no criterion in the offline mirror) — see
//! `antler::bench::harness`.

use antler::bench::bench_fn;
use antler::ordering::{
    solve_brute, solve_genetic, solve_held_karp, GaConfig, OrderingProblem,
};
use antler::testkit::gen;
use antler::tsplib::table3_instances;
use antler::util::rng::Pcg32;

fn random_problem(n: usize, seed: u64) -> OrderingProblem {
    let mut rng = Pcg32::seed(seed);
    let flat = gen::sym_cost_matrix(&mut rng, n, 100.0);
    let cost: Vec<Vec<f64>> =
        (0..n).map(|i| flat[i * n..(i + 1) * n].to_vec()).collect();
    OrderingProblem::from_matrix(cost)
}

fn main() {
    println!("== ordering solver benchmarks ==");
    for n in [8usize, 10] {
        let p = random_problem(n, n as u64);
        bench_fn(&format!("brute_force/n={n}"), 1, 10, || {
            let _ = solve_brute(&p);
        });
    }
    for n in [10usize, 14, 17] {
        let p = random_problem(n, n as u64);
        bench_fn(&format!("held_karp/n={n}"), 1, if n > 14 { 3 } else { 10 }, || {
            let _ = solve_held_karp(&p);
        });
    }
    for n in [10usize, 17, 24] {
        let p = random_problem(n, n as u64);
        let cfg = GaConfig::default();
        bench_fn(&format!("genetic/n={n}"), 1, 3, || {
            let _ = solve_genetic(&p, &cfg);
        });
    }
    // the actual Table 3 regeneration, timed end to end
    bench_fn("table3/all_nine_instances", 0, 1, || {
        for inst in table3_instances() {
            let _ = solve_held_karp(&inst.problem);
            let _ = solve_genetic(&inst.problem, &GaConfig::default());
        }
    });
}
