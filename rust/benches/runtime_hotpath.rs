//! `cargo bench` target: the serving hot path on the live runtime —
//! per-layer execution, whole-task execution with and without activation
//! caching, the end-to-end serve loop, cross-frame batching (batch-1 vs
//! batch-8 on the shared trunk), and the sharded executor pool under
//! both schedulers (work-stealing vs the round-robin baseline, even and
//! skewed workloads). Runs on whichever backend `ANTLER_BACKEND` selects
//! (the reference backend needs no artifacts, so this never skips). This
//! is the §Perf measurement harness (EXPERIMENTS.md).

use std::time::Duration;

use antler::bench::bench_fn;
use antler::coordinator::{
    serve, serve_sharded, serve_sharded_opts, serve_sharded_sources,
    BlockExecutor, ServePlan, ShardOpts, Source,
};
use antler::device::Device;
use antler::memory::tier::TierConfig;
use antler::model::Tensor;
use antler::runtime::{backend_from_env, Backend, ReferenceBackend};
use antler::taskgraph::{Partition, TaskGraph};
use antler::trainer::GraphWeights;
use antler::util::rng::Pcg32;

fn graph5() -> TaskGraph {
    TaskGraph::new(
        5,
        vec![1, 3, 4],
        vec![
            Partition(vec![0, 0, 0, 0, 0]),
            Partition(vec![0, 0, 0, 1, 1]),
            Partition(vec![0, 1, 1, 2, 2]),
            Partition::singletons(5),
        ],
    )
    .unwrap()
}

fn main() {
    let be = backend_from_env().expect("backend");
    println!("runtime_hotpath: backend = {}", be.name());
    let arch = be.arch("cnn5").unwrap();
    let graph = graph5();
    let ncls = vec![2usize; 5];
    let mut rng = Pcg32::seed(1);
    let store = GraphWeights::init(&graph, &arch, &ncls, &mut rng);
    let mut ex = BlockExecutor::new(
        be.as_ref(),
        Device::msp430(),
        arch.clone(),
        graph.clone(),
        ncls.clone(),
        store.clone(),
    );
    ex.warmup().unwrap();

    // single layer execution (the innermost hot path)
    let x1 = Tensor::full(vec![1, 16, 16, 1], 0.2);
    let w = Tensor::he_init(arch.layers[0].param_shapes(2)[0].clone(), &mut rng);
    let b = Tensor::zeros(arch.layers[0].param_shapes(2)[1].clone());
    bench_fn("layer/cnn5_conv0_b1", 5, 200, || {
        let _ = be.run_layer(&arch, 0, None, &x1, &w, &b).unwrap();
    });

    // one full task, fresh sample every time (no activation reuse)
    let mut sid = 0u64;
    bench_fn("task/full_path_no_reuse", 3, 100, || {
        sid += 1;
        let _ = ex.run_task(sid, 0, &x1).unwrap();
    });

    // all five tasks on ONE sample (activation reuse across tasks)
    bench_fn("round/5_tasks_shared_sample", 2, 50, || {
        sid += 1;
        for t in 0..5 {
            let _ = ex.run_task(sid, t, &x1).unwrap();
        }
    });

    // the serve loop end to end
    let frames: Vec<(u64, Tensor)> = (0..20u64)
        .map(|i| {
            let data = (0..256).map(|k| ((i as usize + k) % 7) as f32 * 0.1).collect();
            (i, Tensor::new(vec![1, 16, 16, 1], data))
        })
        .collect();
    let plan = ServePlan::unconditional(vec![0, 1, 2, 3, 4]);
    bench_fn("serve/20_frames_x_5_tasks", 1, 10, || {
        let _ = serve(&mut ex, &plan, frames.clone(), 32, None).unwrap();
    });
    println!(
        "counters: layer_execs={} layer_skips={} ({:.0}% compute avoided)",
        ex.layer_execs,
        ex.layer_skips,
        ex.layer_skips as f64 / (ex.layer_execs + ex.layer_skips) as f64 * 100.0
    );

    // ---- cross-frame batching: the shared trunk (both conv layers),
    // 8 frames one at a time vs one batch-8 forward. The blocked batch
    // kernels give each sample an independent accumulation chain, so
    // batch-8 must clear >= 2x frames/sec (EXPERIMENTS.md §Perf gate).
    let rbe = ReferenceBackend::new();
    let trunk_frames: Vec<Tensor> = (0..8)
        .map(|i| {
            let data = (0..256)
                .map(|k| ((i * 31 + k) % 11) as f32 * 0.07 - 0.3)
                .collect();
            Tensor::new(vec![1, 16, 16, 1], data)
        })
        .collect();
    let refs: Vec<&Tensor> = trunk_frames.iter().collect();
    let xb8 = Tensor::concat_batch(&refs);
    let w0 = Tensor::he_init(arch.layers[0].param_shapes(2)[0].clone(), &mut rng);
    let b0 = Tensor::zeros(arch.layers[0].param_shapes(2)[1].clone());
    let w1 = Tensor::he_init(arch.layers[1].param_shapes(2)[0].clone(), &mut rng);
    let b1 = Tensor::zeros(arch.layers[1].param_shapes(2)[1].clone());
    let t1 = bench_fn("trunk/batch1_x8_frames", 5, 150, || {
        for f in &trunk_frames {
            let y0 = rbe.run_layer(&arch, 0, None, f, &w0, &b0).unwrap();
            let _ = rbe.run_layer(&arch, 1, None, &y0, &w1, &b1).unwrap();
        }
    });
    let t8 = bench_fn("trunk/batch8_one_call", 5, 150, || {
        let y0 = rbe.run_layer(&arch, 0, None, &xb8, &w0, &b0).unwrap();
        let _ = rbe.run_layer(&arch, 1, None, &y0, &w1, &b1).unwrap();
    });
    println!(
        "trunk batch-8 speedup: {:.2}x frames/sec over batch-1",
        t1.mean_ns / t8.mean_ns
    );

    // ---- the batched serving round: 8 frames through run_round_batched
    // vs 8 per-frame task rounds on an identical executor
    let mut ex_b = BlockExecutor::new(
        ReferenceBackend::new(),
        Device::msp430(),
        arch.clone(),
        graph.clone(),
        ncls.clone(),
        store.clone(),
    );
    let round_frames: Vec<(u64, Tensor)> = (0..8u64)
        .map(|i| (i, trunk_frames[i as usize].clone()))
        .collect();
    let order: Vec<usize> = (0..5).collect();
    let r1 = bench_fn("round/batch1_8_frames_5_tasks", 2, 40, || {
        for (_, x) in &round_frames {
            sid += 1; // a fresh sample id per frame; tasks share it
            for &t in &order {
                let _ = ex_b.run_task(sid, t, x).unwrap();
            }
        }
    });
    let ids: Vec<u64> = round_frames.iter().map(|(i, _)| *i).collect();
    let r8 = bench_fn("round/batch8_5_tasks", 2, 40, || {
        let inputs: Vec<&Tensor> = round_frames.iter().map(|(_, x)| x).collect();
        let _ = ex_b.run_round_batched(&ids, &inputs, &order, &[]).unwrap();
    });
    println!(
        "serving batch-8 speedup: {:.2}x frames/sec over batch-1",
        r1.mean_ns / r8.mean_ns
    );

    // ---- sharded pool scaling (always on the Send reference backend)
    let make_shard = {
        let arch2 = arch.clone();
        let graph2 = graph.clone();
        let ncls2 = ncls.clone();
        let store2 = store.clone();
        move |_s: usize| {
            Ok(BlockExecutor::new(
                ReferenceBackend::new(),
                Device::msp430(),
                arch2.clone(),
                graph2.clone(),
                ncls2.clone(),
                store2.clone(),
            ))
        }
    };
    for shards in [1usize, 2, 4] {
        let make = make_shard.clone();
        let frames = frames.clone();
        let plan = plan.clone();
        bench_fn(&format!("shard/rr_{shards}x_20_frames"), 1, 10, move || {
            let _ =
                serve_sharded(make.clone(), shards, &plan, frames.clone(), 32, None)
                    .unwrap();
        });
    }
    for shards in [2usize, 4] {
        let make = make_shard.clone();
        let frames = frames.clone();
        let plan = plan.clone();
        let opts = ShardOpts { queue_depth: 32, batch: 4, ..ShardOpts::default() };
        bench_fn(
            &format!("shard/steal_b4_{shards}x_20_frames"),
            1,
            10,
            move || {
                let _ = serve_sharded_opts(
                    make.clone(),
                    shards,
                    &plan,
                    frames.clone(),
                    &opts,
                )
                .unwrap();
            },
        );
    }

    // ---- the skewed-workload drop gap: one shard paced 10x slower.
    // Round-robin keeps dealing every 3rd frame to the straggler's full
    // queue; work stealing lets the idle siblings take them instead.
    let skew = |steal: bool| ShardOpts {
        queue_depth: 2,
        batch: if steal { 4 } else { 1 },
        adaptive_batch: false,
        steal,
        local_depth: 1,
        pace: Some(Duration::from_micros(400)),
        handicap: Some((0, Duration::from_millis(4))),
        tier: None,
    };
    let total = 60;
    let skew_frames: Vec<(u64, Tensor)> = (0..total as u64)
        .map(|i| (i, trunk_frames[(i % 8) as usize].clone()))
        .collect();
    let skew_plan = ServePlan::unconditional(vec![0]);
    let rr = serve_sharded_opts(
        make_shard.clone(),
        3,
        &skew_plan,
        skew_frames.clone(),
        &skew(false),
    )
    .unwrap();
    let ws = serve_sharded_opts(
        make_shard.clone(),
        3,
        &skew_plan,
        skew_frames,
        &skew(true),
    )
    .unwrap();
    println!(
        "skewed 3-shard serve, {total} frames, straggler 10x: round-robin \
         dropped {} | work-stealing dropped {}",
        rr.aggregate.dropped, ws.aggregate.dropped
    );

    // ---- two-tier weight memory: cold-start load stall, prefetch on vs
    // off. The fast tier is capped below the graph's total weight
    // footprint so every round must move bytes; prefetch overlaps those
    // loads with the preceding segments' compute while the demand-only
    // run pays every load as a serialized stall. The gap is reported in
    // *simulated* device seconds (the cost model, not the host clock),
    // so the numbers are deterministic run to run. Paced feed = skewed
    // arrival: batch sizes vary, so the prefetcher sees a live backlog.
    let footprint = graph.model_bytes(&arch, &ncls);
    let tier_cap = footprint / 2;
    let tier_frames: Vec<(u64, Tensor)> = (0..24u64)
        .map(|i| (i, trunk_frames[(i % 8) as usize].clone()))
        .collect();
    let mut tier_stalls = Vec::new();
    for prefetch in [false, true] {
        let opts = ShardOpts {
            queue_depth: 32,
            batch: 8,
            pace: Some(Duration::from_micros(200)),
            tier: Some(TierConfig::for_device(
                &Device::msp430(),
                tier_cap,
                prefetch,
            )),
            ..ShardOpts::default()
        };
        let sr = serve_sharded_opts(
            make_shard.clone(),
            1,
            &plan,
            tier_frames.clone(),
            &opts,
        )
        .unwrap();
        let tc = sr.tier.expect("tier-enabled serve must report counters");
        println!(
            "tier cold-start ({} KB fast tier of {} KB footprint), prefetch \
             {}: stall {:.3} ms, {} hits / {} misses ({} prefetch hits), \
             {} evictions, {:.1} KB loaded",
            tier_cap / 1024,
            footprint / 1024,
            if prefetch { "on" } else { "off" },
            tc.stall_s * 1e3,
            tc.hits,
            tc.misses,
            tc.prefetch_hits,
            tc.evictions,
            tc.bytes_loaded as f64 / 1024.0
        );
        tier_stalls.push(tc.stall_s);
    }
    println!(
        "tier prefetch gain: {:.2}x less simulated load stall than demand-only",
        tier_stalls[0] / tier_stalls[1].max(1e-12)
    );

    // ---- the ingest-bound scenario: 4 fast synthetic sources (one frame
    // due every 500 us, 2 ms staleness budget, 400 us admission cost per
    // frame — the decode/copy model). One producer thread would need
    // 4 x 400 us of admission work per 500 us tick (3.2x oversubscribed),
    // so it falls behind every schedule and sheds stale frames; four
    // producers hold one schedule each (0.8x) and shed (near) none. Same
    // shards, same queue depth — the drop gap is pure ingest parallelism.
    let src_frames = |s: usize| -> Vec<(u64, Tensor)> {
        (0..40u64)
            .map(|i| {
                (s as u64 * 1000 + i, trunk_frames[(i % 8) as usize].clone())
            })
            .collect()
    };
    let mk_sources = || -> Vec<Source> {
        (0..4)
            .map(|s| Source {
                interval: Some(Duration::from_micros(500)),
                slack: Some(Duration::from_millis(2)),
                prep: Some(Duration::from_micros(400)),
                ..Source::flood(&format!("sensor{s}"), src_frames(s))
            })
            .collect()
    };
    let ingest_plan = ServePlan::unconditional(vec![0]);
    let ingest_opts = ShardOpts { queue_depth: 32, ..ShardOpts::default() };
    for k in [1usize, 4] {
        let (sr, ing) = serve_sharded_sources(
            make_shard.clone(),
            4,
            &ingest_plan,
            mk_sources(),
            k,
            &ingest_opts,
        )
        .unwrap();
        println!(
            "ingest-bound 4 sources x 40 frames, K={k} producer{}: offered {} \
             delivered {} dropped {} ({} stale, {} backpressure); served {}",
            if k == 1 { "" } else { "s" },
            ing.offered(),
            ing.delivered(),
            sr.aggregate.dropped,
            ing.dropped_stale(),
            ing.dropped_backpressure(),
            sr.aggregate.frames
        );
    }

    // ---- adaptive vs fixed batch under bursty load: 6 sources on the
    // same 3 ms schedule deliver synchronized 6-frame bursts (one
    // producer each). Fixed batch-1 pays per-frame overhead through every
    // burst; fixed batch-8 holds frames for batches the lulls never fill;
    // adaptive grows into the burst and collapses to 1 in the lull —
    // batch histograms + p95 tell the story (EXPERIMENTS.md §Perf).
    let bursty_sources = || -> Vec<Source> {
        (0..6)
            .map(|s| {
                Source::paced(
                    &format!("burst{s}"),
                    (0..30u64)
                        .map(|i| {
                            (
                                s as u64 * 1000 + i,
                                trunk_frames[(i % 8) as usize].clone(),
                            )
                        })
                        .collect(),
                    Duration::from_millis(3),
                )
            })
            .collect()
    };
    for (label, batch, adaptive) in
        [("fixed-1", 1usize, false), ("fixed-8", 8, false), ("auto-8", 8, true)]
    {
        let opts = ShardOpts {
            queue_depth: 8,
            batch,
            adaptive_batch: adaptive,
            ..ShardOpts::default()
        };
        // aggregate.dropped already folds the ingest drops in
        let (sr, _ing) = serve_sharded_sources(
            make_shard.clone(),
            2,
            &ingest_plan,
            bursty_sources(),
            6,
            &opts,
        )
        .unwrap();
        println!(
            "bursty 6x30 frames, 2 shards, {label}: dropped {} p95 {:.2} ms \
             mean batch {:.2} hist {:?}",
            sr.aggregate.dropped,
            sr.aggregate.latency_p95_ms,
            sr.mean_batch(),
            sr.total_hist()
        );
    }
}
