//! `cargo bench` target: the serving hot path on the live runtime —
//! per-layer execution, whole-task execution with and without activation
//! caching, the end-to-end serve loop, and the sharded executor pool.
//! Runs on whichever backend `ANTLER_BACKEND` selects (the reference
//! backend needs no artifacts, so this never skips). This is the §Perf
//! measurement harness (EXPERIMENTS.md).

use antler::bench::bench_fn;
use antler::coordinator::{serve, serve_sharded, BlockExecutor, ServePlan};
use antler::device::Device;
use antler::model::Tensor;
use antler::runtime::{backend_from_env, Backend, ReferenceBackend};
use antler::taskgraph::{Partition, TaskGraph};
use antler::trainer::GraphWeights;
use antler::util::rng::Pcg32;

fn graph5() -> TaskGraph {
    TaskGraph::new(
        5,
        vec![1, 3, 4],
        vec![
            Partition(vec![0, 0, 0, 0, 0]),
            Partition(vec![0, 0, 0, 1, 1]),
            Partition(vec![0, 1, 1, 2, 2]),
            Partition::singletons(5),
        ],
    )
    .unwrap()
}

fn main() {
    let be = backend_from_env().expect("backend");
    println!("runtime_hotpath: backend = {}", be.name());
    let arch = be.arch("cnn5").unwrap();
    let graph = graph5();
    let ncls = vec![2usize; 5];
    let mut rng = Pcg32::seed(1);
    let store = GraphWeights::init(&graph, &arch, &ncls, &mut rng);
    let mut ex = BlockExecutor::new(
        be.as_ref(),
        Device::msp430(),
        arch.clone(),
        graph.clone(),
        ncls.clone(),
        store.clone(),
    );
    ex.warmup().unwrap();

    // single layer execution (the innermost hot path)
    let x1 = Tensor::full(vec![1, 16, 16, 1], 0.2);
    let w = Tensor::he_init(arch.layers[0].param_shapes(2)[0].clone(), &mut rng);
    let b = Tensor::zeros(arch.layers[0].param_shapes(2)[1].clone());
    bench_fn("layer/cnn5_conv0_b1", 5, 200, || {
        let _ = be.run_layer(&arch, 0, None, &x1, &w, &b).unwrap();
    });

    // one full task, fresh sample every time (no activation reuse)
    let mut sid = 0u64;
    bench_fn("task/full_path_no_reuse", 3, 100, || {
        sid += 1;
        let _ = ex.run_task(sid, 0, &x1).unwrap();
    });

    // all five tasks on ONE sample (activation reuse across tasks)
    bench_fn("round/5_tasks_shared_sample", 2, 50, || {
        sid += 1;
        for t in 0..5 {
            let _ = ex.run_task(sid, t, &x1).unwrap();
        }
    });

    // the serve loop end to end
    let frames: Vec<(u64, Tensor)> = (0..20u64)
        .map(|i| {
            let data = (0..256).map(|k| ((i as usize + k) % 7) as f32 * 0.1).collect();
            (i, Tensor::new(vec![1, 16, 16, 1], data))
        })
        .collect();
    let plan = ServePlan::unconditional(vec![0, 1, 2, 3, 4]);
    bench_fn("serve/20_frames_x_5_tasks", 1, 10, || {
        let _ = serve(&mut ex, &plan, frames.clone(), 32, None).unwrap();
    });
    println!(
        "counters: layer_execs={} layer_skips={} ({:.0}% compute avoided)",
        ex.layer_execs,
        ex.layer_skips,
        ex.layer_skips as f64 / (ex.layer_execs + ex.layer_skips) as f64 * 100.0
    );

    // sharded pool scaling (always on the Send reference backend)
    for shards in [1usize, 2, 4] {
        let arch2 = arch.clone();
        let graph2 = graph.clone();
        let ncls2 = ncls.clone();
        let store2 = store.clone();
        let make = move |_s: usize| {
            Ok(BlockExecutor::new(
                ReferenceBackend::new(),
                Device::msp430(),
                arch2.clone(),
                graph2.clone(),
                ncls2.clone(),
                store2.clone(),
            ))
        };
        let frames = frames.clone();
        let plan = plan.clone();
        bench_fn(&format!("shard/{shards}x_20_frames"), 1, 10, move || {
            let _ =
                serve_sharded(make.clone(), shards, &plan, frames.clone(), 32, None)
                    .unwrap();
        });
    }
}
