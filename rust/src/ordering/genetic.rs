//! Genetic-algorithm solver (Appendix 9.2): population of candidate
//! orderings; top-K pairs selected by fitness each round; single-point
//! prefix crossover; two-index swap mutation; invalid offspring discarded;
//! terminates when the best fitness stops improving.
//!
//! The paper's literal prefix-swap crossover produces a valid permutation
//! only when both prefixes contain the same element multiset, so most
//! offspring are discarded and search degenerates toward mutation-only.
//! We implement the literal operator (`Crossover::PrefixSwap`, used when
//! reproducing Table 3's method) plus the standard order-crossover OX1
//! (`Crossover::Order`) as the default. Both respect constraints by
//! discarding invalid children, exactly as the appendix prescribes.

use super::{OrderingProblem, Solution};
use crate::util::rng::Pcg32;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Crossover {
    /// Appendix-literal: swap the first k elements of the pair.
    PrefixSwap,
    /// OX1 order crossover (keeps a slice, fills the rest in partner
    /// order) — always yields a permutation.
    Order,
}

#[derive(Debug, Clone)]
pub struct GaConfig {
    pub population: usize,
    /// Best K pairs selected for crossover each round.
    pub k_pairs: usize,
    pub mutation_prob: f64,
    /// Stop after this many rounds without improvement.
    pub stall_rounds: usize,
    pub max_rounds: usize,
    pub crossover: Crossover,
    /// Repair precedence-violating children (greedy topological reorder
    /// preserving relative positions) instead of discarding them.
    pub repair: bool,
    /// Per-round adjacent-swap hill climbing on the incumbent.
    pub local_search: bool,
    pub seed: u64,
}

impl Default for GaConfig {
    fn default() -> GaConfig {
        GaConfig {
            population: 128,
            k_pairs: 24,
            mutation_prob: 0.9,
            stall_rounds: 60,
            max_rounds: 2000,
            crossover: Crossover::Order,
            repair: true,
            local_search: true,
            seed: 0xA417,
        }
    }
}

/// Paper-literal appendix configuration: prefix-swap crossover, no
/// repair, no local search — invalid offspring simply discarded.
pub fn ga_paper_literal() -> GaConfig {
    GaConfig {
        crossover: Crossover::PrefixSwap,
        repair: false,
        local_search: false,
        ..Default::default()
    }
}

/// Greedy topological repair: rebuild the order by repeatedly emitting
/// the ready task (all prerequisites done) that appears earliest in the
/// broken permutation. Valid input is returned unchanged.
pub fn repair_order(p: &OrderingProblem, order: &[usize]) -> Option<Vec<usize>> {
    let prereq = p.prereq_masks();
    let mut used = 0u32;
    let mut out = Vec::with_capacity(p.n);
    for _ in 0..p.n {
        let next = order
            .iter()
            .copied()
            .find(|&t| used & (1 << t) == 0 && prereq[t] & !used == 0)?;
        out.push(next);
        used |= 1 << next;
    }
    Some(out)
}

/// First-improvement hill climbing: 2-opt segment reversals plus
/// single-task relocation, both precedence-checked.
fn local_search(p: &OrderingProblem, order: &mut Vec<usize>, cost: &mut f64) {
    let n = order.len();
    let mut improved = true;
    while improved {
        improved = false;
        // 2-opt: reverse order[i..=j]; precedence-violating reversals are
        // topologically repaired rather than discarded (dense-precedence
        // instances like br17.12 leave few raw-valid reversals)
        for i in 0..n {
            for j in (i + 1)..n {
                order[i..=j].reverse();
                let cand = if p.is_valid(order) {
                    Some(order.clone())
                } else {
                    repair_order(p, order)
                };
                order[i..=j].reverse();
                if let Some(cand) = cand {
                    let c = p.fitness(&cand);
                    if c + 1e-12 < *cost {
                        *cost = c;
                        *order = cand;
                        improved = true;
                    }
                }
            }
        }
        // single-task relocation
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                let t = order.remove(i);
                order.insert(j, t);
                if p.is_valid(order) {
                    let c = p.fitness(order);
                    if c + 1e-12 < *cost {
                        *cost = c;
                        improved = true;
                        continue;
                    }
                }
                let t = order.remove(j);
                order.insert(i, t);
            }
        }
    }
}

/// Run the GA from several seeds and keep the best (restarts are the
/// cheap cure for premature convergence on rugged precedence landscapes).
pub fn solve_genetic(p: &OrderingProblem, cfg: &GaConfig) -> Option<Solution> {
    let mut best: Option<Solution> = None;
    for r in 0..3u64 {
        let sub = GaConfig { seed: cfg.seed.wrapping_add(r * 0x9E37), ..cfg.clone() };
        if let Some(s) = solve_genetic_once(p, &sub) {
            if best.as_ref().map_or(true, |b| s.cost < b.cost) {
                best = Some(s);
            }
        }
    }
    // multi-start local search from fresh topological orders — escapes
    // the deep local optima dense-precedence instances trap the GA in
    if cfg.local_search {
        let mut rng = Pcg32::seed(cfg.seed ^ 0x5CA1AB1E);
        for _ in 0..8 {
            if let Some(mut o) = random_valid(p, &mut rng, 64) {
                let mut c = p.fitness(&o);
                local_search(p, &mut o, &mut c);
                if best.as_ref().map_or(true, |b| c < b.cost) {
                    best = Some(Solution { order: o, cost: c });
                }
            }
        }
    }
    best
}

fn solve_genetic_once(p: &OrderingProblem, cfg: &GaConfig) -> Option<Solution> {
    if p.n == 0 {
        return Some(Solution { order: vec![], cost: 0.0 });
    }
    let mut rng = Pcg32::seed(cfg.seed);
    let mut pop = seed_population(p, cfg.population, &mut rng)?;
    let mut best = pop
        .iter()
        .map(|o| (p.fitness(o), o.clone()))
        .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
        .map(|(c, o)| Solution { order: o, cost: c })?;

    let mut stall = 0usize;
    for _round in 0..cfg.max_rounds {
        // rank population by fitness
        let mut scored: Vec<(f64, Vec<usize>)> =
            pop.iter().map(|o| (p.fitness(o), o.clone())).collect();
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        if scored[0].0 + 1e-12 < best.cost {
            best = Solution { order: scored[0].1.clone(), cost: scored[0].0 };
            stall = 0;
        } else {
            stall += 1;
            if stall >= cfg.stall_rounds {
                break;
            }
        }

        // top-K pairs crossover + mutation
        let elite = scored.len().min(2 * cfg.k_pairs).max(2);
        let mut next: Vec<Vec<usize>> =
            scored.iter().take(elite).map(|(_, o)| o.clone()).collect();
        for pair in 0..cfg.k_pairs {
            let a = &scored[(2 * pair) % elite].1;
            let b = &scored[(2 * pair + 1) % elite].1;
            for child in crossover(a, b, cfg.crossover, &mut rng) {
                let mut c = child;
                if rng.chance(cfg.mutation_prob) {
                    mutate(&mut c, &mut rng);
                }
                if p.is_valid(&c) {
                    next.push(c);
                } else if cfg.repair {
                    if let Some(fixed) = repair_order(p, &c) {
                        debug_assert!(p.is_valid(&fixed));
                        next.push(fixed);
                    }
                }
            }
        }
        // refill with fresh valid random orders to maintain diversity
        while next.len() < cfg.population {
            if let Some(o) = random_valid(p, &mut rng, 64) {
                next.push(o);
            } else {
                break;
            }
        }
        if next.is_empty() {
            break;
        }
        pop = next;
    }
    if cfg.local_search {
        let mut order = best.order.clone();
        let mut cost = best.cost;
        local_search(p, &mut order, &mut cost);
        if cost < best.cost {
            best = Solution { order, cost };
        }
    }
    Some(best)
}

fn seed_population(
    p: &OrderingProblem,
    size: usize,
    rng: &mut Pcg32,
) -> Option<Vec<Vec<usize>>> {
    let mut pop = Vec::with_capacity(size);
    // include a greedy nearest-neighbour seed when feasible
    if let Some(g) = greedy_seed(p) {
        pop.push(g);
    }
    let mut failures = 0;
    while pop.len() < size && failures < 2000 {
        match random_valid(p, rng, 64) {
            Some(o) => pop.push(o),
            None => failures += 1,
        }
    }
    if pop.is_empty() {
        None
    } else {
        Some(pop)
    }
}

/// Topological-sort-with-random-tie-breaking: uniformly samples valid
/// orders even under dense precedence.
fn random_valid(p: &OrderingProblem, rng: &mut Pcg32, _tries: usize) -> Option<Vec<usize>> {
    let prereq = p.prereq_masks();
    let mut used = 0u32;
    let mut order = Vec::with_capacity(p.n);
    for _ in 0..p.n {
        let ready: Vec<usize> = (0..p.n)
            .filter(|&t| used & (1 << t) == 0 && prereq[t] & !used == 0)
            .collect();
        if ready.is_empty() {
            return None; // precedence cycle
        }
        let t = *rng.choose(&ready);
        order.push(t);
        used |= 1 << t;
    }
    Some(order)
}

/// Greedy nearest-neighbour respecting precedence.
fn greedy_seed(p: &OrderingProblem) -> Option<Vec<usize>> {
    let prereq = p.prereq_masks();
    let mut used = 0u32;
    let mut order: Vec<usize> = Vec::with_capacity(p.n);
    for _ in 0..p.n {
        let mut best: Option<(f64, usize)> = None;
        for t in 0..p.n {
            if used & (1 << t) != 0 || prereq[t] & !used != 0 {
                continue;
            }
            let c = order
                .last()
                .map_or(0.0, |&prev| p.exec_prob(t) * p.cost[prev][t]);
            if best.map_or(true, |(bc, _)| c < bc) {
                best = Some((c, t));
            }
        }
        let (_, t) = best?;
        order.push(t);
        used |= 1 << t;
    }
    Some(order)
}

fn crossover(
    a: &[usize],
    b: &[usize],
    kind: Crossover,
    rng: &mut Pcg32,
) -> Vec<Vec<usize>> {
    let n = a.len();
    if n < 2 {
        return vec![a.to_vec()];
    }
    match kind {
        Crossover::PrefixSwap => {
            let k = rng.range(1, n);
            let mut c1 = b[..k].to_vec();
            c1.extend_from_slice(&a[k..]);
            let mut c2 = a[..k].to_vec();
            c2.extend_from_slice(&b[k..]);
            vec![c1, c2] // possibly invalid; caller filters
        }
        Crossover::Order => {
            vec![ox1(a, b, rng), ox1(b, a, rng)]
        }
    }
}

/// OX1: copy a random slice from `a`, fill remaining positions with the
/// elements of `b` in order of appearance.
fn ox1(a: &[usize], b: &[usize], rng: &mut Pcg32) -> Vec<usize> {
    let n = a.len();
    let i = rng.below(n);
    let j = rng.below(n);
    let (lo, hi) = (i.min(j), i.max(j));
    let mut child = vec![usize::MAX; n];
    let mut in_slice = vec![false; n];
    for k in lo..=hi {
        child[k] = a[k];
        in_slice[a[k]] = true;
    }
    let mut fill = b.iter().filter(|&&t| !in_slice[t]);
    for slot in child.iter_mut() {
        if *slot == usize::MAX {
            *slot = *fill.next().expect("fill exhausted");
        }
    }
    child
}

fn mutate(order: &mut [usize], rng: &mut Pcg32) {
    if order.len() < 2 {
        return;
    }
    let i = rng.below(order.len());
    let j = rng.below(order.len());
    order.swap(i, j);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ordering::solve_held_karp;
    use crate::testkit::{gen, prop_check};

    fn random_problem(rng: &mut Pcg32, n: usize, prec_edges: usize) -> OrderingProblem {
        let flat = gen::sym_cost_matrix(rng, n, 100.0);
        let cost: Vec<Vec<f64>> =
            (0..n).map(|i| flat[i * n..(i + 1) * n].to_vec()).collect();
        let prec = gen::precedence_dag(rng, n, prec_edges);
        OrderingProblem::from_matrix(cost).with_precedence(prec)
    }

    #[test]
    fn ga_matches_exact_on_small_instances() {
        prop_check(
            "ga-near-optimal",
            15,
            |rng| {
                let n = gen::usize_in(rng, 4, 9);
                random_problem(rng, n, 2)
            },
            |p| {
                let exact = solve_held_karp(p).unwrap();
                let ga = solve_genetic(p, &GaConfig::default()).unwrap();
                if !p.is_valid(&ga.order) {
                    return Err("invalid order".into());
                }
                // GA must be within 10% of optimal on these tiny instances
                if ga.cost > exact.cost * 1.10 + 1e-9 {
                    return Err(format!("ga {} vs exact {}", ga.cost, exact.cost));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn ga_never_below_optimal() {
        prop_check(
            "ga-sound",
            15,
            |rng| {
                let n = gen::usize_in(rng, 3, 8);
                random_problem(rng, n, 3)
            },
            |p| {
                let exact = solve_held_karp(p).unwrap();
                let ga = solve_genetic(p, &GaConfig::default()).unwrap();
                if ga.cost + 1e-9 < exact.cost {
                    return Err(format!(
                        "GA {} claims better than exact {}",
                        ga.cost, exact.cost
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prefix_swap_variant_still_finds_valid_solutions() {
        let mut rng = Pcg32::seed(4);
        let p = random_problem(&mut rng, 7, 3);
        let cfg = GaConfig { crossover: Crossover::PrefixSwap, ..Default::default() };
        let s = solve_genetic(&p, &cfg).unwrap();
        assert!(p.is_valid(&s.order));
    }

    #[test]
    fn ox1_always_permutation() {
        prop_check(
            "ox1-perm",
            100,
            |rng| {
                let n = gen::usize_in(rng, 2, 12);
                (gen::permutation(rng, n), gen::permutation(rng, n), rng.split())
            },
            |(a, b, rng)| {
                let mut r = rng.clone();
                let c = ox1(a, b, &mut r);
                let mut s = c.clone();
                s.sort_unstable();
                if s == (0..a.len()).collect::<Vec<_>>() {
                    Ok(())
                } else {
                    Err(format!("not a permutation: {:?}", c))
                }
            },
        );
    }

    #[test]
    fn ga_handles_conditional_instances() {
        let mut rng = Pcg32::seed(21);
        let n = 8;
        let flat = gen::sym_cost_matrix(&mut rng, n, 80.0);
        let cost: Vec<Vec<f64>> =
            (0..n).map(|i| flat[i * n..(i + 1) * n].to_vec()).collect();
        let p = OrderingProblem::from_matrix(cost)
            .with_conditional(vec![(0, 3, 0.8), (1, 5, 0.5)]);
        let exact = solve_held_karp(&p).unwrap();
        let ga = solve_genetic(&p, &GaConfig::default()).unwrap();
        assert!(p.is_valid(&ga.order));
        assert!(ga.cost <= exact.cost * 1.10 + 1e-9);
    }
}
