//! Brute-force solver (§4.4): enumerate permutations, discard the ones
//! violating precedence, keep the best Eq. 7/8 fitness. Backtracking with
//! prerequisite pruning — fine for the small task counts of
//! resource-constrained deployments.

use super::{OrderingProblem, Solution};

/// Exhaustive search. Panics above 12 tasks (use Held–Karp or the GA).
pub fn solve_brute(p: &OrderingProblem) -> Option<Solution> {
    assert!(p.n <= 12, "brute-force solver capped at 12 tasks");
    if p.n == 0 {
        return Some(Solution { order: vec![], cost: 0.0 });
    }
    let prereq = p.prereq_masks();
    let mut best: Option<Solution> = None;
    let mut order = Vec::with_capacity(p.n);
    let mut used = 0u32;
    rec(p, &prereq, &mut order, &mut used, 0.0, &mut best);
    best
}

fn rec(
    p: &OrderingProblem,
    prereq: &[u32],
    order: &mut Vec<usize>,
    used: &mut u32,
    partial: f64,
    best: &mut Option<Solution>,
) {
    if let Some(b) = best {
        if partial >= b.cost {
            return; // admissible prune: costs are non-negative
        }
    }
    if order.len() == p.n {
        let total = if p.cyclic && p.n > 1 {
            partial
                + p.exec_prob(order[0]) * p.cost[order[p.n - 1]][order[0]]
        } else {
            partial
        };
        if best.as_ref().map_or(true, |b| total < b.cost) {
            *best = Some(Solution { order: order.clone(), cost: total });
        }
        return;
    }
    for t in 0..p.n {
        if *used & (1 << t) != 0 {
            continue;
        }
        if prereq[t] & !*used != 0 {
            continue; // an unfinished prerequisite
        }
        let step = if let Some(&prev) = order.last() {
            p.exec_prob(t) * p.cost[prev][t]
        } else {
            0.0
        };
        order.push(t);
        *used |= 1 << t;
        rec(p, prereq, order, used, partial + step, best);
        *used &= !(1 << t);
        order.pop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{gen, prop_check};

    #[test]
    fn finds_optimal_path() {
        // optimal path 1 -> 0 -> 2 costs 1 + 4 = 5? no: pick obvious chain
        let p = OrderingProblem::from_matrix(vec![
            vec![0.0, 1.0, 9.0],
            vec![1.0, 0.0, 1.0],
            vec![9.0, 1.0, 0.0],
        ]);
        let s = solve_brute(&p).unwrap();
        assert_eq!(s.cost, 2.0);
        assert!(s.order == vec![0, 1, 2] || s.order == vec![2, 1, 0]);
    }

    #[test]
    fn respects_precedence() {
        let p = OrderingProblem::from_matrix(vec![
            vec![0.0, 1.0, 9.0],
            vec![1.0, 0.0, 1.0],
            vec![9.0, 1.0, 0.0],
        ])
        .with_precedence(vec![(2, 0)]);
        let s = solve_brute(&p).unwrap();
        assert!(p.is_valid(&s.order));
        let pos = |t: usize| s.order.iter().position(|&x| x == t).unwrap();
        assert!(pos(2) < pos(0));
    }

    #[test]
    fn infeasible_returns_none() {
        let p = OrderingProblem::from_matrix(vec![vec![0.0, 1.0], vec![1.0, 0.0]])
            .with_precedence(vec![(0, 1), (1, 0)]);
        assert!(solve_brute(&p).is_none());
    }

    #[test]
    fn cyclic_objective_counts_wrap_edge() {
        let p = OrderingProblem::from_matrix(vec![
            vec![0.0, 1.0, 1.0],
            vec![1.0, 0.0, 1.0],
            vec![1.0, 1.0, 0.0],
        ])
        .cyclic();
        let s = solve_brute(&p).unwrap();
        assert_eq!(s.cost, 3.0);
    }

    #[test]
    fn prop_brute_never_beaten_by_random_valid_order() {
        prop_check(
            "brute-optimality",
            40,
            |rng| {
                let n = gen::usize_in(rng, 2, 8);
                let flat = gen::sym_cost_matrix(rng, n, 50.0);
                let cost: Vec<Vec<f64>> =
                    (0..n).map(|i| flat[i * n..(i + 1) * n].to_vec()).collect();
                let perm = gen::permutation(rng, n);
                (OrderingProblem::from_matrix(cost), perm)
            },
            |(p, perm)| {
                let s = solve_brute(p).unwrap();
                if !p.is_valid(&s.order) {
                    return Err("solution invalid".into());
                }
                if p.fitness(perm) + 1e-9 < s.cost {
                    return Err(format!(
                        "random order {} beats 'optimal' {}",
                        p.fitness(perm),
                        s.cost
                    ));
                }
                Ok(())
            },
        );
    }
}
