//! Optimal task execution order (§4): an asymmetric-TSP-like problem over
//! the switching-cost matrix (Eq. 3), proven NP-complete in the paper's
//! appendix, with precedence and conditional extensions (§4.3).
//!
//! Three solvers, cross-validated against each other in tests:
//!  * brute force (Eq. 7/8 fitness) — the paper's small-n solver
//!  * Held–Karp exact DP with precedence filtering — ground truth for
//!    Table 3's "Optimal" column (n ≤ ~17)
//!  * the appendix's genetic algorithm — the scalable solver

pub mod brute;
pub mod genetic;
pub mod held_karp;

pub use brute::solve_brute;
pub use genetic::{solve_genetic, GaConfig};
pub use held_karp::solve_held_karp;

/// A task-ordering instance.
#[derive(Debug, Clone)]
pub struct OrderingProblem {
    pub n: usize,
    /// c[i][j]: cost of switching from τ_i to τ_j.
    pub cost: Vec<Vec<f64>>,
    /// (a, b): τ_a must finish before τ_b starts (static, §4.3).
    pub precedence: Vec<(usize, usize)>,
    /// (a, b, p): τ_b runs only after τ_a, with probability p (dynamic,
    /// §4.3). Implies the precedence (a, b).
    pub conditional: Vec<(usize, usize, f64)>,
    /// Cyclic objective (least-cost Hamiltonian cycle, §2.3 / TSP
    /// instances) vs path objective (Eq. 7, one pass over the task set).
    pub cyclic: bool,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Solution {
    pub order: Vec<usize>,
    pub cost: f64,
}

impl OrderingProblem {
    pub fn from_matrix(cost: Vec<Vec<f64>>) -> OrderingProblem {
        let n = cost.len();
        OrderingProblem { n, cost, precedence: vec![], conditional: vec![], cyclic: false }
    }

    pub fn cyclic(mut self) -> OrderingProblem {
        self.cyclic = true;
        self
    }

    pub fn with_precedence(mut self, p: Vec<(usize, usize)>) -> OrderingProblem {
        self.precedence = p;
        self
    }

    pub fn with_conditional(mut self, c: Vec<(usize, usize, f64)>) -> OrderingProblem {
        self.conditional = c;
        self
    }

    /// All hard ordering edges: precedence plus the precedence implied by
    /// conditionals.
    pub fn all_precedence(&self) -> Vec<(usize, usize)> {
        let mut out = self.precedence.clone();
        out.extend(self.conditional.iter().map(|&(a, b, _)| (a, b)));
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Probability that τ_t executes (1.0 unless conditioned).
    pub fn exec_prob(&self, t: usize) -> f64 {
        self.conditional
            .iter()
            .filter(|&&(_, b, _)| b == t)
            .map(|&(_, _, p)| p)
            .product()
    }

    /// Eq. 7 / Eq. 8 fitness: sum of (expected) switching costs along the
    /// order, plus the wrap-around edge when cyclic.
    pub fn fitness(&self, order: &[usize]) -> f64 {
        let mut f = 0.0;
        for w in order.windows(2) {
            f += self.exec_prob(w[1]) * self.cost[w[0]][w[1]];
        }
        if self.cyclic && order.len() > 1 {
            let (last, first) = (order[order.len() - 1], order[0]);
            f += self.exec_prob(first) * self.cost[last][first];
        }
        f
    }

    /// Check hard constraints (a valid permutation respecting precedence).
    pub fn is_valid(&self, order: &[usize]) -> bool {
        if order.len() != self.n {
            return false;
        }
        let mut pos = vec![usize::MAX; self.n];
        for (i, &t) in order.iter().enumerate() {
            if t >= self.n || pos[t] != usize::MAX {
                return false;
            }
            pos[t] = i;
        }
        self.all_precedence()
            .iter()
            .all(|&(a, b)| pos[a] < pos[b])
    }

    /// Prerequisite bitmask per task (for the DP solver).
    pub fn prereq_masks(&self) -> Vec<u32> {
        let mut m = vec![0u32; self.n];
        for (a, b) in self.all_precedence() {
            m[b] |= 1 << a;
        }
        m
    }
}

/// Re-entrant compile entry point for per-tenant plans: restrict the
/// full n×n switching-cost matrix to `tasks` (a subset of original task
/// ids, any order), remap the constraints whose endpoints both fall
/// inside the subset, solve the restricted instance with Held–Karp, and
/// map the order back to original task ids. Constraints touching tasks
/// outside the subset are vacuous for this tenant and are dropped.
///
/// Returns `None` when the subset is empty, repeats a task, names a
/// task outside the matrix, or the restricted instance is infeasible
/// (contradictory precedence) — the caller falls back to the subset's
/// given order, mirroring `deployment_order`'s identity fallback.
pub fn solve_subset(
    cost: &[Vec<f64>],
    tasks: &[usize],
    precedence: &[(usize, usize)],
    conditional: &[(usize, usize, f64)],
) -> Option<Solution> {
    if tasks.is_empty() {
        return None;
    }
    // original task id -> position in the subset, usize::MAX = absent
    let mut local = vec![usize::MAX; cost.len()];
    for (i, &t) in tasks.iter().enumerate() {
        if t >= cost.len() || local[t] != usize::MAX {
            return None;
        }
        local[t] = i;
    }
    let sub_cost: Vec<Vec<f64>> = tasks
        .iter()
        .map(|&a| tasks.iter().map(|&b| cost[a][b]).collect())
        .collect();
    let sub_prec: Vec<(usize, usize)> = precedence
        .iter()
        .filter(|&&(a, b)| {
            a < local.len()
                && b < local.len()
                && local[a] != usize::MAX
                && local[b] != usize::MAX
        })
        .map(|&(a, b)| (local[a], local[b]))
        .collect();
    let sub_cond: Vec<(usize, usize, f64)> = conditional
        .iter()
        .filter(|&&(a, b, _)| {
            a < local.len()
                && b < local.len()
                && local[a] != usize::MAX
                && local[b] != usize::MAX
        })
        .map(|&(a, b, p)| (local[a], local[b], p))
        .collect();
    let problem = OrderingProblem::from_matrix(sub_cost)
        .with_precedence(sub_prec)
        .with_conditional(sub_cond);
    solve_held_karp(&problem).map(|s| Solution {
        order: s.order.iter().map(|&i| tasks[i]).collect(),
        cost: s.cost,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> OrderingProblem {
        // the Fig. 4 example spirit: 0-1 cheap, 0-2 pricey
        OrderingProblem::from_matrix(vec![
            vec![0.0, 1.0, 4.0],
            vec![1.0, 0.0, 2.0],
            vec![4.0, 2.0, 0.0],
        ])
    }

    #[test]
    fn fitness_path_and_cycle() {
        let p = toy();
        assert_eq!(p.fitness(&[0, 1, 2]), 3.0);
        let pc = toy().cyclic();
        assert_eq!(pc.fitness(&[0, 1, 2]), 7.0);
    }

    #[test]
    fn conditional_scales_edge_cost() {
        let p = toy().with_conditional(vec![(0, 2, 0.5)]);
        // edge into task 2 is halved in expectation
        assert_eq!(p.fitness(&[0, 1, 2]), 1.0 + 0.5 * 2.0);
        assert_eq!(p.exec_prob(2), 0.5);
        assert_eq!(p.exec_prob(1), 1.0);
    }

    #[test]
    fn validity_checks_precedence() {
        let p = toy().with_precedence(vec![(2, 0)]);
        assert!(!p.is_valid(&[0, 1, 2]));
        assert!(p.is_valid(&[2, 0, 1]));
        assert!(p.is_valid(&[2, 1, 0]));
        assert!(!p.is_valid(&[0, 0, 1]));
        assert!(!p.is_valid(&[0, 1]));
    }

    #[test]
    fn conditional_implies_precedence() {
        let p = toy().with_conditional(vec![(1, 0, 0.8)]);
        assert!(!p.is_valid(&[0, 1, 2]));
        assert!(p.is_valid(&[1, 0, 2]));
    }

    #[test]
    fn prereq_masks_built() {
        let p = toy().with_precedence(vec![(0, 2), (1, 2)]);
        let m = p.prereq_masks();
        assert_eq!(m[2], 0b011);
        assert_eq!(m[0], 0);
    }

    #[test]
    fn subset_of_everything_matches_the_full_solve() {
        let p = toy();
        let full = solve_held_karp(&p).unwrap();
        let sub = solve_subset(&p.cost, &[0, 1, 2], &[], &[]).unwrap();
        assert_eq!(sub.order, full.order);
        assert_eq!(sub.cost, full.cost);
    }

    #[test]
    fn subset_remaps_to_original_task_ids() {
        // tasks {0, 2} of the toy matrix: 0->2 costs 4, 2->0 costs 4
        // (symmetric), so both orders tie at cost 4 — but the returned
        // ids must be original ids, not subset positions
        let p = toy();
        let sub = solve_subset(&p.cost, &[2, 0], &[], &[]).unwrap();
        let mut ids = sub.order.clone();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 2]);
        assert_eq!(sub.cost, 4.0);
    }

    #[test]
    fn subset_keeps_only_inside_constraints() {
        // precedence (2, 0) binds inside {0, 2}; (1, 0) names task 1,
        // outside the subset, and must be dropped rather than panicking
        let p = toy();
        let sub =
            solve_subset(&p.cost, &[0, 2], &[(2, 0), (1, 0)], &[]).unwrap();
        assert_eq!(sub.order, vec![2, 0]);
        // conditional edges remap too: (0, 2, 0.5) halves the 0->2 edge
        let sub =
            solve_subset(&p.cost, &[0, 2], &[], &[(0, 2, 0.5)]).unwrap();
        assert_eq!(sub.order, vec![0, 2]);
        assert_eq!(sub.cost, 2.0);
    }

    #[test]
    fn subset_rejects_bad_inputs() {
        let p = toy();
        assert!(solve_subset(&p.cost, &[], &[], &[]).is_none());
        assert!(solve_subset(&p.cost, &[0, 0], &[], &[]).is_none());
        assert!(solve_subset(&p.cost, &[0, 7], &[], &[]).is_none());
        // contradictory precedence inside the subset is infeasible
        assert!(
            solve_subset(&p.cost, &[0, 1], &[(0, 1), (1, 0)], &[]).is_none()
        );
    }

    #[test]
    fn singleton_subset_is_trivially_ordered() {
        let p = toy();
        let sub = solve_subset(&p.cost, &[1], &[], &[]).unwrap();
        assert_eq!(sub.order, vec![1]);
        assert_eq!(sub.cost, 0.0);
    }
}
