//! Held–Karp exact dynamic program over subsets, with prerequisite
//! filtering for precedence/conditional instances. This is the ground
//! truth for Table 3's "Optimal" column (the published TSPLIB optima are
//! not available offline; solver-vs-solver comparison preserves the
//! table's claim — see DESIGN.md, Substitutions).

use super::{OrderingProblem, Solution};

/// Exact solution for n ≤ 20 (table is 2^n · n doubles).
pub fn solve_held_karp(p: &OrderingProblem) -> Option<Solution> {
    assert!(p.n <= 20, "Held-Karp capped at 20 tasks");
    if p.n == 0 {
        return Some(Solution { order: vec![], cost: 0.0 });
    }
    if p.n == 1 {
        return Some(Solution { order: vec![0], cost: 0.0 });
    }
    let n = p.n;
    let full: u32 = if n == 32 { u32::MAX } else { (1u32 << n) - 1 };
    let prereq = p.prereq_masks();
    let size = (full as usize + 1) * n;
    let mut dp = vec![f64::INFINITY; size];
    let mut parent = vec![u8::MAX; size];
    let idx = |mask: u32, j: usize| mask as usize * n + j;

    // Cyclic tours can start anywhere; fix task 0 as the start WLOG.
    // Paths may start at any task with no prerequisites.
    for j in 0..n {
        if prereq[j] != 0 {
            continue;
        }
        if p.cyclic && j != 0 {
            continue;
        }
        dp[idx(1 << j, j)] = 0.0;
    }

    for mask in 1..=full {
        for j in 0..n {
            let mj = 1u32 << j;
            if mask & mj == 0 {
                continue;
            }
            let cur = dp[idx(mask, j)];
            if !cur.is_finite() {
                continue;
            }
            // extend to k not yet visited whose prerequisites are all done
            for k in 0..n {
                let mk = 1u32 << k;
                if mask & mk != 0 || prereq[k] & !mask != 0 {
                    continue;
                }
                let next = mask | mk;
                let cand = cur + p.exec_prob(k) * p.cost[j][k];
                let slot = idx(next, k);
                if cand < dp[slot] {
                    dp[slot] = cand;
                    parent[slot] = j as u8;
                }
            }
        }
    }

    // pick the best endpoint
    let mut best_end = None;
    let mut best_cost = f64::INFINITY;
    for j in 0..n {
        let mut c = dp[idx(full, j)];
        if p.cyclic {
            c += p.exec_prob(0) * p.cost[j][0];
        }
        if c < best_cost {
            best_cost = c;
            best_end = Some(j);
        }
    }
    let mut j = best_end?;
    if !best_cost.is_finite() {
        return None;
    }
    // reconstruct
    let mut order = vec![j];
    let mut mask = full;
    while mask.count_ones() > 1 {
        let pj = parent[idx(mask, j)];
        debug_assert_ne!(pj, u8::MAX);
        mask &= !(1u32 << j);
        j = pj as usize;
        order.push(j);
    }
    order.reverse();
    Some(Solution { order, cost: best_cost })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ordering::solve_brute;
    use crate::testkit::{gen, prop_check};

    #[test]
    fn matches_brute_force_unconstrained() {
        prop_check(
            "hk-equals-brute",
            30,
            |rng| {
                let n = gen::usize_in(rng, 2, 9);
                let flat = gen::sym_cost_matrix(rng, n, 100.0);
                let cost: Vec<Vec<f64>> =
                    (0..n).map(|i| flat[i * n..(i + 1) * n].to_vec()).collect();
                let cyclic = rng.chance(0.5);
                let mut p = OrderingProblem::from_matrix(cost);
                if cyclic {
                    p = p.cyclic();
                }
                p
            },
            |p| {
                let a = solve_held_karp(p).unwrap();
                let b = solve_brute(p).unwrap();
                if (a.cost - b.cost).abs() > 1e-9 {
                    return Err(format!("hk {} vs brute {}", a.cost, b.cost));
                }
                if !p.is_valid(&a.order) {
                    return Err("invalid order".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn matches_brute_force_with_precedence() {
        prop_check(
            "hk-equals-brute-prec",
            30,
            |rng| {
                let n = gen::usize_in(rng, 3, 9);
                let flat = gen::sym_cost_matrix(rng, n, 100.0);
                let cost: Vec<Vec<f64>> =
                    (0..n).map(|i| flat[i * n..(i + 1) * n].to_vec()).collect();
                let prec = gen::precedence_dag(rng, n, n / 2 + 1);
                OrderingProblem::from_matrix(cost).with_precedence(prec)
            },
            |p| {
                let a = solve_held_karp(p).unwrap();
                let b = solve_brute(p).unwrap();
                if (a.cost - b.cost).abs() > 1e-9 {
                    return Err(format!("hk {} vs brute {}", a.cost, b.cost));
                }
                if !p.is_valid(&a.order) {
                    return Err("invalid order".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn matches_brute_force_with_conditional() {
        prop_check(
            "hk-equals-brute-cond",
            20,
            |rng| {
                let n = gen::usize_in(rng, 3, 8);
                let flat = gen::sym_cost_matrix(rng, n, 60.0);
                let cost: Vec<Vec<f64>> =
                    (0..n).map(|i| flat[i * n..(i + 1) * n].to_vec()).collect();
                let prec = gen::precedence_dag(rng, n, 2);
                let cond: Vec<(usize, usize, f64)> = prec
                    .iter()
                    .map(|&(a, b)| (a, b, 0.5 + rng.f64() * 0.5))
                    .collect();
                OrderingProblem::from_matrix(cost).with_conditional(cond)
            },
            |p| {
                let a = solve_held_karp(p).unwrap();
                let b = solve_brute(p).unwrap();
                if (a.cost - b.cost).abs() > 1e-9 {
                    return Err(format!("hk {} vs brute {}", a.cost, b.cost));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn infeasible_returns_none() {
        let p = OrderingProblem::from_matrix(vec![vec![0.0, 1.0], vec![1.0, 0.0]])
            .with_precedence(vec![(0, 1), (1, 0)]);
        assert!(solve_held_karp(&p).is_none());
    }

    #[test]
    fn handles_17_nodes() {
        let mut rng = crate::util::rng::Pcg32::seed(99);
        let n = 17;
        let flat = gen::sym_cost_matrix(&mut rng, n, 100.0);
        let cost: Vec<Vec<f64>> =
            (0..n).map(|i| flat[i * n..(i + 1) * n].to_vec()).collect();
        let p = OrderingProblem::from_matrix(cost).cyclic();
        let s = solve_held_karp(&p).unwrap();
        assert!(p.is_valid(&s.order));
        assert!(s.cost.is_finite());
    }
}
