//! Dataset-analog generators for the nine Table 2 datasets. One-vs-rest
//! binary tasks ("each task on a dataset corresponds to recognizing one
//! class"), 10 tasks per dataset except the HHAR analog's 6.

use crate::model::Tensor;
use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// Which common architecture this dataset's tasks use (Table 2).
    pub arch: &'static str,
    pub modality: &'static str, // image | audio | imu
    pub n_classes: usize,
    pub seed: u64,
    /// Class-pattern vs noise mix (higher = easier).
    pub signal: f32,
}

/// The nine dataset analogs (paper Table 2: 10 tasks each, HHAR 6).
pub fn standard_datasets() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec { name: "mnist-s", arch: "cnn5", modality: "image", n_classes: 10, seed: 101, signal: 2.2 },
        DatasetSpec { name: "fmnist-s", arch: "cnn5", modality: "image", n_classes: 10, seed: 102, signal: 1.8 },
        DatasetSpec { name: "cifar10-s", arch: "cnn7", modality: "image", n_classes: 10, seed: 103, signal: 1.4 },
        DatasetSpec { name: "svhn-s", arch: "cnn7", modality: "image", n_classes: 10, seed: 104, signal: 1.5 },
        DatasetSpec { name: "gtsrb-s", arch: "cnn5", modality: "image", n_classes: 10, seed: 105, signal: 2.0 },
        DatasetSpec { name: "gsc-s", arch: "cnn5", modality: "audio", n_classes: 10, seed: 106, signal: 1.7 },
        DatasetSpec { name: "esc-s", arch: "cnn5", modality: "audio", n_classes: 10, seed: 107, signal: 1.5 },
        DatasetSpec { name: "us8k-s", arch: "cnn5", modality: "audio", n_classes: 10, seed: 108, signal: 1.6 },
        DatasetSpec { name: "hhar-s", arch: "dnn4", modality: "imu", n_classes: 6, seed: 109, signal: 2.0 },
    ]
}

pub fn dataset_by_name(name: &str) -> Option<DatasetSpec> {
    standard_datasets().into_iter().find(|d| d.name == name)
}

/// A materialized dataset: samples + integer class labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub spec: DatasetSpec,
    /// [N, input...] samples.
    pub x: Tensor,
    pub labels: Vec<usize>,
    pub input_shape: Vec<usize>,
}

impl DatasetSpec {
    /// Generate `n` samples with the architecture's input shape.
    pub fn generate(&self, input_shape: &[usize], n: usize) -> Dataset {
        let mut rng = Pcg32::seed(self.seed);
        let feat: usize = input_shape.iter().product();
        // shared basis: 4 latent patterns every class template mixes —
        // this is what creates cross-task affinity at early layers
        let basis: Vec<Vec<f32>> = (0..4)
            .map(|_| smooth_pattern(input_shape, &mut rng))
            .collect();
        let templates: Vec<Vec<f32>> = (0..self.n_classes)
            .map(|_| {
                let own = smooth_pattern(input_shape, &mut rng);
                let mix: Vec<f32> = (0..4).map(|_| rng.f32() * 0.8).collect();
                (0..feat)
                    .map(|i| {
                        own[i] * 0.9
                            + basis.iter().zip(&mix).map(|(b, m)| b[i] * m).sum::<f32>()
                    })
                    .collect()
            })
            .collect();
        let mut data = Vec::with_capacity(n * feat);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % self.n_classes; // balanced
            labels.push(c);
            for f in 0..feat {
                data.push(self.signal * templates[c][f] + rng.gauss() * 0.8);
            }
        }
        let mut shape = vec![n];
        shape.extend_from_slice(input_shape);
        Dataset {
            spec: self.clone(),
            x: Tensor::new(shape, data),
            labels,
            input_shape: input_shape.to_vec(),
        }
    }
}

/// Low-frequency random pattern: a coarse 4-grid (per leading spatial dim)
/// bilinearly upsampled — learnable by 3x3 convs, unlike white noise.
fn smooth_pattern(shape: &[usize], rng: &mut Pcg32) -> Vec<f32> {
    match shape.len() {
        1 => {
            let n = shape[0];
            let coarse: Vec<f32> = (0..8).map(|_| rng.gauss()).collect();
            (0..n)
                .map(|i| {
                    let pos = i as f32 / n as f32 * 7.0;
                    let lo = pos.floor() as usize;
                    let t = pos - lo as f32;
                    coarse[lo] * (1.0 - t) + coarse[(lo + 1).min(7)] * t
                })
                .collect()
        }
        3 => {
            let (h, w, c) = (shape[0], shape[1], shape[2]);
            let g = 4usize;
            let coarse: Vec<f32> = (0..g * g * c).map(|_| rng.gauss()).collect();
            let mut out = Vec::with_capacity(h * w * c);
            for y in 0..h {
                for x in 0..w {
                    for ch in 0..c {
                        let fy = y as f32 / h as f32 * (g - 1) as f32;
                        let fx = x as f32 / w as f32 * (g - 1) as f32;
                        let (y0, x0) = (fy.floor() as usize, fx.floor() as usize);
                        let (ty, tx) = (fy - y0 as f32, fx - x0 as f32);
                        let at = |yy: usize, xx: usize| {
                            coarse[(yy.min(g - 1) * g + xx.min(g - 1)) * c + ch]
                        };
                        let v = at(y0, x0) * (1.0 - ty) * (1.0 - tx)
                            + at(y0, x0 + 1) * (1.0 - ty) * tx
                            + at(y0 + 1, x0) * ty * (1.0 - tx)
                            + at(y0 + 1, x0 + 1) * ty * tx;
                        out.push(v);
                    }
                }
            }
            out
        }
        other => panic!("unsupported input rank {other}"),
    }
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn n_tasks(&self) -> usize {
        self.spec.n_classes
    }

    /// Binary one-vs-rest label for `task` on sample `i`.
    pub fn binary_label(&self, task: usize, i: usize) -> i32 {
        (self.labels[i] == task) as i32
    }

    /// Train/test split indices (80/20, deterministic round-robin).
    pub fn split(&self) -> (Vec<usize>, Vec<usize>) {
        let mut train = Vec::new();
        let mut test = Vec::new();
        for i in 0..self.len() {
            if i % 5 == 4 {
                test.push(i);
            } else {
                train.push(i);
            }
        }
        (train, test)
    }

    /// Draw a class-balanced binary batch for `task`: half positives,
    /// half negatives (one-vs-rest with 10 classes is 90/10 imbalanced
    /// otherwise). Returns (x, y).
    pub fn balanced_batch(
        &self,
        task: usize,
        pool: &[usize],
        bsz: usize,
        rng: &mut Pcg32,
    ) -> (Tensor, Vec<i32>) {
        let pos: Vec<usize> =
            pool.iter().copied().filter(|&i| self.labels[i] == task).collect();
        let neg: Vec<usize> =
            pool.iter().copied().filter(|&i| self.labels[i] != task).collect();
        assert!(!pos.is_empty() && !neg.is_empty(), "degenerate task {task}");
        let mut idx = Vec::with_capacity(bsz);
        for k in 0..bsz {
            if k % 2 == 0 {
                idx.push(*rng.choose(&pos));
            } else {
                idx.push(*rng.choose(&neg));
            }
        }
        self.gather(&idx, task)
    }

    /// Gather samples by index into a batch tensor with binary labels.
    pub fn gather(&self, idx: &[usize], task: usize) -> (Tensor, Vec<i32>) {
        let feat: usize = self.input_shape.iter().product();
        let mut data = Vec::with_capacity(idx.len() * feat);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            data.extend_from_slice(&self.x.data[i * feat..(i + 1) * feat]);
            y.push(self.binary_label(task, i));
        }
        let mut shape = vec![idx.len()];
        shape.extend_from_slice(&self.input_shape);
        (Tensor::new(shape, data), y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nine_datasets_with_paper_task_counts() {
        let all = standard_datasets();
        assert_eq!(all.len(), 9);
        assert_eq!(all.iter().filter(|d| d.n_classes == 10).count(), 8);
        assert_eq!(dataset_by_name("hhar-s").unwrap().n_classes, 6);
        assert!(dataset_by_name("nope").is_none());
    }

    #[test]
    fn generation_is_deterministic_and_balanced() {
        let spec = dataset_by_name("mnist-s").unwrap();
        let a = spec.generate(&[16, 16, 1], 100);
        let b = spec.generate(&[16, 16, 1], 100);
        assert_eq!(a.x, b.x);
        for c in 0..10 {
            assert_eq!(a.labels.iter().filter(|&&l| l == c).count(), 10);
        }
    }

    #[test]
    fn classes_are_separated_in_input_space() {
        // within-class distance must be smaller than between-class
        let spec = dataset_by_name("mnist-s").unwrap();
        let d = spec.generate(&[16, 16, 1], 200);
        let feat = 256;
        let row = |i: usize| &d.x.data[i * feat..(i + 1) * feat];
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        let (mut within, mut wn, mut between, mut bn) = (0.0, 0, 0.0, 0);
        for i in 0..60 {
            for j in (i + 1)..60 {
                let dd = dist(row(i), row(j));
                if d.labels[i] == d.labels[j] {
                    within += dd;
                    wn += 1;
                } else {
                    between += dd;
                    bn += 1;
                }
            }
        }
        assert!((within / wn as f32) < (between / bn as f32));
    }

    #[test]
    fn split_is_80_20() {
        let spec = dataset_by_name("gsc-s").unwrap();
        let d = spec.generate(&[16, 16, 1], 500);
        let (train, test) = d.split();
        assert_eq!(train.len(), 400);
        assert_eq!(test.len(), 100);
    }

    #[test]
    fn balanced_batch_is_half_positive() {
        let spec = dataset_by_name("esc-s").unwrap();
        let d = spec.generate(&[16, 16, 1], 300);
        let (train, _) = d.split();
        let mut rng = Pcg32::seed(7);
        let (x, y) = d.balanced_batch(3, &train, 32, &mut rng);
        assert_eq!(x.shape, vec![32, 16, 16, 1]);
        assert_eq!(y.iter().filter(|&&l| l == 1).count(), 16);
    }

    #[test]
    fn imu_dataset_is_1d() {
        let spec = dataset_by_name("hhar-s").unwrap();
        let d = spec.generate(&[128], 60);
        assert_eq!(d.x.shape, vec![60, 128]);
    }
}
