//! Synthetic dataset generators — the stand-ins for the nine public
//! datasets of Table 2 and the §7 deployment recordings (no network or
//! human-subject data exists in this environment; see DESIGN.md,
//! Substitutions).
//!
//! Design requirements the generators satisfy so Antler's claims are
//! exercised for real:
//!  * tasks over one domain share low-level latent structure (class
//!    templates are mixtures over a *shared* basis), so early-layer
//!    representations correlate across tasks → meaningful affinity;
//!  * classes are separable by the small common architectures at the
//!    paper's ~90% accuracy level, tunable via the noise scale;
//!  * everything is deterministic from a seed.

pub mod deployment;
pub mod synthetic;

pub use deployment::{audio_stream_spec, image_stream_spec, DeploymentSpec};
pub use synthetic::{dataset_by_name, standard_datasets, Dataset, DatasetSpec};
