//! §7 deployment analogs: multi-factor sensor streams where EVERY task
//! labels the SAME sample (five audio tasks, four image tasks), including
//! the presence factor that drives the precedence/conditional experiments.

use crate::model::Tensor;
use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct TaskDef {
    pub name: &'static str,
    pub ncls: usize,
}

#[derive(Debug, Clone)]
pub struct DeploymentSpec {
    pub name: &'static str,
    pub arch: &'static str,
    pub input_shape: Vec<usize>,
    pub tasks: Vec<TaskDef>,
    /// Index of the presence-detection task (τ0 in both deployments).
    pub presence_task: usize,
    /// P(presence) in the stream — the paper's conditional experiments
    /// execute the remaining tasks at 80%.
    pub presence_prob: f64,
    pub seed: u64,
}

/// §7.1: five audio tasks on the 16-bit system.
pub fn audio_stream_spec() -> DeploymentSpec {
    DeploymentSpec {
        name: "audio",
        arch: "cnn5",
        input_shape: vec![16, 16, 1],
        tasks: vec![
            TaskDef { name: "presence", ncls: 2 },
            TaskDef { name: "command", ncls: 11 },
            TaskDef { name: "speaker", ncls: 5 },
            TaskDef { name: "emotion", ncls: 3 },
            TaskDef { name: "distance", ncls: 2 },
        ],
        presence_task: 0,
        presence_prob: 0.8,
        seed: 710,
    }
}

/// §7.2: four image tasks on the 32-bit system.
pub fn image_stream_spec() -> DeploymentSpec {
    DeploymentSpec {
        name: "image",
        arch: "cnn7",
        input_shape: vec![32, 32, 1],
        tasks: vec![
            TaskDef { name: "presence", ncls: 2 },
            TaskDef { name: "mask", ncls: 2 },
            TaskDef { name: "identity", ncls: 5 },
            TaskDef { name: "emotion", ncls: 3 },
        ],
        presence_task: 0,
        presence_prob: 0.8,
        seed: 720,
    }
}

/// Materialized stream: every sample labelled by every task.
#[derive(Debug, Clone)]
pub struct DeploymentData {
    pub spec: DeploymentSpec,
    pub x: Tensor,
    /// labels[task][sample]
    pub labels: Vec<Vec<usize>>,
}

impl DeploymentSpec {
    pub fn ncls_vec(&self) -> Vec<usize> {
        self.tasks.iter().map(|t| t.ncls).collect()
    }

    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Generate `n` stream samples. Each non-presence factor contributes
    /// an additive class pattern scaled by presence; tasks therefore
    /// share latent structure (→ affinity) and absence makes dependent
    /// labels trivial/skippable (→ conditional experiments).
    pub fn generate(&self, n: usize) -> DeploymentData {
        let mut rng = Pcg32::seed(self.seed);
        let feat: usize = self.input_shape.iter().product();
        // per task, per class, a smooth pattern on a shared coarse basis
        let shared: Vec<f32> = (0..feat).map(|_| rng.gauss() * 0.5).collect();
        let patterns: Vec<Vec<Vec<f32>>> = self
            .tasks
            .iter()
            .map(|t| {
                (0..t.ncls)
                    .map(|_| {
                        (0..feat)
                            .map(|i| rng.gauss() + 0.6 * shared[i])
                            .collect()
                    })
                    .collect()
            })
            .collect();
        let mut data = Vec::with_capacity(n * feat);
        let mut labels = vec![Vec::with_capacity(n); self.n_tasks()];
        for _ in 0..n {
            let present = rng.chance(self.presence_prob);
            let mut sample = vec![0.0f32; feat];
            for (t, task) in self.tasks.iter().enumerate() {
                let label = if t == self.presence_task {
                    present as usize
                } else if present {
                    rng.below(task.ncls)
                } else {
                    0 // undefined when nothing is present
                };
                labels[t].push(label);
                if present {
                    let scale = if t == self.presence_task { 1.4 } else { 1.0 };
                    for i in 0..feat {
                        sample[i] += scale * patterns[t][label][i]
                            / (self.n_tasks() as f32).sqrt() * 1.6;
                    }
                }
            }
            for i in 0..feat {
                data.push(sample[i] + rng.gauss() * 0.4);
            }
        }
        let mut shape = vec![n];
        shape.extend_from_slice(&self.input_shape);
        DeploymentData { spec: self.clone(), x: Tensor::new(shape, data), labels }
    }
}

impl DeploymentData {
    pub fn len(&self) -> usize {
        self.labels[0].len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn split(&self) -> (Vec<usize>, Vec<usize>) {
        let mut train = Vec::new();
        let mut test = Vec::new();
        for i in 0..self.len() {
            if i % 5 == 4 {
                test.push(i);
            } else {
                train.push(i);
            }
        }
        (train, test)
    }

    /// Gather a batch for one task: (x, labels) with class-stratified
    /// sampling so every class appears.
    pub fn batch(
        &self,
        task: usize,
        pool: &[usize],
        bsz: usize,
        rng: &mut Pcg32,
    ) -> (Tensor, Vec<i32>) {
        let ncls = self.spec.tasks[task].ncls;
        let by_class: Vec<Vec<usize>> = (0..ncls)
            .map(|c| {
                pool.iter()
                    .copied()
                    .filter(|&i| self.labels[task][i] == c)
                    .collect()
            })
            .collect();
        let mut idx = Vec::with_capacity(bsz);
        let mut c = 0usize;
        while idx.len() < bsz {
            let class = &by_class[c % ncls];
            c += 1;
            if class.is_empty() {
                continue;
            }
            idx.push(*rng.choose(class));
        }
        self.gather(task, &idx)
    }

    pub fn gather(&self, task: usize, idx: &[usize]) -> (Tensor, Vec<i32>) {
        let feat: usize = self.spec.input_shape.iter().product();
        let mut data = Vec::with_capacity(idx.len() * feat);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            data.extend_from_slice(&self.x.data[i * feat..(i + 1) * feat]);
            y.push(self.labels[task][i] as i32);
        }
        let mut shape = vec![idx.len()];
        shape.extend_from_slice(&self.spec.input_shape);
        (Tensor::new(shape, data), y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_match_section7() {
        let a = audio_stream_spec();
        assert_eq!(a.ncls_vec(), vec![2, 11, 5, 3, 2]);
        assert_eq!(a.arch, "cnn5");
        let i = image_stream_spec();
        assert_eq!(i.ncls_vec(), vec![2, 2, 5, 3]);
        assert_eq!(i.arch, "cnn7");
    }

    #[test]
    fn presence_rate_near_spec() {
        let d = audio_stream_spec().generate(1000);
        let present =
            d.labels[0].iter().filter(|&&l| l == 1).count() as f64 / 1000.0;
        assert!((present - 0.8).abs() < 0.05, "{present}");
    }

    #[test]
    fn absent_samples_have_default_labels() {
        let d = audio_stream_spec().generate(500);
        for i in 0..d.len() {
            if d.labels[0][i] == 0 {
                for t in 1..d.spec.n_tasks() {
                    assert_eq!(d.labels[t][i], 0);
                }
            }
        }
    }

    #[test]
    fn batch_covers_all_classes() {
        let d = audio_stream_spec().generate(2000);
        let (train, _) = d.split();
        let mut rng = Pcg32::seed(5);
        let (x, y) = d.batch(1, &train, 33, &mut rng); // command, 11 classes
        assert_eq!(x.shape[0], 33);
        let seen: std::collections::HashSet<i32> = y.into_iter().collect();
        assert!(seen.len() >= 8, "classes seen: {:?}", seen);
    }

    #[test]
    fn deterministic() {
        let a = image_stream_spec().generate(64);
        let b = image_stream_spec().generate(64);
        assert_eq!(a.x, b.x);
        assert_eq!(a.labels, b.labels);
    }
}
