//! Model descriptions shared with the python compile path via
//! `artifacts/manifest.json`: architectures, layer specs, parameter
//! layouts, and the `Tensor` type that flows through the whole system.

pub mod archs;
pub mod manifest;
pub mod tensor;

pub use manifest::{ArchSpec, Artifact, LayerKind, LayerSpec, Manifest};
pub use tensor::Tensor;

/// Bytes per stored weight. The paper's deployments store f32 weights in
/// external memory (FRAM/flash); quantized baselines override this.
pub const BYTES_PER_WEIGHT: usize = 4;
