//! Dense f32 tensor with shape metadata — the single value type exchanged
//! between the data generators, the weight stores, and the PJRT runtime.

use crate::util::rng::Pcg32;

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} vs data len {}",
            shape,
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    /// He-style init matching `model.init_params` on the python side.
    pub fn he_init(shape: Vec<usize>, rng: &mut Pcg32) -> Tensor {
        if shape.len() < 2 {
            return Tensor::zeros(shape); // biases start at zero
        }
        let fan_in: usize = shape[..shape.len() - 1].iter().product();
        let scale = (2.0 / fan_in as f32).sqrt();
        let n = shape.iter().product();
        let data = (0..n).map(|_| rng.gauss() * scale).collect();
        Tensor { shape, data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * super::BYTES_PER_WEIGHT
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Flat view of the first `batch` elements along axis 0.
    pub fn slice_batch(&self, start: usize, count: usize) -> Tensor {
        assert!(!self.shape.is_empty());
        let per: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = count;
        Tensor::new(
            shape,
            self.data[start * per..(start + count) * per].to_vec(),
        )
    }

    /// Concatenate along axis 0 (all trailing dims must match).
    pub fn concat_batch(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let tail = &parts[0].shape[1..];
        let mut total = 0;
        let mut data = Vec::new();
        for p in parts {
            assert_eq!(&p.shape[1..], tail);
            total += p.shape[0];
            data.extend_from_slice(&p.data);
        }
        let mut shape = vec![total];
        shape.extend_from_slice(tail);
        Tensor::new(shape, data)
    }

    /// L2 distance to another tensor (same shape), for test assertions.
    pub fn l2_dist(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.bytes(), 24);
    }

    #[test]
    #[should_panic]
    fn new_rejects_mismatch() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn he_init_scale() {
        let mut rng = Pcg32::seed(1);
        let t = Tensor::he_init(vec![256, 64], &mut rng);
        let var = t.data.iter().map(|x| x * x).sum::<f32>() / t.len() as f32;
        let expect = 2.0 / 256.0;
        assert!((var - expect).abs() < expect * 0.2, "var {}", var);
    }

    #[test]
    fn bias_init_zero() {
        let mut rng = Pcg32::seed(2);
        let b = Tensor::he_init(vec![8], &mut rng);
        assert!(b.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn slice_and_concat_roundtrip() {
        let t = Tensor::new(vec![4, 2], (0..8).map(|x| x as f32).collect());
        let a = t.slice_batch(0, 2);
        let b = t.slice_batch(2, 2);
        assert_eq!(a.shape, vec![2, 2]);
        let back = Tensor::concat_batch(&[&a, &b]);
        assert_eq!(back, t);
    }
}
