//! `artifacts/manifest.json` — the contract between the python compile
//! path (L1/L2) and the rust coordinator (L3). Parsed with the in-tree
//! JSON codec; shapes here drive the weight stores, the cost models, and
//! the PJRT argument marshalling.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    ConvPool,
    Dense,
    Logits,
}

impl LayerKind {
    pub fn parse(s: &str) -> Result<LayerKind> {
        Ok(match s {
            "conv_pool" => LayerKind::ConvPool,
            "dense" => LayerKind::Dense,
            "logits" => LayerKind::Logits,
            other => bail!("unknown layer kind {other:?}"),
        })
    }
}

#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub kind: LayerKind,
    /// Activation shape entering this layer (no batch dim).
    pub in_shape: Vec<usize>,
    /// Activation shape leaving this layer (no batch dim).
    pub out_shape: Vec<usize>,
    /// Multiply-accumulates per sample (drives the device time model).
    pub macs_per_sample: u64,
    /// Raw cfg fields (kh/kw/cin/cout or din/dout). dout==0 on logits means
    /// "class count chosen per task".
    pub cfg: BTreeMap<String, usize>,
}

impl LayerSpec {
    /// The per-task classification head: its output width (`dout == 0`
    /// in the cfg) is chosen per task at instantiation time.
    pub fn is_logits(&self) -> bool {
        self.kind == LayerKind::Logits
    }

    /// Parameter shapes [w, b] for a given class count.
    pub fn param_shapes(&self, ncls: usize) -> Vec<Vec<usize>> {
        match self.kind {
            LayerKind::ConvPool => vec![
                vec![
                    self.cfg["kh"],
                    self.cfg["kw"],
                    self.cfg["cin"],
                    self.cfg["cout"],
                ],
                vec![self.cfg["cout"]],
            ],
            LayerKind::Dense | LayerKind::Logits => {
                let dout = if self.cfg["dout"] == 0 { ncls } else { self.cfg["dout"] };
                vec![vec![self.cfg["din"], dout], vec![dout]]
            }
        }
    }

    /// Parameter count (weights + biases) for a given class count.
    pub fn param_count(&self, ncls: usize) -> usize {
        self.param_shapes(ncls)
            .iter()
            .map(|s| s.iter().product::<usize>())
            .sum()
    }

    pub fn param_bytes(&self, ncls: usize) -> usize {
        self.param_count(ncls) * super::BYTES_PER_WEIGHT
    }

    /// Output activation element count per sample.
    pub fn out_elems(&self) -> usize {
        self.out_shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArchSpec {
    pub name: String,
    pub input: Vec<usize>,
    pub layers: Vec<LayerSpec>,
    /// Class counts the AOT pass lowered train/eval/logits artifacts for.
    pub ncls_available: Vec<usize>,
}

impl ArchSpec {
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn input_elems(&self) -> usize {
        self.input.iter().product()
    }

    /// Total parameter count of one network instance.
    pub fn total_params(&self, ncls: usize) -> usize {
        self.layers.iter().map(|l| l.param_count(ncls)).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs_per_sample).sum()
    }

    /// Flat [w0, b0, w1, b1, ...] shape list — must match python
    /// `model.param_shapes` ordering exactly.
    pub fn flat_param_shapes(&self, ncls: usize) -> Vec<Vec<usize>> {
        self.layers
            .iter()
            .flat_map(|l| l.param_shapes(ncls))
            .collect()
    }
}

#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub kind: String, // "layer" | "train" | "eval"
    pub arch: String,
    pub layer: Option<usize>,
    pub ncls: Option<usize>,
    pub batch: usize,
    pub file: String,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub archs: BTreeMap<String, ArchSpec>,
    pub entries: BTreeMap<String, Artifact>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        Self::from_json(dir.to_path_buf(), &json)
    }

    pub fn from_json(dir: PathBuf, json: &Json) -> Result<Manifest> {
        let version = json
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing version"))?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut archs = BTreeMap::new();
        for (name, spec) in json
            .get("archs")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing archs"))?
        {
            archs.insert(name.clone(), parse_arch(name, spec)?);
        }
        let mut entries = BTreeMap::new();
        for e in json
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing entries"))?
        {
            let a = parse_artifact(e)?;
            entries.insert(a.name.clone(), a);
        }
        Ok(Manifest { dir, archs, entries })
    }

    pub fn arch(&self, name: &str) -> Result<&ArchSpec> {
        self.archs
            .get(name)
            .ok_or_else(|| anyhow!("unknown arch {name:?}"))
    }

    pub fn entry(&self, name: &str) -> Result<&Artifact> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))
    }

    /// Artifact name for a layer executable.
    pub fn layer_artifact(
        &self,
        arch: &str,
        layer: usize,
        ncls: Option<usize>,
        batch: usize,
    ) -> String {
        match ncls {
            Some(c) => format!("layer_{arch}_{layer}_c{c}_b{batch}"),
            None => format!("layer_{arch}_{layer}_b{batch}"),
        }
    }

    pub fn train_artifact(&self, arch: &str, ncls: usize) -> String {
        format!("train_{arch}_c{ncls}")
    }

    pub fn eval_artifact(&self, arch: &str, ncls: usize) -> String {
        format!("eval_{arch}_c{ncls}")
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.entry(name)?.file))
    }
}

fn parse_arch(name: &str, j: &Json) -> Result<ArchSpec> {
    let input = j
        .get("input")
        .and_then(Json::as_usize_vec)
        .ok_or_else(|| anyhow!("arch {name}: missing input"))?;
    let mut layers = Vec::new();
    for l in j
        .get("layers")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("arch {name}: missing layers"))?
    {
        let kind = LayerKind::parse(
            l.get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("layer missing kind"))?,
        )?;
        let cfg = l
            .get("cfg")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("layer missing cfg"))?
            .iter()
            .map(|(k, v)| (k.clone(), v.as_usize().unwrap_or(0)))
            .collect();
        layers.push(LayerSpec {
            kind,
            in_shape: l
                .get("in")
                .and_then(Json::as_usize_vec)
                .ok_or_else(|| anyhow!("layer missing in"))?,
            out_shape: l
                .get("out")
                .and_then(Json::as_usize_vec)
                .ok_or_else(|| anyhow!("layer missing out"))?,
            macs_per_sample: l
                .get("macs_per_sample")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("layer missing macs"))? as u64,
            cfg,
        });
    }
    let ncls_available = j
        .get("ncls")
        .and_then(Json::as_usize_vec)
        .ok_or_else(|| anyhow!("arch {name}: missing ncls"))?;
    Ok(ArchSpec { name: name.to_string(), input, layers, ncls_available })
}

fn parse_artifact(j: &Json) -> Result<Artifact> {
    let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
        j.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("artifact missing {key}"))?
            .iter()
            .map(|s| {
                s.as_usize_vec()
                    .ok_or_else(|| anyhow!("bad shape in {key}"))
            })
            .collect()
    };
    Ok(Artifact {
        name: j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("artifact missing name"))?
            .to_string(),
        kind: j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("artifact missing kind"))?
            .to_string(),
        arch: j
            .get("arch")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("artifact missing arch"))?
            .to_string(),
        layer: j.get("layer").and_then(Json::as_usize),
        ncls: j.get("ncls").and_then(|v| v.as_usize()),
        batch: j
            .get("batch")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("artifact missing batch"))?,
        file: j
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("artifact missing file"))?
            .to_string(),
        inputs: shapes("inputs")?,
        outputs: shapes("outputs")?,
    })
}

/// Default artifacts directory: `$ANTLER_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("ANTLER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_manifest() -> Json {
        Json::parse(
            r#"{
          "version": 1,
          "archs": {
            "cnn5": {
              "input": [16,16,1],
              "layers": [
                {"kind":"conv_pool","cfg":{"kh":3,"kw":3,"cin":1,"cout":8},
                 "in":[16,16,1],"out":[8,8,8],"macs_per_sample":18432},
                {"kind":"dense","cfg":{"din":512,"dout":64},
                 "in":[8,8,8],"out":[64],"macs_per_sample":32768},
                {"kind":"logits","cfg":{"din":64,"dout":0},
                 "in":[64],"out":[2],"macs_per_sample":128}
              ],
              "ncls": [2,3]
            }
          },
          "entries": [
            {"name":"layer_cnn5_0_b1","kind":"layer","arch":"cnn5","layer":0,
             "layer_kind":"conv_pool","ncls":null,"batch":1,
             "file":"layer_cnn5_0_b1.hlo.txt",
             "inputs":[[1,16,16,1],[3,3,1,8],[8]],"outputs":[[1,8,8,8]]}
          ]
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_arch_and_shapes() {
        let m = Manifest::from_json(PathBuf::from("/tmp"), &tiny_manifest()).unwrap();
        let a = m.arch("cnn5").unwrap();
        assert_eq!(a.n_layers(), 3);
        assert_eq!(a.layers[0].param_shapes(2), vec![vec![3, 3, 1, 8], vec![8]]);
        // logits layer resolves dout=0 -> ncls
        assert_eq!(a.layers[2].param_shapes(5), vec![vec![64, 5], vec![5]]);
        assert_eq!(a.layers[2].param_count(3), 64 * 3 + 3);
        assert_eq!(a.total_macs(), 18432 + 32768 + 128);
    }

    #[test]
    fn artifact_lookup_and_names() {
        let m = Manifest::from_json(PathBuf::from("/tmp"), &tiny_manifest()).unwrap();
        assert!(m.entry("layer_cnn5_0_b1").is_ok());
        assert!(m.entry("nope").is_err());
        assert_eq!(m.layer_artifact("cnn5", 2, Some(3), 1), "layer_cnn5_2_c3_b1");
        assert_eq!(m.layer_artifact("cnn5", 0, None, 32), "layer_cnn5_0_b32");
        assert_eq!(m.train_artifact("cnn5", 2), "train_cnn5_c2");
    }

    #[test]
    fn flat_param_shapes_order() {
        let m = Manifest::from_json(PathBuf::from("/tmp"), &tiny_manifest()).unwrap();
        let shapes = m.arch("cnn5").unwrap().flat_param_shapes(2);
        assert_eq!(shapes.len(), 6);
        assert_eq!(shapes[0], vec![3, 3, 1, 8]);
        assert_eq!(shapes[1], vec![8]);
        assert_eq!(shapes[4], vec![64, 2]);
    }

    #[test]
    fn rejects_bad_version() {
        let j = Json::parse(r#"{"version":9,"archs":{},"entries":[]}"#).unwrap();
        assert!(Manifest::from_json(PathBuf::from("/tmp"), &j).is_err());
    }
}
