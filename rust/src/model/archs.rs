//! Built-in architecture registry — the rust-side mirror of
//! `python/compile/model.py::ARCHS`, so backends that never touch a
//! manifest (the reference interpreter, the sim-only figure drivers)
//! still know every common architecture's layer list, shapes and MAC
//! counts. When PJRT artifacts exist, `manifest.json` is authoritative;
//! these specs are byte-identical to what `compile.aot` emits.

use std::collections::BTreeMap;

use crate::model::manifest::{ArchSpec, Manifest};
use crate::util::json::Json;

/// Embedded copy of the manifest `archs` section (entries elided).
/// Must track python/compile/model.py — test_aot.py checks the python
/// side; `builtin_matches_layer_algebra` below checks this side.
const EMBEDDED_ARCHS: &str = r#"{
  "version": 1,
  "archs": {
    "cnn5": {"input": [16,16,1], "ncls": [2,3,5,11], "layers": [
      {"kind":"conv_pool","cfg":{"kh":3,"kw":3,"cin":1,"cout":8},"in":[16,16,1],"out":[8,8,8],"macs_per_sample":18432},
      {"kind":"conv_pool","cfg":{"kh":3,"kw":3,"cin":8,"cout":16},"in":[8,8,8],"out":[4,4,16],"macs_per_sample":73728},
      {"kind":"dense","cfg":{"din":256,"dout":64},"in":[4,4,16],"out":[64],"macs_per_sample":16384},
      {"kind":"dense","cfg":{"din":64,"dout":32},"in":[64],"out":[32],"macs_per_sample":2048},
      {"kind":"logits","cfg":{"din":32,"dout":0},"in":[32],"out":[2],"macs_per_sample":64}]},
    "cnn7": {"input": [32,32,1], "ncls": [2,3,5], "layers": [
      {"kind":"conv_pool","cfg":{"kh":3,"kw":3,"cin":1,"cout":8},"in":[32,32,1],"out":[16,16,8],"macs_per_sample":73728},
      {"kind":"conv_pool","cfg":{"kh":3,"kw":3,"cin":8,"cout":16},"in":[16,16,8],"out":[8,8,16],"macs_per_sample":294912},
      {"kind":"conv_pool","cfg":{"kh":3,"kw":3,"cin":16,"cout":32},"in":[8,8,16],"out":[4,4,32],"macs_per_sample":294912},
      {"kind":"dense","cfg":{"din":512,"dout":128},"in":[4,4,32],"out":[128],"macs_per_sample":65536},
      {"kind":"dense","cfg":{"din":128,"dout":64},"in":[128],"out":[64],"macs_per_sample":8192},
      {"kind":"dense","cfg":{"din":64,"dout":32},"in":[64],"out":[32],"macs_per_sample":2048},
      {"kind":"logits","cfg":{"din":32,"dout":0},"in":[32],"out":[2],"macs_per_sample":64}]},
    "dnn4": {"input": [128], "ncls": [2], "layers": [
      {"kind":"dense","cfg":{"din":128,"dout":64},"in":[128],"out":[64],"macs_per_sample":8192},
      {"kind":"dense","cfg":{"din":64,"dout":64},"in":[64],"out":[64],"macs_per_sample":4096},
      {"kind":"dense","cfg":{"din":64,"dout":32},"in":[64],"out":[32],"macs_per_sample":2048},
      {"kind":"logits","cfg":{"din":32,"dout":0},"in":[32],"out":[2],"macs_per_sample":64}]}
  },
  "entries": []
}"#;

/// Every built-in architecture, keyed by name.
pub fn builtin_archs() -> BTreeMap<String, ArchSpec> {
    Manifest::from_json(
        std::path::PathBuf::from("."),
        &Json::parse(EMBEDDED_ARCHS).expect("embedded archs parse"),
    )
    .expect("embedded manifest parses")
    .archs
}

/// One built-in architecture by name.
pub fn builtin_arch(name: &str) -> Option<ArchSpec> {
    builtin_archs().remove(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_all_three_archs() {
        let archs = builtin_archs();
        assert_eq!(
            archs.keys().cloned().collect::<Vec<_>>(),
            vec!["cnn5", "cnn7", "dnn4"]
        );
    }

    #[test]
    fn builtin_matches_layer_algebra() {
        // the embedded in/out/macs fields must be derivable from cfg the
        // same way python/compile/aot.py derives them
        for (name, arch) in builtin_archs() {
            let mut shape = arch.input.clone();
            for (i, l) in arch.layers.iter().enumerate() {
                assert_eq!(l.in_shape, shape, "{name} layer {i} in_shape");
                match l.kind {
                    crate::model::LayerKind::ConvPool => {
                        let (h, w) = (shape[0], shape[1]);
                        assert_eq!(shape[2], l.cfg["cin"], "{name} layer {i}");
                        let macs = (h * w
                            * l.cfg["kh"]
                            * l.cfg["kw"]
                            * l.cfg["cin"]
                            * l.cfg["cout"]) as u64;
                        assert_eq!(l.macs_per_sample, macs, "{name} layer {i}");
                        shape = vec![h / 2, w / 2, l.cfg["cout"]];
                    }
                    _ => {
                        let din: usize = shape.iter().product();
                        assert_eq!(din, l.cfg["din"], "{name} layer {i}");
                        let dout = if l.cfg["dout"] == 0 { 2 } else { l.cfg["dout"] };
                        assert_eq!(l.macs_per_sample, (din * dout) as u64);
                        shape = vec![dout];
                    }
                }
                assert_eq!(l.out_shape, shape, "{name} layer {i} out_shape");
            }
        }
    }

    #[test]
    fn builtin_arch_lookup() {
        assert!(builtin_arch("cnn5").is_some());
        assert_eq!(builtin_arch("dnn4").unwrap().n_layers(), 4);
        assert!(builtin_arch("resnet50").is_none());
    }
}
