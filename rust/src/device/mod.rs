//! Device cost models — the substitute for the paper's physical testbeds
//! (Table 1): a 16-bit TI MSP430FR5994 custom board with external SPI FRAM
//! and a 32-bit ARM Cortex-M7 STM32H747 with on-package eFlash. Every time
//! and energy number reported by the benchmark harness is derived from
//! these models: t = MACs·cpm/f + bytes/bandwidth, E = P·t + e_byte·bytes.
//!
//! Calibration targets (from the paper):
//!  * per-MAC latency ratio MSP430:STM32 ≈ 100× (§6.3 "execution time on
//!    STM32H747 is 100X faster")
//!  * weight reloading overhead is a visible fraction of total time on the
//!    16-bit system and "almost invisible" on the 32-bit one (Fig. 11)

/// Where weights live when not resident in RAM.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExtMemory {
    /// External SPI FRAM (the custom MSP430 board's 2 MB expansion).
    SpiFram,
    /// On-package embedded flash (STM32H747, 2 MB).
    EFlash,
}

#[derive(Debug, Clone)]
pub struct Device {
    pub name: &'static str,
    pub bits: u32,
    pub freq_hz: f64,
    /// Average CPU cycles per multiply-accumulate (word-width dependent).
    pub cycles_per_mac: f64,
    /// Average CPU cycles per non-MAC activation element op (pool, relu...).
    pub cycles_per_elem: f64,
    /// Active power draw in watts while computing.
    pub active_power_w: f64,
    /// Usable RAM for weights + activation buffers, bytes.
    pub ram_bytes: usize,
    pub ext: ExtMemory,
    /// External memory read bandwidth, bytes/second.
    pub ext_read_bps: f64,
    /// Extra energy per byte read from external memory, joules.
    pub ext_energy_per_byte: f64,
}

impl Device {
    /// 16-bit TI MSP430FR5994 custom board (Table 1):
    /// 16 MHz, 8 KB SRAM (+ FRAM used as main memory for the network
    /// image), 512 KB + 2 MB external FRAM, 118 µA/MHz @ 3.0 V.
    pub fn msp430() -> Device {
        Device {
            name: "msp430fr5994",
            bits: 16,
            freq_hz: 16e6,
            // no pipelined MAC; 16-bit HW multiplier + load/store ≈ 4 cyc
            cycles_per_mac: 4.0,
            cycles_per_elem: 2.0,
            // 118 uA/MHz * 16 MHz * 3.0 V
            active_power_w: 118e-6 * 16.0 * 3.0,
            // static allocation budget for the common-arch image + buffers
            ram_bytes: 256 * 1024,
            ext: ExtMemory::SpiFram,
            // QSPI FRAM @ 40 MHz -> ~4 MB/s sustained. Calibration note:
            // Fig. 11a shows weight reload as a visible *minority* share
            // of Vanilla's total on the 16-bit board (were loads dominant,
            // the zero-load in-memory baselines would have beaten Antler,
            // contradicting Fig. 9) — 4 MB/s puts reload at ~40% of a
            // Vanilla round, matching the paper's breakdown shape.
            ext_read_bps: 4.0e6,
            ext_energy_per_byte: 15e-9,
        }
    }

    /// 32-bit STM32H747 (Cortex-M7 core, Table 1): 480 MHz, 1 MB SRAM,
    /// 2 MB eFlash, ~100 mA @ 3.3 V.
    pub fn stm32h747() -> Device {
        Device {
            name: "stm32h747",
            bits: 32,
            freq_hz: 480e6,
            // dual-issue M7 with SIMD MAC, but f32 path ≈ 1.2 cyc/MAC
            cycles_per_mac: 1.2,
            cycles_per_elem: 0.6,
            active_power_w: 0.100 * 3.3,
            ram_bytes: 1024 * 1024,
            ext: ExtMemory::EFlash,
            // memory-mapped (XIP) 64-bit eFlash behind the ART cache:
            // effectively GB/s-class — the paper's Fig. 11 shows the
            // 32-bit board's reload overhead as "almost invisible"
            ext_read_bps: 2.0e9,
            ext_energy_per_byte: 1e-9,
        }
    }

    pub fn by_name(name: &str) -> Option<Device> {
        match name {
            "msp430" | "msp430fr5994" | "16bit" => Some(Device::msp430()),
            "stm32" | "stm32h747" | "32bit" => Some(Device::stm32h747()),
            _ => None,
        }
    }

    /// Seconds to execute `macs` multiply-accumulates plus `elems`
    /// element-wise ops in RAM.
    pub fn exec_time(&self, macs: u64, elems: u64) -> f64 {
        (macs as f64 * self.cycles_per_mac + elems as f64 * self.cycles_per_elem)
            / self.freq_hz
    }

    /// Seconds to load `bytes` from external memory into RAM.
    pub fn load_time(&self, bytes: usize) -> f64 {
        bytes as f64 / self.ext_read_bps
    }

    /// Joules for a period of `secs` of active computation.
    pub fn exec_energy(&self, secs: f64) -> f64 {
        self.active_power_w * secs
    }

    /// Joules for loading `bytes` from external memory (bus active power
    /// plus per-byte access energy).
    pub fn load_energy(&self, bytes: usize) -> f64 {
        self.active_power_w * self.load_time(bytes)
            + self.ext_energy_per_byte * bytes as f64
    }
}

/// A cost sample split into the two components Fig. 11 reports.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Cost {
    pub exec_s: f64,
    pub load_s: f64,
    pub exec_j: f64,
    pub load_j: f64,
}

impl Cost {
    pub fn time(&self) -> f64 {
        self.exec_s + self.load_s
    }
    pub fn energy(&self) -> f64 {
        self.exec_j + self.load_j
    }
    pub fn add(&mut self, other: Cost) {
        self.exec_s += other.exec_s;
        self.load_s += other.load_s;
        self.exec_j += other.exec_j;
        self.load_j += other.load_j;
    }
    pub fn scaled(&self, k: f64) -> Cost {
        Cost {
            exec_s: self.exec_s * k,
            load_s: self.load_s * k,
            exec_j: self.exec_j * k,
            load_j: self.load_j * k,
        }
    }
}

impl Device {
    /// Cost of executing a compute region (MACs + element ops) in RAM.
    pub fn exec_cost(&self, macs: u64, elems: u64) -> Cost {
        let t = self.exec_time(macs, elems);
        Cost { exec_s: t, exec_j: self.exec_energy(t), ..Default::default() }
    }

    /// Cost of loading weight bytes from external memory.
    pub fn load_cost(&self, bytes: usize) -> Cost {
        Cost {
            load_s: self.load_time(bytes),
            load_j: self.load_energy(bytes),
            ..Default::default()
        }
    }

    /// Cost of a load whose transfer (partially) overlapped compute:
    /// the energy for every byte moved is still paid, but only the
    /// *visible* stall counts as load time. `stall_s == load_time(bytes)`
    /// recovers `load_cost`; `stall_s == 0` is a fully hidden prefetch.
    pub fn load_cost_stalled(&self, bytes: usize, stall_s: f64) -> Cost {
        Cost {
            load_s: stall_s,
            load_j: self.load_energy(bytes),
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_mac_ratio_near_100x() {
        let a = Device::msp430();
        let b = Device::stm32h747();
        let ratio = (a.cycles_per_mac / a.freq_hz) / (b.cycles_per_mac / b.freq_hz);
        assert!((50.0..200.0).contains(&ratio), "ratio {}", ratio);
    }

    #[test]
    fn switching_overhead_visible_only_on_16bit() {
        // Load a ~70 KB network image vs executing ~500 K MACs — the paper's
        // Fig. 11 shape: reload cost is a significant share on MSP430 and
        // negligible on STM32.
        let bytes = 70 * 1024;
        let macs = 500_000;
        for (dev, visible) in
            [(Device::msp430(), true), (Device::stm32h747(), false)]
        {
            let load = dev.load_time(bytes);
            let exec = dev.exec_time(macs, 0);
            let share = load / (load + exec);
            if visible {
                assert!(share > 0.08, "{} share {}", dev.name, share);
            } else {
                assert!(share < 0.05, "{} share {}", dev.name, share);
            }
        }
    }

    #[test]
    fn energy_positive_and_monotone() {
        let d = Device::msp430();
        assert!(d.load_energy(1000) > 0.0);
        assert!(d.load_energy(2000) > d.load_energy(1000));
        assert!(d.exec_energy(0.5) > d.exec_energy(0.1));
    }

    #[test]
    fn cost_accumulates() {
        let d = Device::stm32h747();
        let mut c = d.exec_cost(1_000_000, 1000);
        c.add(d.load_cost(4096));
        assert!(c.time() > 0.0 && c.energy() > 0.0);
        assert!((c.time() - (c.exec_s + c.load_s)).abs() < 1e-15);
    }

    #[test]
    fn stalled_load_pays_full_energy_partial_time() {
        let d = Device::msp430();
        let bytes = 8192;
        let full = d.load_cost(bytes);
        let hidden = d.load_cost_stalled(bytes, 0.0);
        let partial = d.load_cost_stalled(bytes, full.load_s / 2.0);
        assert_eq!(hidden.load_j, full.load_j);
        assert_eq!(hidden.load_s, 0.0);
        assert_eq!(partial.load_j, full.load_j);
        assert!((partial.load_s - full.load_s / 2.0).abs() < 1e-15);
        // stall == load_time recovers the flat model exactly
        assert_eq!(d.load_cost_stalled(bytes, full.load_s), full);
    }

    #[test]
    fn by_name_aliases() {
        assert_eq!(Device::by_name("16bit").unwrap().name, "msp430fr5994");
        assert_eq!(Device::by_name("stm32").unwrap().name, "stm32h747");
        assert!(Device::by_name("esp32").is_none());
    }
}
