//! Minimal concurrency substrate (the offline mirror has no tokio):
//! a fixed thread pool with a shared injector queue, plus `parallel_map`
//! / `try_parallel_map` helpers used by the enumeration sweeps and the
//! serving coordinator. Panicking jobs are contained per item — they
//! never take a pool worker down with them.

pub mod pool;

pub use pool::{parallel_map, try_parallel_map, ThreadPool};
