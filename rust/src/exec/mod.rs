//! Minimal concurrency substrate (the offline mirror has no tokio):
//! a fixed thread pool with a shared injector queue, plus a `parallel_map`
//! helper used by the enumeration sweeps and the serving coordinator.

pub mod pool;

pub use pool::{parallel_map, ThreadPool};
