//! Fixed-size thread pool over a `Mutex<VecDeque>` injector queue with a
//! condvar. Deliberately simple: the coordinator's workloads are coarse
//! (one job = one inference or one graph scored), so queue contention is
//! negligible; see EXPERIMENTS.md §Perf for measurements.
//!
//! Concurrency primitives come from the `crate::sync` facade, so the
//! shutdown protocol (shutdown flag + notify_all + join) is exhaustively
//! model-checked by `loom_tests` below (`./ci.sh --loom`).

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::{
    lock_unpoisoned, thread, wait_unpoisoned, Arc, Condvar, Mutex,
};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = Arc::clone(&shared);
                // lint:allow(panic) — OS thread-spawn failure at pool
                // construction is unrecoverable by design; every caller
                // would abort anyway
                thread::spawn_named(format!("antler-worker-{i}"), move || {
                    worker_loop(sh)
                })
                .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut q = lock_unpoisoned(&self.shared.queue);
        q.push_back(Box::new(f));
        drop(q);
        self.shared.cv.notify_one();
    }

    /// Number of jobs waiting (not including running ones).
    pub fn backlog(&self) -> usize {
        lock_unpoisoned(&self.shared.queue).len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = lock_unpoisoned(&sh.queue);
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                // loom-verified: loom_pool_shutdown_joins_parked_workers —
                // execute() and Drop both mutate under this mutex before
                // notifying, so a parked worker cannot miss either wake
                q = wait_unpoisoned(&sh.cv, q);
            }
        };
        // contain a panicking job: letting it unwind through here would
        // kill this worker thread and silently shrink the pool for every
        // later submitter. The job's owner observes the failure through
        // its own channel/slot going unfilled (see `parallel_map`, which
        // records the payload per item).
        let _ = catch_unwind(AssertUnwindSafe(job));
    }
}

/// Map `f` over `items` on `threads` threads, preserving order, with the
/// outcome of every item surfaced individually: `Ok(result)` or
/// `Err(panic payload)`. A panicking item neither kills its worker (see
/// `worker_loop`) nor aborts the map — every other item still completes.
/// Falls back to a sequential loop for a single thread (avoids overhead).
pub fn try_parallel_map<T, R, F>(
    threads: usize,
    items: Vec<T>,
    f: F,
) -> Vec<thread::Result<R>>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    if threads <= 1 || items.len() <= 1 {
        return items
            .into_iter()
            .map(|item| catch_unwind(AssertUnwindSafe(|| f(item))))
            .collect();
    }
    let f = Arc::new(f);
    let n = items.len();
    let slots: Arc<Mutex<Vec<Option<thread::Result<R>>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    let pool = ThreadPool::new(threads.min(n));
    let (tx, rx) = crate::sync::mpsc::channel::<()>();
    for (i, item) in items.into_iter().enumerate() {
        let f = Arc::clone(&f);
        let slots = Arc::clone(&slots);
        let tx = tx.clone();
        pool.execute(move || {
            // record the item's outcome — value or panic payload — before
            // signalling, so the collector below never deadlocks on a
            // panicked item (the old code hung its misleading
            // `expect("worker panicked")` on exactly that)
            let r = catch_unwind(AssertUnwindSafe(|| f(item)));
            lock_unpoisoned(&slots)[i] = Some(r);
            let _ = tx.send(());
        });
    }
    drop(tx);
    for _ in 0..n {
        // a recv error means every worker vanished before signalling —
        // impossible while worker_loop contains panics, but degrade to
        // per-slot surfacing below rather than panicking the caller
        if rx.recv().is_err() {
            break;
        }
    }
    // every slot was written before its signal was sent, so after n
    // signals the results are complete. Take them under the lock —
    // Arc::try_unwrap would race with the last worker's Arc clone, which
    // drops only after its send, and panic spuriously.
    let results = std::mem::take(&mut *lock_unpoisoned(&slots));
    results
        .into_iter()
        .map(|o| match o {
            Some(r) => r,
            None => Err(Box::new("pool worker vanished before recording")
                as Box<dyn std::any::Any + Send>),
        })
        .collect()
}

/// Map `f` over `items` on `threads` threads, preserving order. If any
/// item panicked, the first panic is re-raised on the caller's thread —
/// after every other item has completed and with the pool left healthy.
pub fn parallel_map<T, R, F>(threads: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    try_parallel_map(threads, items, f)
        .into_iter()
        .map(|r| match r {
            Ok(v) => v,
            Err(payload) => resume_unwind(payload),
        })
        .collect()
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::sync::atomic::AtomicUsize;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = crate::sync::mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let v: Vec<usize> = (0..64).collect();
        let out = parallel_map(4, v, |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_thread() {
        let out = parallel_map(1, vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }

    #[test]
    fn pool_survives_panicking_job() {
        // a single worker: the panicking job and the follow-up MUST run
        // on the same thread, proving containment (not a respawn)
        let pool = ThreadPool::new(1);
        let (tx, rx) = crate::sync::mpsc::channel();
        pool.execute(|| panic!("contained"));
        pool.execute(move || {
            let _ = tx.send(42);
        });
        assert_eq!(
            rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap(),
            42,
            "pool lost its worker to a panicking job"
        );
    }

    #[test]
    fn try_parallel_map_surfaces_panic_per_item() {
        let out = try_parallel_map(4, vec![1usize, 2, 3, 4], |x| {
            if x == 3 {
                panic!("item three");
            }
            x * 10
        });
        assert_eq!(out.len(), 4);
        assert_eq!(*out[0].as_ref().unwrap(), 10);
        assert_eq!(*out[1].as_ref().unwrap(), 20);
        assert!(out[2].is_err(), "panicking item must surface as Err");
        assert_eq!(*out[3].as_ref().unwrap(), 40);
    }

    #[test]
    fn parallel_map_completes_other_items_despite_panic() {
        let done = Arc::new(AtomicUsize::new(0));
        let d = Arc::clone(&done);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            parallel_map(4, (0..16usize).collect::<Vec<_>>(), move |x| {
                if x == 7 {
                    panic!("boom");
                }
                d.fetch_add(1, Ordering::SeqCst);
                x
            })
        }));
        assert!(caught.is_err(), "the panic must reach the caller");
        // every non-panicking item still ran to completion
        assert_eq!(done.load(Ordering::SeqCst), 15);
    }

    #[test]
    fn try_parallel_map_sequential_path_catches_too() {
        let out = try_parallel_map(1, vec![0usize, 1], |x| {
            if x == 0 {
                panic!("seq");
            }
            x
        });
        assert!(out[0].is_err());
        assert_eq!(*out[1].as_ref().unwrap(), 1);
    }
}

/// Exhaustive model check of the pool shutdown protocol (`./ci.sh
/// --loom`): a worker parked in `wait_unpoisoned` must see both wake
/// reasons — a job arriving and shutdown — under EVERY interleaving of
/// `execute`, the worker's own pop/park, and `Drop`. A lost wakeup here
/// deadlocks `Drop`'s join, which loom reports as a hung model.
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;
    use crate::sync::atomic::AtomicUsize;

    #[test]
    fn loom_pool_shutdown_joins_parked_workers() {
        let mut b = loom::model::Builder::new();
        b.preemption_bound = Some(3);
        b.check(|| {
            let pool = ThreadPool::new(2);
            let ran = Arc::new(AtomicUsize::new(0));
            let r = Arc::clone(&ran);
            pool.execute(move || {
                r.fetch_add(1, Ordering::SeqCst);
            });
            // Drop races shutdown against workers that may be parked
            // pre-notify, mid-pop, or still spawning
            drop(pool);
            assert_eq!(ran.load(Ordering::SeqCst), 1, "job lost at shutdown");
        });
    }

    #[test]
    fn loom_pool_executes_from_two_submitters() {
        let mut b = loom::model::Builder::new();
        b.preemption_bound = Some(2);
        b.check(|| {
            let pool = ThreadPool::new(1);
            let ran = Arc::new(AtomicUsize::new(0));
            let (r1, r2) = (Arc::clone(&ran), Arc::clone(&ran));
            let pool = Arc::new(pool);
            let p2 = Arc::clone(&pool);
            let submitter = thread::spawn(move || {
                p2.execute(move || {
                    r2.fetch_add(1, Ordering::SeqCst);
                });
            });
            pool.execute(move || {
                r1.fetch_add(1, Ordering::SeqCst);
            });
            submitter.join().unwrap();
            // dropping the last Arc joins the worker after both jobs
            drop(pool);
            assert_eq!(ran.load(Ordering::SeqCst), 2);
        });
    }
}
