//! Fixed-size thread pool over a `Mutex<VecDeque>` injector queue with a
//! condvar. Deliberately simple: the coordinator's workloads are coarse
//! (one job = one inference or one graph scored), so queue contention is
//! negligible; see EXPERIMENTS.md §Perf for measurements.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("antler-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(Box::new(f));
        drop(q);
        self.shared.cv.notify_one();
    }

    /// Number of jobs waiting (not including running ones).
    pub fn backlog(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        job();
    }
}

/// Map `f` over `items` on `threads` threads, preserving order.
/// Falls back to a sequential loop for a single thread (avoids overhead).
pub fn parallel_map<T, R, F>(threads: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    if threads <= 1 || items.len() <= 1 {
        return items.into_iter().map(f).collect();
    }
    let f = Arc::new(f);
    let n = items.len();
    let slots: Arc<Mutex<Vec<Option<R>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    let pool = ThreadPool::new(threads.min(n));
    let (tx, rx) = std::sync::mpsc::channel::<()>();
    for (i, item) in items.into_iter().enumerate() {
        let f = Arc::clone(&f);
        let slots = Arc::clone(&slots);
        let tx = tx.clone();
        pool.execute(move || {
            let r = f(item);
            slots.lock().unwrap()[i] = Some(r);
            let _ = tx.send(());
        });
    }
    drop(tx);
    for _ in 0..n {
        rx.recv().expect("worker panicked");
    }
    Arc::try_unwrap(slots)
        .ok()
        .expect("slots still shared")
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("missing result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = std::sync::mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let v: Vec<usize> = (0..64).collect();
        let out = parallel_map(4, v, |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_thread() {
        let out = parallel_map(1, vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }
}
