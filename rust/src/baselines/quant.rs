//! Weight-transform mechanisms behind the baseline systems:
//!  * NWV (Neural Weight Virtualization, [32]) — pack every task's
//!    weights into a fixed RAM budget by k-means page merging: weight
//!    pages across tasks that land in the same cluster share one
//!    physical page.
//!  * NWS (Weight Separation, [33]) — keep the top-|magnitude| fraction
//!    of weights task-private (in flash), merge the rest in RAM.
//!  * YONO ([27]) — product-quantization codebook compression: weights
//!    split into sub-vectors, k-means to a small codebook, stored as
//!    1-byte indices + the codebook.
//!
//! All transforms consume per-task flat parameter lists (biases are kept
//! exact everywhere — they are tiny and every scheme stores them raw).

use crate::model::Tensor;
use crate::util::rng::Pcg32;

/// Plain k-means on `points` (row-major, `dim` wide). Returns (centroids,
/// assignment). Deterministic from `rng`; `iters` Lloyd steps.
pub fn kmeans(
    points: &[f32],
    dim: usize,
    k: usize,
    iters: usize,
    rng: &mut Pcg32,
) -> (Vec<f32>, Vec<usize>) {
    let n = points.len() / dim;
    assert!(n > 0 && k > 0);
    let k = k.min(n);
    // init: random distinct points
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let mut centroids: Vec<f32> = Vec::with_capacity(k * dim);
    for &i in idx.iter().take(k) {
        centroids.extend_from_slice(&points[i * dim..(i + 1) * dim]);
    }
    let mut assign = vec![0usize; n];
    for _ in 0..iters {
        // assign
        for i in 0..n {
            let p = &points[i * dim..(i + 1) * dim];
            let mut best = (f32::INFINITY, 0usize);
            for c in 0..k {
                let q = &centroids[c * dim..(c + 1) * dim];
                let mut d = 0.0f32;
                for j in 0..dim {
                    let t = p[j] - q[j];
                    d += t * t;
                }
                if d < best.0 {
                    best = (d, c);
                }
            }
            assign[i] = best.1;
        }
        // update
        let mut sums = vec![0.0f32; k * dim];
        let mut counts = vec![0usize; k];
        for i in 0..n {
            let c = assign[i];
            counts[c] += 1;
            for j in 0..dim {
                sums[c * dim + j] += points[i * dim + j];
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for j in 0..dim {
                    centroids[c * dim + j] = sums[c * dim + j] / counts[c] as f32;
                }
            }
        }
    }
    (centroids, assign)
}

fn is_weight(t: &Tensor) -> bool {
    t.rank() > 1
}

fn flat_weights(params: &[Vec<Tensor>]) -> Vec<f32> {
    params
        .iter()
        .flat_map(|p| p.iter())
        .filter(|t| is_weight(t))
        .flat_map(|t| t.data.iter().copied())
        .collect()
}

fn scatter_weights(params: &mut [Vec<Tensor>], flat: &[f32]) {
    let mut off = 0;
    for p in params.iter_mut() {
        for t in p.iter_mut() {
            if is_weight(t) {
                let len = t.data.len();
                t.data.copy_from_slice(&flat[off..off + len]);
                off += len;
            }
        }
    }
    assert_eq!(off, flat.len());
}

fn bias_bytes(params: &[Vec<Tensor>]) -> usize {
    params
        .iter()
        .flat_map(|p| p.iter())
        .filter(|t| !is_weight(t))
        .map(|t| t.bytes())
        .sum()
}

/// Result of a baseline weight transform.
#[derive(Debug, Clone)]
pub struct Packed {
    /// Transformed per-task parameter lists (for accuracy evaluation).
    pub params: Vec<Vec<Tensor>>,
    /// Bytes resident in RAM.
    pub ram_bytes: usize,
    /// Bytes that stay in external memory and reload per task switch.
    pub ext_bytes_per_task: usize,
}

/// NWV: merge weight pages across all tasks into `budget_bytes` of RAM.
pub fn nwv_pack(
    params: &[Vec<Tensor>],
    budget_bytes: usize,
    page: usize,
    rng: &mut Pcg32,
) -> Packed {
    let mut out = params.to_vec();
    let flat = flat_weights(params);
    let n_pages = flat.len().div_ceil(page);
    let bias = bias_bytes(params);
    let budget_pages = budget_bytes.saturating_sub(bias) / (page * 4);
    let k = budget_pages.clamp(1, n_pages);
    // pad to page multiple
    let mut padded = flat.clone();
    padded.resize(n_pages * page, 0.0);
    let (centroids, assign) = kmeans(&padded, page, k, 6, rng);
    let mut merged = vec![0.0f32; padded.len()];
    for (i, &c) in assign.iter().enumerate() {
        merged[i * page..(i + 1) * page]
            .copy_from_slice(&centroids[c * page..(c + 1) * page]);
    }
    merged.truncate(flat.len());
    scatter_weights(&mut out, &merged);
    let unique = assign
        .iter()
        .collect::<std::collections::HashSet<_>>()
        .len();
    Packed {
        params: out,
        ram_bytes: unique * page * 4 + bias,
        ext_bytes_per_task: 0,
    }
}

/// NWS: top `keep_frac` |weights| stay exact (flash-resident, reloaded per
/// task), the rest are NWV-merged into RAM.
pub fn nws_pack(
    params: &[Vec<Tensor>],
    budget_bytes: usize,
    keep_frac: f64,
    page: usize,
    rng: &mut Pcg32,
) -> Packed {
    let flat = flat_weights(params);
    let n = flat.len();
    let keep = ((n as f64) * keep_frac) as usize;
    // magnitude threshold
    let mut mags: Vec<f32> = flat.iter().map(|x| x.abs()).collect();
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let thresh = if keep == 0 { f32::INFINITY } else { mags[keep - 1] };
    // merge only the small weights
    let small: Vec<f32> = flat
        .iter()
        .map(|&x| if x.abs() >= thresh { 0.0 } else { x })
        .collect();
    let n_pages = small.len().div_ceil(page);
    let bias = bias_bytes(params);
    let budget_pages = budget_bytes.saturating_sub(bias) / (page * 4);
    let k = budget_pages.clamp(1, n_pages);
    let mut padded = small.clone();
    padded.resize(n_pages * page, 0.0);
    let (centroids, assign) = kmeans(&padded, page, k, 6, rng);
    let mut merged = vec![0.0f32; padded.len()];
    for (i, &c) in assign.iter().enumerate() {
        merged[i * page..(i + 1) * page]
            .copy_from_slice(&centroids[c * page..(c + 1) * page]);
    }
    merged.truncate(n);
    // exact large weights override the merged values
    let mut final_flat = merged;
    let mut kept = 0usize;
    for (i, &x) in flat.iter().enumerate() {
        if x.abs() >= thresh {
            final_flat[i] = x;
            kept += 1;
        }
    }
    let mut out = params.to_vec();
    scatter_weights(&mut out, &final_flat);
    let unique = assign
        .iter()
        .collect::<std::collections::HashSet<_>>()
        .len();
    Packed {
        params: out,
        ram_bytes: unique * page * 4 + bias,
        ext_bytes_per_task: kept * 4 / params.len().max(1),
    }
}

/// YONO: product quantization with `dim`-wide sub-vectors and a `k`-entry
/// codebook (k ≤ 256 so indices are one byte).
pub fn yono_pack(params: &[Vec<Tensor>], dim: usize, k: usize, rng: &mut Pcg32) -> Packed {
    assert!(k <= 256, "one-byte codebook indices");
    let flat = flat_weights(params);
    let n_sub = flat.len().div_ceil(dim);
    let mut padded = flat.clone();
    padded.resize(n_sub * dim, 0.0);
    let (centroids, assign) = kmeans(&padded, dim, k, 8, rng);
    let mut quant = vec![0.0f32; padded.len()];
    for (i, &c) in assign.iter().enumerate() {
        quant[i * dim..(i + 1) * dim]
            .copy_from_slice(&centroids[c * dim..(c + 1) * dim]);
    }
    quant.truncate(flat.len());
    let mut out = params.to_vec();
    scatter_weights(&mut out, &quant);
    Packed {
        params: out,
        ram_bytes: k * dim * 4 + n_sub + bias_bytes(params),
        ext_bytes_per_task: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_params(tasks: usize, seed: u64) -> Vec<Vec<Tensor>> {
        let mut rng = Pcg32::seed(seed);
        (0..tasks)
            .map(|_| {
                vec![
                    Tensor::he_init(vec![16, 8], &mut rng),
                    Tensor::zeros(vec![8]),
                    Tensor::he_init(vec![8, 2], &mut rng),
                    Tensor::zeros(vec![2]),
                ]
            })
            .collect()
    }

    fn raw_bytes(p: &[Vec<Tensor>]) -> usize {
        p.iter().flat_map(|t| t.iter()).map(|t| t.bytes()).sum()
    }

    #[test]
    fn kmeans_clusters_separated_points() {
        let mut rng = Pcg32::seed(1);
        let mut pts = Vec::new();
        for i in 0..40 {
            let base = if i % 2 == 0 { 0.0 } else { 10.0 };
            pts.extend([base + rng.f32() * 0.1, base - rng.f32() * 0.1]);
        }
        let (_, assign) = kmeans(&pts, 2, 2, 5, &mut rng);
        for i in (0..40).step_by(2) {
            assert_eq!(assign[i], assign[0]);
            assert_eq!(assign[i + 1], assign[1]);
        }
        assert_ne!(assign[0], assign[1]);
    }

    #[test]
    fn nwv_fits_budget_and_degrades_with_pressure() {
        let params = toy_params(4, 2);
        let mut rng = Pcg32::seed(3);
        let raw = raw_bytes(&params);
        let tight = nwv_pack(&params, raw / 8, 16, &mut rng);
        let loose = nwv_pack(&params, raw, 16, &mut Pcg32::seed(3));
        assert!(tight.ram_bytes <= raw / 8 + 256);
        assert!(tight.ram_bytes < loose.ram_bytes);
        // distortion grows as the budget shrinks
        let dist = |packed: &Packed| -> f64 {
            packed
                .params
                .iter()
                .zip(&params)
                .flat_map(|(a, b)| a.iter().zip(b.iter()))
                .map(|(a, b)| a.l2_dist(b))
                .sum()
        };
        assert!(dist(&tight) > dist(&loose));
    }

    #[test]
    fn nws_keeps_large_weights_exact() {
        let params = toy_params(3, 4);
        let mut rng = Pcg32::seed(5);
        let raw = raw_bytes(&params);
        let packed = nws_pack(&params, raw / 10, 0.07, 16, &mut rng);
        // the largest-magnitude weight must be preserved exactly
        let (mut max_val, mut loc) = (0.0f32, (0, 0, 0));
        for (t, p) in params.iter().enumerate() {
            for (j, tensor) in p.iter().enumerate() {
                for (i, &v) in tensor.data.iter().enumerate() {
                    if v.abs() > max_val {
                        max_val = v.abs();
                        loc = (t, j, i);
                    }
                }
            }
        }
        let (t, j, i) = loc;
        assert_eq!(packed.params[t][j].data[i], params[t][j].data[i]);
        assert!(packed.ext_bytes_per_task > 0);
    }

    #[test]
    fn yono_codebook_compresses_hard() {
        // larger toy nets: codebook overhead must amortize
        let mut rng0 = Pcg32::seed(60);
        let params: Vec<Vec<Tensor>> = (0..6)
            .map(|_| {
                vec![
                    Tensor::he_init(vec![64, 32], &mut rng0),
                    Tensor::zeros(vec![32]),
                    Tensor::he_init(vec![32, 8], &mut rng0),
                    Tensor::zeros(vec![8]),
                ]
            })
            .collect();
        let mut rng = Pcg32::seed(7);
        let raw = raw_bytes(&params);
        let packed = yono_pack(&params, 8, 64, &mut rng);
        assert!(packed.ram_bytes < raw / 4, "{} vs {}", packed.ram_bytes, raw);
        assert_eq!(packed.ext_bytes_per_task, 0);
        // quantized weights remain finite and close-ish
        for (a, b) in packed.params.iter().flatten().zip(params.iter().flatten()) {
            assert!(a.data.iter().all(|v| v.is_finite()));
            assert_eq!(a.shape, b.shape);
        }
    }

    #[test]
    fn transforms_preserve_bias_exactly() {
        let mut params = toy_params(2, 8);
        params[0][1].data.iter_mut().for_each(|v| *v = 0.5);
        let mut rng = Pcg32::seed(9);
        let packed = nwv_pack(&params, 512, 16, &mut rng);
        assert!(packed.params[0][1].data.iter().all(|&v| v == 0.5));
    }
}
