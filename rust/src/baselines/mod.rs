//! The four comparison systems of §6 and their cost/memory/accuracy
//! models, all sharing the same `ExecSim` semantics so differences come
//! from the *mechanisms*, not the accounting:
//!
//!  * Vanilla — independently trained classifiers, run sequentially,
//!    full weight reload per task visit (disjoint graph, cold slots).
//!  * NWV [32] — everything packed into RAM via page merging: zero
//!    switching cost, but every task still executes its full network and
//!    packing pressure costs accuracy.
//!  * NWS [33] — NWV plus the top-7% weights task-private in flash:
//!    small reload per switch, accuracy ≈ Vanilla.
//!  * YONO [27] — codebook-compressed, all-in-RAM: zero switching cost,
//!    full execution per task.
//!  * Antler — task graph + optimal order + activation caching.

pub mod quant;

pub use quant::{kmeans, nws_pack, nwv_pack, yono_pack, Packed};

use crate::device::{Cost, Device};
use crate::memory::ExecSim;
use crate::model::ArchSpec;
use crate::taskgraph::TaskGraph;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SystemKind {
    Vanilla,
    Antler,
    Nwv,
    Nws,
    Yono,
}

impl SystemKind {
    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::Vanilla => "Vanilla",
            SystemKind::Antler => "Antler",
            SystemKind::Nwv => "NWV",
            SystemKind::Nws => "NWS",
            SystemKind::Yono => "YONO",
        }
    }

    pub fn all() -> [SystemKind; 5] {
        [
            SystemKind::Vanilla,
            SystemKind::Antler,
            SystemKind::Nwv,
            SystemKind::Nws,
            SystemKind::Yono,
        ]
    }
}

/// Per-round cost of a system on a device (Figures 9–11). `antler_graph`
/// and `antler_order` are the selected task graph and its optimal order;
/// `nws_ext_bytes` is NWS's per-task flash-private weight footprint.
pub struct CostInputs<'a> {
    pub device: &'a Device,
    pub arch: &'a ArchSpec,
    pub ncls: &'a [usize],
    pub antler_graph: &'a TaskGraph,
    pub antler_order: &'a [usize],
    pub nws_ext_bytes_per_task: usize,
}

/// Steady-state per-round (one input sample, all tasks) cost of `system`.
pub fn round_cost(system: SystemKind, inp: &CostInputs) -> Cost {
    let n = inp.ncls.len();
    let bounds = inp.antler_graph.bounds.clone();
    match system {
        SystemKind::Antler => {
            let mut sim =
                ExecSim::new(inp.device, inp.arch, inp.antler_graph, inp.ncls);
            sim.steady_round_cost(inp.antler_order, 4)
        }
        SystemKind::Vanilla => {
            let g = TaskGraph::disjoint(n, bounds);
            let order: Vec<usize> = (0..n).collect();
            let mut sim = ExecSim::new(inp.device, inp.arch, &g, inp.ncls);
            sim.steady_round_cost(&order, 4)
        }
        SystemKind::Nwv | SystemKind::Yono => {
            // full in-memory execution of every network, zero loads
            let g = TaskGraph::disjoint(n, bounds);
            let order: Vec<usize> = (0..n).collect();
            let mut sim = ExecSim::new(inp.device, inp.arch, &g, inp.ncls);
            sim.all_resident = true;
            sim.steady_round_cost(&order, 4)
        }
        SystemKind::Nws => {
            let g = TaskGraph::disjoint(n, bounds);
            let order: Vec<usize> = (0..n).collect();
            let mut sim = ExecSim::new(inp.device, inp.arch, &g, inp.ncls);
            sim.all_resident = true;
            let mut c = sim.steady_round_cost(&order, 4);
            // per task visit: reload its private high-significance weights
            for _ in 0..n {
                c.add(inp.device.load_cost(inp.nws_ext_bytes_per_task));
            }
            c
        }
    }
}

/// Total weight storage (Table 4 / Table 5). For the in-memory systems
/// this is the packed RAM footprint; for Vanilla/Antler it is the full
/// stored model.
pub fn memory_bytes(
    system: SystemKind,
    arch: &ArchSpec,
    ncls: &[usize],
    antler_graph: &TaskGraph,
    packed_ram: Option<usize>,
    nws_ext_total: usize,
) -> usize {
    let n = ncls.len();
    match system {
        SystemKind::Vanilla => {
            ncls.iter().map(|&c| arch.total_params(c) * 4).sum()
        }
        SystemKind::Antler => antler_graph.model_bytes(arch, ncls),
        SystemKind::Nwv | SystemKind::Yono => packed_ram.unwrap_or_else(|| {
            // fallback heuristic when no trained weights are available:
            // a single network image plus per-task heads
            arch.total_params(2) * 4 + n * 256
        }),
        SystemKind::Nws => {
            packed_ram.unwrap_or_else(|| arch.total_params(2) * 4) + nws_ext_total
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskgraph::Partition;

    const TINY: &str = r#"{
      "version": 1,
      "archs": {"cnn5": {"input": [16,16,1], "ncls": [2],
        "layers": [
          {"kind":"conv_pool","cfg":{"kh":3,"kw":3,"cin":1,"cout":8},"in":[16,16,1],"out":[8,8,8],"macs_per_sample":18432},
          {"kind":"conv_pool","cfg":{"kh":3,"kw":3,"cin":8,"cout":16},"in":[8,8,8],"out":[4,4,16],"macs_per_sample":73728},
          {"kind":"dense","cfg":{"din":256,"dout":64},"in":[4,4,16],"out":[64],"macs_per_sample":16384},
          {"kind":"dense","cfg":{"din":64,"dout":32},"in":[64],"out":[32],"macs_per_sample":2048},
          {"kind":"logits","cfg":{"din":32,"dout":0},"in":[32],"out":[2],"macs_per_sample":64}
        ]}},
      "entries": []
    }"#;

    fn arch() -> ArchSpec {
        crate::model::manifest::Manifest::from_json(
            std::path::PathBuf::from("/tmp"),
            &crate::util::json::Json::parse(TINY).unwrap(),
        )
        .unwrap()
        .arch("cnn5")
        .unwrap()
        .clone()
    }

    fn antler_graph(n: usize) -> TaskGraph {
        // all share segments 0-1, split into two groups at segment 2
        let half: Vec<usize> = (0..n).map(|t| (t >= n / 2) as usize).collect();
        TaskGraph::new(
            n,
            vec![1, 3, 4],
            vec![
                Partition::one_group(n),
                Partition::one_group(n),
                Partition(half),
                Partition::singletons(n),
            ],
        )
        .unwrap()
    }

    fn inputs<'a>(
        device: &'a Device,
        arch: &'a ArchSpec,
        ncls: &'a [usize],
        g: &'a TaskGraph,
        order: &'a [usize],
    ) -> CostInputs<'a> {
        CostInputs {
            device,
            arch,
            ncls,
            antler_graph: g,
            antler_order: order,
            nws_ext_bytes_per_task: 5 * 1024,
        }
    }

    #[test]
    fn antler_beats_all_baselines_on_16bit() {
        let device = Device::msp430();
        let arch = arch();
        let ncls = vec![2usize; 6];
        let g = antler_graph(6);
        let order: Vec<usize> = (0..6).collect();
        let inp = inputs(&device, &arch, &ncls, &g, &order);
        let antler = round_cost(SystemKind::Antler, &inp).time();
        for sys in [SystemKind::Vanilla, SystemKind::Nwv, SystemKind::Nws, SystemKind::Yono] {
            let t = round_cost(sys, &inp).time();
            assert!(antler < t, "{}: antler {} vs {}", sys.name(), antler, t);
        }
    }

    #[test]
    fn antler_speedup_increases_with_sharing() {
        // the paper's 2.3x–4.6x band is checked end-to-end in the fig9
        // bench with the *selected* graphs; here: monotonicity + a sane
        // upper bound for a deliberately extreme (deeply shared) graph
        let device = Device::msp430();
        let arch = arch();
        let ncls = vec![2usize; 10];
        let deep = antler_graph(10);
        let shallow = TaskGraph::new(
            10,
            vec![1, 3, 4],
            vec![
                Partition::one_group(10),
                Partition::singletons(10),
                Partition::singletons(10),
                Partition::singletons(10),
            ],
        )
        .unwrap();
        let order: Vec<usize> = (0..10).collect();
        let vanilla =
            round_cost(SystemKind::Vanilla, &inputs(&device, &arch, &ncls, &deep, &order))
                .time();
        let t_deep =
            round_cost(SystemKind::Antler, &inputs(&device, &arch, &ncls, &deep, &order))
                .time();
        let t_shallow = round_cost(
            SystemKind::Antler,
            &inputs(&device, &arch, &ncls, &shallow, &order),
        )
        .time();
        assert!(t_deep < t_shallow, "{t_deep} vs {t_shallow}");
        assert!(vanilla / t_shallow > 1.0);
        assert!(vanilla / t_deep < 40.0);
    }

    #[test]
    fn in_memory_systems_have_zero_load() {
        let device = Device::msp430();
        let arch = arch();
        let ncls = vec![2usize; 4];
        let g = antler_graph(4);
        let order: Vec<usize> = (0..4).collect();
        let inp = inputs(&device, &arch, &ncls, &g, &order);
        assert_eq!(round_cost(SystemKind::Nwv, &inp).load_s, 0.0);
        assert_eq!(round_cost(SystemKind::Yono, &inp).load_s, 0.0);
        assert!(round_cost(SystemKind::Nws, &inp).load_s > 0.0);
        assert!(round_cost(SystemKind::Vanilla, &inp).load_s > 0.0);
    }

    #[test]
    fn memory_ordering_matches_table4() {
        // Table 4: Vanilla > Antler > NWS > NWV >= YONO
        let arch = arch();
        let ncls = vec![2usize; 10];
        let g = antler_graph(10);
        let vanilla =
            memory_bytes(SystemKind::Vanilla, &arch, &ncls, &g, None, 0);
        let antler = memory_bytes(SystemKind::Antler, &arch, &ncls, &g, None, 0);
        let nws =
            memory_bytes(SystemKind::Nws, &arch, &ncls, &g, Some(50_000), 25_000);
        let nwv = memory_bytes(SystemKind::Nwv, &arch, &ncls, &g, Some(55_000), 0);
        let yono = memory_bytes(SystemKind::Yono, &arch, &ncls, &g, Some(45_000), 0);
        assert!(vanilla > antler, "{vanilla} vs {antler}");
        assert!(antler > nws);
        assert!(nws > nwv);
        assert!(nwv > yono);
    }
}
