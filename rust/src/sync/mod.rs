//! Sync facade: the ONE place the crate touches `std::sync` /
//! `std::thread` primitives. Normal builds re-export std; under
//! `--cfg loom` (the model-checking lane, `./ci.sh --loom`) the same
//! names resolve to [`loom`](https://docs.rs/loom) equivalents, so the
//! exact production protocols — the steal queue's wake/close, the
//! `CloseOnDrop` guard, dead-shard absorption, the ingest shutdown
//! barrier, thread-pool shutdown, the tier's prefetch-hint mailbox —
//! are *exhaustively* interleaved by the `loom_*` tests instead of
//! sampled by stress tests.
//!
//! The custom lint (`tools/lint.sh`, run by `./ci.sh`) bans raw
//! `std::sync`/`std::thread` everywhere else in `src/`, so new
//! concurrency cannot silently bypass the model checker.
//!
//! Deliberate scope limits, so the facade stays honest:
//!
//! * **`mpsc` is re-exported from std even under loom** (loom has no
//!   channel model). Channels are used for result *collection* (every
//!   sender is dropped before the receiver is drained — plain
//!   join-style hand-off), for the round-robin baseline's per-shard
//!   queues, and for the network listener's acceptor→producer
//!   connection hand-off (`coordinator::net`, where dropping the
//!   senders IS the shutdown signal — CONCURRENCY.md §Listener
//!   shutdown); the load-bearing serving protocols (steal queue,
//!   ingest barrier, pool shutdown) are mutex+condvar+atomics and ARE
//!   loom-modeled.
//! * **`thread::scope` is re-exported from std even under loom** (loom
//!   models only `'static` spawns). The ingest barrier's loom test
//!   (`ingest::loom_tests`) therefore drives the real `produce()` loop
//!   from plain loom spawns and re-asserts the barrier's conservation
//!   contract after joining — same protocol, modeled spawn.
//! * Under loom, `thread::sleep` becomes `loom::thread::yield_now()`:
//!   loom has no clock, and every sleep in the serving path is a pacing
//!   knob, never a correctness mechanism (that is exactly what the loom
//!   suite proves — see CONCURRENCY.md).

#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Atomics: std in normal builds, loom's modeled atomics under
/// `--cfg loom` (loom explores the orderings, so a `Relaxed` that
/// needed to be `Acquire` fails the model, not production).
pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::{
        AtomicBool, AtomicIsize, AtomicU64, AtomicUsize, Ordering,
    };

    #[cfg(loom)]
    pub use loom::sync::atomic::{
        AtomicBool, AtomicIsize, AtomicU64, AtomicUsize, Ordering,
    };
}

/// Channels are std in every build — see the module docs for why they
/// are out of the loom model's scope.
pub mod mpsc {
    pub use std::sync::mpsc::*;
}

/// Threads: std spawn/sleep/scope normally; loom's modeled spawn under
/// `--cfg loom` (scope and sleep keep std/no-op semantics — module docs).
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::{
        scope, sleep, spawn, yield_now, JoinHandle, Result, Scope,
        ScopedJoinHandle,
    };

    #[cfg(loom)]
    pub use loom::thread::{spawn, yield_now, JoinHandle};
    #[cfg(loom)]
    pub use std::thread::{scope, Result, Scope, ScopedJoinHandle};

    /// loom has no clock: a sleep is modeled as a yield (sleeps in this
    /// crate pace load, they are never relied on for correctness).
    #[cfg(loom)]
    pub fn sleep(_d: std::time::Duration) {
        loom::thread::yield_now();
    }

    /// Spawn a named worker thread (loom ignores the name — its
    /// executions are identified by schedule, not thread name).
    #[cfg(not(loom))]
    pub fn spawn_named<F, T>(name: String, f: F) -> std::io::Result<JoinHandle<T>>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        std::thread::Builder::new().name(name).spawn(f)
    }

    #[cfg(loom)]
    pub fn spawn_named<F, T>(
        _name: String,
        f: F,
    ) -> std::io::Result<JoinHandle<T>>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        Ok(spawn(f))
    }
}

/// Lock, recovering from poisoning. The serving path's locks guard
/// plain counters and queues whose invariants are (re-)checked by the
/// `coordinator::audit` ledgers and the conservation asserts, so a
/// sibling's panic must not cascade into every thread that shares the
/// mutex — the pool already contains panicking jobs (`exec::pool`), and
/// a poisoned-lock unwrap here would undo that containment. This is
/// also the hot path's single sanctioned alternative to `.unwrap()`
/// (which `tools/lint.sh` bans there).
pub fn lock_unpoisoned<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Condvar wait, recovering from poisoning (rationale as
/// [`lock_unpoisoned`]). Call sites must carry a `loom-verified:`
/// annotation naming the loom test that proves their wake protocol
/// lost-wakeup-free — `tools/lint.sh` enforces the annotation, and
/// CONCURRENCY.md records each verdict.
pub fn wait_unpoisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn lock_unpoisoned_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = Arc::clone(&m);
        // poison the mutex by panicking while holding it
        let _ = thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*lock_unpoisoned(&m), 7);
        *lock_unpoisoned(&m) = 8;
        assert_eq!(*lock_unpoisoned(&m), 8);
    }

    #[test]
    fn spawn_named_names_the_thread() {
        let h = thread::spawn_named("antler-test-thread".into(), || {
            std::thread::current().name().map(str::to_string)
        })
        .unwrap();
        assert_eq!(h.join().unwrap().as_deref(), Some("antler-test-thread"));
    }
}
