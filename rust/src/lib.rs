//! Antler: efficient multitask inference for resource-constrained systems.
//!
//! Reproduction of Luo et al., "Efficient Multitask Learning on
//! Resource-Constrained Systems" (2023). Three-layer architecture:
//!   L1: Pallas kernels (build-time python, `python/compile/kernels/`)
//!   L2: JAX per-layer model blocks, AOT-lowered to HLO text
//!   L3: this crate — the Antler coordinator: task graphs, affinity,
//!       ordering, memory-hierarchy simulation, serving runtime.

pub mod affinity;
pub mod bench;
pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod device;
pub mod exec;
pub mod memory;
pub mod model;
pub mod ordering;
pub mod runtime;
pub mod sync;
pub mod taskgraph;
pub mod tsplib;
pub mod testkit;
pub mod trainer;
pub mod util;
