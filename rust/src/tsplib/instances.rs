//! The Table 3 instance set. FIVE is the public five-city dataset
//! verbatim (optimal tour 19). The remaining TSPLIB/SOP matrices are
//! size-matched seeded analogs (same node / precedence / conditional
//! counts as the paper's table; the offline environment has no TSPLIB
//! copy). "Optimal" is always computed by the exact solver, so the
//! GA-vs-optimal comparison the table makes is preserved instance by
//! instance.

use crate::ordering::OrderingProblem;
use crate::testkit::gen;
use crate::util::rng::Pcg32;

use super::parser::parse_tsplib;

/// The classic 5-city instance (Burkardt's `five.tsp`); optimal tour 19.
pub const FIVE: &str = "NAME: five\nTYPE: TSP\nDIMENSION: 5\n\
EDGE_WEIGHT_TYPE: EXPLICIT\nEDGE_WEIGHT_FORMAT: FULL_MATRIX\n\
EDGE_WEIGHT_SECTION\n\
0 3 4 2 7\n\
3 0 4 6 3\n\
4 4 0 5 8\n\
2 6 5 0 6\n\
7 3 8 6 0\n\
EOF\n";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    Regular,
    Precedence,
    Conditional,
}

#[derive(Debug, Clone)]
pub struct Table3Instance {
    pub name: &'static str,
    pub variant: Variant,
    pub nodes: usize,
    pub n_precedence: usize,
    pub n_conditional: usize,
    pub problem: OrderingProblem,
}

fn synthetic(
    name: &'static str,
    variant: Variant,
    nodes: usize,
    n_prec: usize,
    n_cond: usize,
    seed: u64,
    cyclic: bool,
) -> Table3Instance {
    let mut rng = Pcg32::seed(seed);
    let flat = gen::sym_cost_matrix(&mut rng, nodes, 400.0);
    let cost: Vec<Vec<f64>> = (0..nodes)
        .map(|i| flat[i * nodes..(i + 1) * nodes].iter().map(|x| x.round()).collect())
        .collect();
    let all_edges = gen::precedence_dag(&mut rng, nodes, n_prec + n_cond);
    let (cond_edges, prec_edges) = all_edges.split_at(n_cond.min(all_edges.len()));
    let conditional: Vec<(usize, usize, f64)> = cond_edges
        .iter()
        .map(|&(a, b)| (a, b, (0.5 + rng.f64() * 0.5 * 10.0).round() / 10.0))
        .map(|(a, b, p)| (a, b, p.clamp(0.5, 1.0)))
        .collect();
    let mut p = OrderingProblem::from_matrix(cost)
        .with_precedence(prec_edges.to_vec())
        .with_conditional(conditional);
    if cyclic {
        p = p.cyclic();
    }
    Table3Instance {
        name,
        variant,
        nodes,
        n_precedence: prec_edges.len(),
        n_conditional: n_cond,
        problem: p,
    }
}

/// Build the nine Table 3 rows: three regular, three precedence, three
/// conditional instances with the paper's node/constraint counts.
pub fn table3_instances() -> Vec<Table3Instance> {
    let five = Table3Instance {
        name: "FIVE",
        variant: Variant::Regular,
        nodes: 5,
        n_precedence: 0,
        n_conditional: 0,
        problem: parse_tsplib(FIVE, true).expect("embedded FIVE parses"),
    };
    vec![
        five,
        synthetic("P01*", Variant::Regular, 15, 0, 0, 1501, true),
        synthetic("GR17*", Variant::Regular, 17, 0, 0, 1701, true),
        synthetic("ESC07*", Variant::Precedence, 9, 6, 0, 907, false),
        synthetic("ESC11*", Variant::Precedence, 13, 3, 0, 1311, false),
        synthetic("br17.12*", Variant::Precedence, 17, 12, 0, 1712, false),
        synthetic("ESC07c*", Variant::Conditional, 9, 6, 3, 917, false),
        synthetic("ESC11c*", Variant::Conditional, 13, 3, 3, 1321, false),
        synthetic("ESC12c*", Variant::Conditional, 14, 7, 3, 1412, false),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ordering::{solve_brute, solve_held_karp};

    #[test]
    fn five_optimal_tour_is_19() {
        let p = parse_tsplib(FIVE, true).unwrap();
        let s = solve_held_karp(&p).unwrap();
        assert_eq!(s.cost.round() as i64, 19);
        let b = solve_brute(&p).unwrap();
        assert_eq!(b.cost.round() as i64, 19);
    }

    #[test]
    fn table3_counts_match_paper_rows() {
        let inst = table3_instances();
        assert_eq!(inst.len(), 9);
        let by_name: std::collections::HashMap<_, _> =
            inst.iter().map(|i| (i.name, i)).collect();
        assert_eq!(by_name["FIVE"].nodes, 5);
        assert_eq!(by_name["P01*"].nodes, 15);
        assert_eq!(by_name["GR17*"].nodes, 17);
        assert_eq!(by_name["ESC07*"].nodes, 9);
        assert_eq!(by_name["ESC07*"].n_precedence, 6);
        assert_eq!(by_name["ESC11*"].n_precedence, 3);
        assert_eq!(by_name["br17.12*"].n_precedence, 12);
        assert_eq!(by_name["ESC12c*"].n_conditional, 3);
        assert_eq!(by_name["ESC12c*"].nodes, 14);
    }

    #[test]
    fn all_instances_feasible() {
        for inst in table3_instances() {
            if inst.nodes <= 17 {
                let s = solve_held_karp(&inst.problem);
                assert!(s.is_some(), "{} infeasible", inst.name);
                assert!(inst.problem.is_valid(&s.unwrap().order), "{}", inst.name);
            }
        }
    }

    #[test]
    fn conditional_instances_have_probabilities_in_range() {
        for inst in table3_instances() {
            for &(_, _, p) in &inst.problem.conditional {
                assert!((0.5..=1.0).contains(&p), "{}: p={}", inst.name, p);
            }
        }
    }
}
