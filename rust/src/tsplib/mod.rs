//! TSPLIB-format instances for Table 3 (GA vs optimal task ordering).
//!
//! Parser for EXPLICIT edge-weight TSP/SOP files (FULL_MATRIX,
//! LOWER_DIAG_ROW, UPPER_ROW) plus the embedded instance set. The FIVE
//! instance is the public Burkardt dataset verbatim; the larger TSPLIB
//! matrices are not redistributable/offline here, so size-matched seeded
//! analogs stand in (same node / precedence / conditional counts as Table
//! 3), and the "Optimal" column is computed by the exact Held–Karp solver
//! rather than read from the TSPLIB index — see DESIGN.md, Substitutions.

pub mod instances;
pub mod parser;

pub use instances::{table3_instances, Table3Instance, Variant};
pub use parser::parse_tsplib;
