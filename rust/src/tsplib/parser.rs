//! Minimal TSPLIB parser: EXPLICIT edge weights in FULL_MATRIX,
//! LOWER_DIAG_ROW or UPPER_ROW layout. SOP-style instances mark
//! precedence with -1 entries (`c[i][j] == -1` ⇒ j must precede i).

use anyhow::{anyhow, bail, Result};

use crate::ordering::OrderingProblem;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Format {
    FullMatrix,
    LowerDiagRow,
    UpperRow,
}

/// Parse TSPLIB text into an ordering problem. `cyclic` selects the tour
/// (TSP) vs path (SOP) objective.
pub fn parse_tsplib(text: &str, cyclic: bool) -> Result<OrderingProblem> {
    let mut dim: Option<usize> = None;
    let mut fmt: Option<Format> = None;
    let mut weights: Vec<f64> = Vec::new();
    let mut in_weights = false;

    for raw in text.lines() {
        let line = raw.trim();
        if line.is_empty() || line == "EOF" {
            continue;
        }
        if in_weights {
            if line.contains(':') || line.ends_with("SECTION") {
                in_weights = false;
            } else {
                for tok in line.split_whitespace() {
                    weights.push(
                        tok.parse::<f64>()
                            .map_err(|_| anyhow!("bad weight token {tok:?}"))?,
                    );
                }
                continue;
            }
        }
        if let Some((k, v)) = line.split_once(':') {
            let (k, v) = (k.trim(), v.trim());
            match k {
                "DIMENSION" => dim = Some(v.parse()?),
                "EDGE_WEIGHT_FORMAT" => {
                    fmt = Some(match v {
                        "FULL_MATRIX" => Format::FullMatrix,
                        "LOWER_DIAG_ROW" => Format::LowerDiagRow,
                        "UPPER_ROW" => Format::UpperRow,
                        other => bail!("unsupported EDGE_WEIGHT_FORMAT {other}"),
                    })
                }
                _ => {}
            }
        } else if line == "EDGE_WEIGHT_SECTION" {
            in_weights = true;
        }
    }

    let n = dim.ok_or_else(|| anyhow!("missing DIMENSION"))?;
    let fmt = fmt.unwrap_or(Format::FullMatrix);
    let mut c = vec![vec![0.0f64; n]; n];
    match fmt {
        Format::FullMatrix => {
            if weights.len() != n * n {
                bail!("expected {} weights, got {}", n * n, weights.len());
            }
            for i in 0..n {
                for j in 0..n {
                    c[i][j] = weights[i * n + j];
                }
            }
        }
        Format::LowerDiagRow => {
            let expect = n * (n + 1) / 2;
            if weights.len() != expect {
                bail!("expected {} weights, got {}", expect, weights.len());
            }
            let mut it = weights.iter();
            for i in 0..n {
                for j in 0..=i {
                    let w = *it.next().unwrap();
                    c[i][j] = w;
                    c[j][i] = w;
                }
            }
        }
        Format::UpperRow => {
            let expect = n * (n - 1) / 2;
            if weights.len() != expect {
                bail!("expected {} weights, got {}", expect, weights.len());
            }
            let mut it = weights.iter();
            for i in 0..n {
                for j in (i + 1)..n {
                    let w = *it.next().unwrap();
                    c[i][j] = w;
                    c[j][i] = w;
                }
            }
        }
    }

    // SOP convention: -1 marks precedence (j before i); cost becomes 0.
    let mut precedence = Vec::new();
    for i in 0..n {
        for j in 0..n {
            if c[i][j] < 0.0 {
                precedence.push((j, i));
                c[i][j] = 0.0;
            }
        }
    }

    let mut p = OrderingProblem::from_matrix(c).with_precedence(precedence);
    if cyclic {
        p = p.cyclic();
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = "NAME: t3\nTYPE: TSP\nDIMENSION: 3\n\
EDGE_WEIGHT_TYPE: EXPLICIT\nEDGE_WEIGHT_FORMAT: FULL_MATRIX\n\
EDGE_WEIGHT_SECTION\n0 1 2\n1 0 3\n2 3 0\nEOF\n";

    #[test]
    fn parses_full_matrix() {
        let p = parse_tsplib(FULL, true).unwrap();
        assert_eq!(p.n, 3);
        assert_eq!(p.cost[0][1], 1.0);
        assert_eq!(p.cost[2][1], 3.0);
        assert!(p.cyclic);
        assert!(p.precedence.is_empty());
    }

    #[test]
    fn parses_lower_diag_row() {
        let text = "DIMENSION: 3\nEDGE_WEIGHT_FORMAT: LOWER_DIAG_ROW\n\
EDGE_WEIGHT_SECTION\n0\n5 0\n7 9 0\nEOF\n";
        let p = parse_tsplib(text, false).unwrap();
        assert_eq!(p.cost[0][1], 5.0);
        assert_eq!(p.cost[1][0], 5.0);
        assert_eq!(p.cost[2][1], 9.0);
    }

    #[test]
    fn parses_sop_precedence() {
        let text = "DIMENSION: 3\nEDGE_WEIGHT_FORMAT: FULL_MATRIX\n\
EDGE_WEIGHT_SECTION\n0 1 2\n-1 0 3\n2 3 0\nEOF\n";
        let p = parse_tsplib(text, false).unwrap();
        // c[1][0] == -1 => task 0 must precede task 1
        assert_eq!(p.precedence, vec![(0, 1)]);
        assert_eq!(p.cost[1][0], 0.0);
    }

    #[test]
    fn rejects_wrong_weight_count() {
        let text = "DIMENSION: 3\nEDGE_WEIGHT_FORMAT: FULL_MATRIX\n\
EDGE_WEIGHT_SECTION\n0 1\nEOF\n";
        assert!(parse_tsplib(text, false).is_err());
    }

    #[test]
    fn tolerates_multiline_weights() {
        let text = "DIMENSION: 2\nEDGE_WEIGHT_FORMAT: FULL_MATRIX\n\
EDGE_WEIGHT_SECTION\n0\n4 4\n0\nEOF\n";
        let p = parse_tsplib(text, false).unwrap();
        assert_eq!(p.cost[0][1], 4.0);
        assert_eq!(p.cost[1][0], 4.0);
    }
}
