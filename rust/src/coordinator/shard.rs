//! Sharded serving: N executors, each owning its own `Send` backend (the
//! pure-Rust reference interpreter), running on the existing
//! `exec::pool::ThreadPool`. This is the heavy-traffic serving layer:
//! one process, N cores, N independent §2.3 state machines, one
//! aggregate [`ServeReport`].
//!
//! Two schedulers:
//!
//! * **Work-stealing** (the default, [`ShardOpts::steal`]): frames land
//!   in one shared bounded injector queue, plus a small per-shard deque
//!   for frames whose tagged shard is already *warm* (its
//!   [`BlockExecutor`] has the entry segment weights resident — the
//!   residency-aware routing from the ROADMAP). Idle shards drain their
//!   own deque, then the injector, then steal from the longest sibling
//!   deque — so a stalled or dead shard never strands frames that
//!   healthy shards had capacity for. A shard whose executor fails is
//!   marked dead, its queued frames are returned to the injector, and
//!   serving continues on the survivors (the failure is reported in
//!   [`ShardReport::shard_errors`]).
//!
//! * **Round-robin** (the PR-3 baseline, kept for comparison): frames
//!   are dealt to per-shard bounded queues blindly; a full — or dead —
//!   shard queue drops the frame even while siblings idle. This is
//!   exactly the under-utilization the paper's cost model penalizes;
//!   the regression tests and `benches/runtime_hotpath.rs` measure the
//!   gap (EXPERIMENTS.md §Perf).
//!
//! Cross-frame micro-batching ([`ShardOpts::batch`]): a shard drains up
//! to `batch` queued frames in one pop and runs them through
//! [`BlockExecutor::run_round_batched`] — one batched forward per
//! segment per task, amortizing weight-block loads (the batching case
//! from *Batching-Aware Joint Model Onloading and Offloading*,
//! PAPERS.md) while the reference backend's block kernels keep the
//! predictions bitwise identical to the single-frame loop.
//!
//! Sharding is by frame, so per-sample activation reuse across tasks is
//! preserved inside every shard (a frame's whole task round runs on one
//! executor); only cross-frame weight residency is per-shard state.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicIsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, TrySendError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::exec::pool::ThreadPool;
use crate::model::Tensor;
use crate::runtime::Backend;

use super::executor::BlockExecutor;
use super::server::{
    build_report, process_frame, Frame, FrameResult, ServePlan, ServeReport,
};

/// Knobs for a sharded serve.
#[derive(Debug, Clone)]
pub struct ShardOpts {
    /// Bound of the shared injector queue (work-stealing) or of each
    /// per-shard queue (round-robin). Overflow drops the frame.
    pub queue_depth: usize,
    /// Max frames a shard drains into one batched forward (1 = off).
    /// Work-stealing only: the round-robin baseline deliberately keeps
    /// PR 3's frame-at-a-time behavior and ignores this.
    pub batch: usize,
    /// Work-stealing scheduler (default) vs the round-robin baseline.
    pub steal: bool,
    /// Bound of each per-shard preferred deque (work-stealing only).
    pub local_depth: usize,
    /// Delay between produced frames (a paced sensor front-end).
    pub pace: Option<Duration>,
    /// Test/bench knob: (shard, per-frame delay) slowing one shard down
    /// to model a straggler or a core stolen by another tenant.
    pub handicap: Option<(usize, Duration)>,
}

impl Default for ShardOpts {
    fn default() -> ShardOpts {
        ShardOpts {
            queue_depth: 64,
            batch: 1,
            steal: true,
            local_depth: 2,
            pace: None,
            handicap: None,
        }
    }
}

/// Aggregate result of a sharded serve.
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub shards: usize,
    /// Frames actually processed by each shard.
    pub frames_per_shard: Vec<usize>,
    /// Shards whose executor failed mid-stream (work continued on the
    /// survivors; the poisoned frames are counted as dropped).
    pub shard_errors: Vec<(usize, String)>,
    /// Every frame's result, sorted by frame id.
    pub results: Vec<FrameResult>,
    /// Pool-wide metrics (frames/drops/latency percentiles/sim cost and
    /// layer counters summed over every shard).
    pub aggregate: ServeReport,
}

impl ShardReport {
    /// Number of shards that processed at least one frame.
    pub fn busy_shards(&self) -> usize {
        self.frames_per_shard.iter().filter(|&&c| c > 0).count()
    }
}

/// What one shard worker hands back when its loop ends.
struct ShardOutcome {
    shard: usize,
    results: Vec<FrameResult>,
    tasks_skipped: usize,
    layer_execs: u64,
    layer_skips: u64,
    /// Executor failure that killed the shard, if any.
    error: Option<String>,
    /// Frames consumed but not served because of that failure.
    failed: usize,
}

/// Serve `frames` across `n_shards` executors built by `make_executor`
/// (one per shard, each owning its backend — the backend must be `Send`,
/// which the reference backend is and PJRT deliberately is not).
///
/// Compatibility wrapper over [`serve_sharded_opts`] running the
/// round-robin baseline with batching off, like PR 3's scheduler.
pub fn serve_sharded<B, F>(
    make_executor: F,
    n_shards: usize,
    plan: &ServePlan,
    frames: Vec<(u64, Tensor)>,
    queue_depth: usize,
    pace: Option<Duration>,
) -> Result<ShardReport>
where
    B: Backend + Send + 'static,
    F: FnMut(usize) -> Result<BlockExecutor<B>>,
{
    let opts = ShardOpts {
        queue_depth,
        pace,
        steal: false,
        batch: 1,
        ..ShardOpts::default()
    };
    serve_sharded_opts(make_executor, n_shards, plan, frames, &opts)
}

/// Serve `frames` across `n_shards` executors with explicit scheduler
/// options. Returns when every shard has drained and reported.
pub fn serve_sharded_opts<B, F>(
    make_executor: F,
    n_shards: usize,
    plan: &ServePlan,
    frames: Vec<(u64, Tensor)>,
    opts: &ShardOpts,
) -> Result<ShardReport>
where
    B: Backend + Send + 'static,
    F: FnMut(usize) -> Result<BlockExecutor<B>>,
{
    if opts.steal {
        serve_work_stealing(make_executor, n_shards, plan, frames, opts)
    } else {
        serve_round_robin(make_executor, n_shards, plan, frames, opts)
    }
}

// --------------------------------------------------------- round-robin

/// The PR-3 baseline: deal frames to per-shard bounded queues in strict
/// rotation. Kept as the comparison point for the work-stealing
/// scheduler; its known pathology (frames offered to a full or dead
/// shard are dropped while siblings idle) is measured, not fixed.
fn serve_round_robin<B, F>(
    mut make_executor: F,
    n_shards: usize,
    plan: &ServePlan,
    frames: Vec<(u64, Tensor)>,
    opts: &ShardOpts,
) -> Result<ShardReport>
where
    B: Backend + Send + 'static,
    F: FnMut(usize) -> Result<BlockExecutor<B>>,
{
    let n = n_shards.max(1);
    let pool = ThreadPool::new(n);
    let (res_tx, res_rx) = channel();
    let mut frame_txs = Vec::with_capacity(n);
    for s in 0..n {
        let (tx, rx) = sync_channel::<Frame>(opts.queue_depth.max(1));
        frame_txs.push(tx);
        let mut ex = make_executor(s)?;
        let plan = plan.clone();
        let res_tx = res_tx.clone();
        let handicap = opts.handicap;
        pool.execute(move || {
            let mut out = ShardOutcome {
                shard: s,
                results: Vec::new(),
                tasks_skipped: 0,
                layer_execs: 0,
                layer_skips: 0,
                error: None,
                failed: 0,
            };
            while let Ok(frame) = rx.recv() {
                if let Some((hs, d)) = handicap {
                    if hs == s {
                        std::thread::sleep(d);
                    }
                }
                match process_frame(&mut ex, &plan, frame) {
                    Ok((r, sk)) => {
                        out.results.push(r);
                        out.tasks_skipped += sk;
                    }
                    Err(e) => {
                        out.error = Some(format!("{e:#}"));
                        // keep consuming so frames already accepted into
                        // this shard's queue are accounted as dropped
                        // rather than silently vanishing
                        out.failed = 1 + rx.iter().count();
                        break;
                    }
                }
            }
            out.layer_execs = ex.layer_execs;
            out.layer_skips = ex.layer_skips;
            let _ = res_tx.send(out);
        });
    }
    drop(res_tx);

    let t0 = Instant::now();
    let mut dropped = 0usize;
    for (i, (id, input)) in frames.into_iter().enumerate() {
        let frame = Frame { id, input, enqueued: Instant::now() };
        match frame_txs[i % n].try_send(frame) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => dropped += 1,
            // a dead shard's queue: the frame is dropped even when live
            // shards had capacity — the round-robin pathology the
            // work-stealing scheduler exists to fix
            Err(TrySendError::Disconnected(_)) => dropped += 1,
        }
        if let Some(p) = opts.pace {
            std::thread::sleep(p);
        }
    }
    drop(frame_txs); // closes every queue; shard loops drain and exit

    collect_outcomes(n, res_rx, dropped, t0)
}

// -------------------------------------------------------- work stealing

/// Shared scheduler state: one bounded injector plus per-shard deques.
struct StealState {
    global: VecDeque<Frame>,
    locals: Vec<VecDeque<Frame>>,
    dead: Vec<bool>,
    closed: bool,
}

struct StealQueue {
    st: Mutex<StealState>,
    cv: Condvar,
}

impl StealQueue {
    fn new(n: usize) -> StealQueue {
        StealQueue {
            st: Mutex::new(StealState {
                global: VecDeque::new(),
                locals: (0..n).map(|_| VecDeque::new()).collect(),
                dead: vec![false; n],
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue one frame: onto the preferred shard's deque when that
    /// shard is live and its deque has room, else onto the bounded
    /// injector. Returns false (frame dropped) only when the injector is
    /// full — there is no per-shard overflow, so a slow shard cannot
    /// strand frames the others could serve.
    fn push(
        &self,
        frame: Frame,
        preferred: Option<usize>,
        queue_depth: usize,
        local_depth: usize,
    ) -> bool {
        let mut st = self.st.lock().unwrap();
        if let Some(p) = preferred {
            if p < st.locals.len() && !st.dead[p] && st.locals[p].len() < local_depth
            {
                st.locals[p].push_back(frame);
                drop(st);
                self.cv.notify_all();
                return true;
            }
        }
        if st.global.len() < queue_depth {
            st.global.push_back(frame);
            drop(st);
            self.cv.notify_all();
            return true;
        }
        false
    }

    /// No more frames will be pushed; drain-and-exit.
    fn close(&self) {
        self.st.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Shard `s`'s executor failed: flag it and return its queued frames
    /// to the injector front so the survivors pick them up promptly.
    fn mark_dead(&self, s: usize) {
        let mut st = self.st.lock().unwrap();
        st.dead[s] = true;
        let orphans: Vec<Frame> = st.locals[s].drain(..).collect();
        for f in orphans.into_iter().rev() {
            st.global.push_front(f);
        }
        drop(st);
        self.cv.notify_all();
    }

    /// Pop up to `max` frames for shard `me`: own deque first, then the
    /// injector, then (only when otherwise idle) steal from the longest
    /// sibling deque. Blocks while empty; `None` once closed and fully
    /// drained.
    fn pop_batch(&self, me: usize, max: usize) -> Option<Vec<Frame>> {
        let mut st = self.st.lock().unwrap();
        loop {
            let mut batch = Vec::new();
            while batch.len() < max {
                if let Some(f) = st.locals[me].pop_front() {
                    batch.push(f);
                    continue;
                }
                if let Some(f) = st.global.pop_front() {
                    batch.push(f);
                    continue;
                }
                break;
            }
            if batch.is_empty() {
                let victim = (0..st.locals.len())
                    .filter(|&v| v != me && !st.locals[v].is_empty())
                    .max_by_key(|&v| st.locals[v].len());
                if let Some(v) = victim {
                    while batch.len() < max {
                        match st.locals[v].pop_front() {
                            Some(f) => batch.push(f),
                            None => break,
                        }
                    }
                }
            }
            if !batch.is_empty() {
                return Some(batch);
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Frames nobody will ever pop (every worker exited early). Counted
    /// as dropped so frame conservation holds even in total failure.
    fn drain_remaining(&self) -> usize {
        let mut st = self.st.lock().unwrap();
        let mut n = st.global.len();
        st.global.clear();
        for l in st.locals.iter_mut() {
            n += l.len();
            l.clear();
        }
        n
    }
}

/// Per-shard weight-residency board: the group id resident in each
/// segment slot, published by the shard after every round so the
/// dispatcher can route tagged frames to already-warm executors.
struct ResidencyBoard {
    segs: Vec<AtomicIsize>,
}

impl ResidencyBoard {
    fn new(nseg: usize) -> ResidencyBoard {
        ResidencyBoard { segs: (0..nseg).map(|_| AtomicIsize::new(-1)).collect() }
    }

    fn publish(&self, resident: &[Option<usize>]) {
        for (slot, r) in self.segs.iter().zip(resident) {
            slot.store(r.map_or(-1, |g| g as isize), Ordering::Relaxed);
        }
    }

    /// True when every segment the plan needs a stable group for is
    /// already resident (`None` entries are don't-cares: segments whose
    /// group changes between tasks within a round anyway).
    fn warm_for(&self, needed: &[Option<usize>]) -> bool {
        self.segs.iter().zip(needed).all(|(slot, need)| match need {
            Some(g) => slot.load(Ordering::Relaxed) == *g as isize,
            None => true,
        })
    }
}

/// The shared-injector work-stealing scheduler with residency-aware
/// dispatch and cross-frame micro-batching.
fn serve_work_stealing<B, F>(
    mut make_executor: F,
    n_shards: usize,
    plan: &ServePlan,
    frames: Vec<(u64, Tensor)>,
    opts: &ShardOpts,
) -> Result<ShardReport>
where
    B: Backend + Send + 'static,
    F: FnMut(usize) -> Result<BlockExecutor<B>>,
{
    let n = n_shards.max(1);
    // build executors up front: the dispatcher reads the graph shape for
    // residency routing before the workers take ownership
    let mut executors = Vec::with_capacity(n);
    for s in 0..n {
        executors.push(make_executor(s)?);
    }
    // a shard is "warm" when the blocks every task in the round shares
    // (the stable trunk) are resident; branch segments swap groups
    // within a round and are excluded from the test
    let graph = &executors[0].graph;
    let nseg = graph.n_segments();
    let needed: Vec<Option<usize>> = match plan.order.first() {
        Some(&t0) => (0..nseg)
            .map(|s| {
                let g0 = graph.group_of(s, t0);
                plan.order
                    .iter()
                    .all(|&t| graph.group_of(s, t) == g0)
                    .then_some(g0)
            })
            .collect(),
        None => Vec::new(),
    };
    let boards: Vec<Arc<ResidencyBoard>> =
        (0..n).map(|_| Arc::new(ResidencyBoard::new(nseg))).collect();
    let queue = Arc::new(StealQueue::new(n));
    let pool = ThreadPool::new(n);
    let (res_tx, res_rx) = channel();
    let batch = opts.batch.max(1);
    for (s, mut ex) in executors.into_iter().enumerate() {
        let queue = Arc::clone(&queue);
        let board = Arc::clone(&boards[s]);
        let plan = plan.clone();
        let res_tx = res_tx.clone();
        let handicap = opts.handicap;
        pool.execute(move || {
            let mut out = ShardOutcome {
                shard: s,
                results: Vec::new(),
                tasks_skipped: 0,
                layer_execs: 0,
                layer_skips: 0,
                error: None,
                failed: 0,
            };
            while let Some(popped) = queue.pop_batch(s, batch) {
                if let Some((hs, d)) = handicap {
                    if hs == s {
                        std::thread::sleep(d * popped.len() as u32);
                    }
                }
                let m = popped.len();
                let step: Result<()> = (|| {
                    if m == 1 {
                        let frame = popped.into_iter().next().unwrap();
                        let (r, sk) = process_frame(&mut ex, &plan, frame)?;
                        out.results.push(r);
                        out.tasks_skipped += sk;
                    } else {
                        let ids: Vec<u64> =
                            popped.iter().map(|f| f.id).collect();
                        let enq: Vec<Instant> =
                            popped.iter().map(|f| f.enqueued).collect();
                        let inputs: Vec<&Tensor> =
                            popped.iter().map(|f| &f.input).collect();
                        let started = Instant::now();
                        let round = ex.run_round_batched(
                            &ids,
                            &inputs,
                            &plan.order,
                            &plan.conditional,
                        )?;
                        for i in 0..m {
                            out.results.push(FrameResult {
                                id: ids[i],
                                predictions: round.predictions[i].clone(),
                                sim_cost: round.costs[i],
                                wall_latency_s: enq[i]
                                    .elapsed()
                                    .as_secs_f64(),
                                queue_wait_s: started
                                    .duration_since(enq[i])
                                    .as_secs_f64(),
                            });
                        }
                        out.tasks_skipped += round.tasks_skipped;
                    }
                    Ok(())
                })();
                match step {
                    Ok(()) => board.publish(ex.resident()),
                    Err(e) => {
                        // this shard is broken: surface the error, give
                        // its queued frames back, let the others serve
                        out.error = Some(format!("{e:#}"));
                        out.failed += m;
                        queue.mark_dead(s);
                        break;
                    }
                }
            }
            out.layer_execs = ex.layer_execs;
            out.layer_skips = ex.layer_skips;
            let _ = res_tx.send(out);
        });
    }
    drop(res_tx);

    let t0 = Instant::now();
    let mut dropped = 0usize;
    let qd = opts.queue_depth.max(1);
    let ld = opts.local_depth.max(1);
    for (id, input) in frames {
        // residency-aware dispatch: a frame sticks to its tagged shard
        // only while that shard is warm and has deque room; otherwise it
        // goes to the injector where any idle shard takes it
        let preferred = if needed.is_empty() {
            None
        } else {
            let p = (id as usize) % n;
            boards[p].warm_for(&needed).then_some(p)
        };
        let frame = Frame { id, input, enqueued: Instant::now() };
        if !queue.push(frame, preferred, qd, ld) {
            dropped += 1;
        }
        if let Some(p) = opts.pace {
            std::thread::sleep(p);
        }
    }
    queue.close();

    let report = collect_outcomes(n, res_rx, dropped, t0);
    // if every worker died early, queued frames were never consumed
    let leftover = queue.drain_remaining();
    report.map(|mut r| {
        r.aggregate.dropped += leftover;
        r
    })
}

// --------------------------------------------------------- aggregation

fn collect_outcomes(
    n: usize,
    res_rx: std::sync::mpsc::Receiver<ShardOutcome>,
    mut dropped: usize,
    t0: Instant,
) -> Result<ShardReport> {
    let mut frames_per_shard = vec![0usize; n];
    let mut shard_errors = Vec::new();
    let mut all = Vec::new();
    let mut skipped = 0usize;
    let mut layer_execs = 0u64;
    let mut layer_skips = 0u64;
    for _ in 0..n {
        let out = res_rx
            .recv()
            .map_err(|_| anyhow!("a shard worker died before reporting"))?;
        frames_per_shard[out.shard] = out.results.len();
        skipped += out.tasks_skipped;
        layer_execs += out.layer_execs;
        layer_skips += out.layer_skips;
        dropped += out.failed;
        if let Some(e) = out.error {
            shard_errors.push((out.shard, e));
        }
        all.extend(out.results);
    }
    shard_errors.sort_by_key(|&(s, _)| s);
    all.sort_by_key(|r| r.id);
    let wall = t0.elapsed().as_secs_f64();
    let aggregate =
        build_report(&all, dropped, wall, skipped, layer_execs, layer_skips);
    Ok(ShardReport {
        shards: n,
        frames_per_shard,
        shard_errors,
        results: all,
        aggregate,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Device;
    use crate::model::ArchSpec;
    use crate::runtime::ReferenceBackend;
    use crate::taskgraph::{Partition, TaskGraph};
    use crate::trainer::GraphWeights;
    use crate::util::rng::Pcg32;

    fn make_executor(_shard: usize) -> Result<BlockExecutor<ReferenceBackend>> {
        let backend = ReferenceBackend::new();
        let arch = backend.arch("cnn5")?;
        let graph = TaskGraph::new(
            3,
            vec![1, 3, 4],
            vec![
                Partition(vec![0, 0, 0]),
                Partition(vec![0, 0, 0]),
                Partition(vec![0, 0, 1]),
                Partition::singletons(3),
            ],
        )?;
        let ncls = vec![2, 2, 2];
        // identical seed per shard: every shard serves the same weights
        let mut rng = Pcg32::seed(7);
        let store = GraphWeights::init(&graph, &arch, &ncls, &mut rng);
        Ok(BlockExecutor::new(
            backend,
            Device::msp430(),
            arch,
            graph,
            ncls,
            store,
        ))
    }

    fn frames(n: usize) -> Vec<(u64, Tensor)> {
        let mut rng = Pcg32::seed(15);
        (0..n as u64)
            .map(|i| {
                let data = (0..256).map(|_| rng.gauss()).collect();
                (i, Tensor::new(vec![1, 16, 16, 1], data))
            })
            .collect()
    }

    #[test]
    fn sharded_serve_covers_all_frames_across_executors() {
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        // deep queues: 24 frames over 3 shards never overflow depth 16
        let report =
            serve_sharded(make_executor, 3, &plan, frames(24), 16, None).unwrap();
        assert_eq!(report.shards, 3);
        assert_eq!(report.aggregate.dropped, 0);
        assert_eq!(report.aggregate.frames, 24);
        // round-robin with no drops: exactly even split, ≥2 shards busy
        assert_eq!(report.frames_per_shard, vec![8, 8, 8]);
        assert!(report.busy_shards() >= 2);
        // aggregate metrics are real
        assert!(report.aggregate.throughput_fps > 0.0);
        assert!(report.aggregate.sim_time_per_frame_s > 0.0);
        assert!(report.aggregate.layer_execs > 0);
        // per-frame activation reuse still happens inside each shard
        assert!(report.aggregate.layer_skips > 0);
        assert!(report.shard_errors.is_empty());
    }

    #[test]
    fn sharded_serve_conserves_frames_with_tiny_queues() {
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let total = 30;
        let report =
            serve_sharded(make_executor, 2, &plan, frames(total), 1, None).unwrap();
        assert_eq!(
            report.aggregate.frames + report.aggregate.dropped,
            total
        );
        assert_eq!(
            report.frames_per_shard.iter().sum::<usize>(),
            report.aggregate.frames
        );
    }

    #[test]
    fn single_shard_degenerates_to_plain_serve() {
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let report =
            serve_sharded(make_executor, 1, &plan, frames(6), 8, None).unwrap();
        assert_eq!(report.shards, 1);
        assert_eq!(report.aggregate.frames, 6);
        assert_eq!(report.frames_per_shard, vec![6]);
    }

    #[test]
    fn conditional_plans_work_sharded() {
        let plan = ServePlan {
            order: vec![0, 1, 2],
            conditional: vec![(0, 1), (0, 2)],
        };
        let report =
            serve_sharded(make_executor, 3, &plan, frames(18), 16, None).unwrap();
        assert_eq!(report.aggregate.frames, 18);
        assert!(report.aggregate.tasks_skipped <= 36);
    }

    #[test]
    fn work_stealing_covers_all_frames() {
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let opts = ShardOpts { queue_depth: 64, ..ShardOpts::default() };
        let report =
            serve_sharded_opts(make_executor, 3, &plan, frames(24), &opts)
                .unwrap();
        assert_eq!(report.aggregate.dropped, 0);
        assert_eq!(report.aggregate.frames, 24);
        assert!(report.shard_errors.is_empty());
        // results arrive sorted by frame id, every id exactly once
        let ids: Vec<u64> = report.results.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..24u64).collect::<Vec<_>>());
        assert_eq!(
            report.frames_per_shard.iter().sum::<usize>(),
            report.aggregate.frames
        );
    }

    #[test]
    fn work_stealing_batched_matches_single_executor_predictions() {
        let plan = ServePlan {
            order: vec![0, 1, 2],
            conditional: vec![(0, 2)],
        };
        let fr = frames(17);
        // baseline: one executor, frame at a time
        let mut ex = make_executor(0).unwrap();
        let (tx, rx) = channel();
        for (id, x) in fr.clone() {
            tx.send(Frame { id, input: x, enqueued: Instant::now() })
                .unwrap();
        }
        drop(tx);
        let (mut base, _) =
            crate::coordinator::server::run_executor(&mut ex, &plan, rx).unwrap();
        base.sort_by_key(|r| r.id);

        let opts = ShardOpts {
            queue_depth: 64,
            batch: 4,
            ..ShardOpts::default()
        };
        let report =
            serve_sharded_opts(make_executor, 2, &plan, fr, &opts).unwrap();
        assert_eq!(report.aggregate.dropped, 0);
        assert_eq!(report.results.len(), base.len());
        for (got, want) in report.results.iter().zip(&base) {
            assert_eq!(got.id, want.id);
            assert_eq!(
                got.predictions, want.predictions,
                "frame {} diverged under sharded batching",
                got.id
            );
        }
    }

    /// Regression for the round-robin dead-shard pathology: with work
    /// stealing, killing one shard must not strand the frames it would
    /// have been dealt — the survivors absorb them, frame conservation
    /// holds, and at most the poisoned frame itself is lost.
    #[test]
    fn dead_shard_frames_are_absorbed_by_survivors() {
        struct FailingBackend {
            inner: ReferenceBackend,
            fail: bool,
        }
        impl Backend for FailingBackend {
            fn name(&self) -> &'static str {
                "failing"
            }
            fn arch(&self, name: &str) -> Result<ArchSpec> {
                self.inner.arch(name)
            }
            fn arch_names(&self) -> Vec<String> {
                self.inner.arch_names()
            }
            fn run_layer(
                &self,
                arch: &ArchSpec,
                layer: usize,
                ncls: Option<usize>,
                x: &Tensor,
                w: &Tensor,
                b: &Tensor,
            ) -> Result<Tensor> {
                anyhow::ensure!(!self.fail, "injected shard fault");
                self.inner.run_layer(arch, layer, ncls, x, w, b)
            }
            fn train_step(
                &self,
                arch: &ArchSpec,
                ncls: usize,
                params: &mut Vec<Tensor>,
                x: &Tensor,
                y: &[i32],
                lr: f32,
            ) -> Result<f32> {
                self.inner.train_step(arch, ncls, params, x, y, lr)
            }
            fn eval_logits(
                &self,
                arch: &ArchSpec,
                ncls: usize,
                params: &[Tensor],
                x: &Tensor,
            ) -> Result<Tensor> {
                self.inner.eval_logits(arch, ncls, params, x)
            }
        }

        let make = |shard: usize| -> Result<BlockExecutor<FailingBackend>> {
            let template = make_executor(0)?;
            Ok(BlockExecutor::new(
                FailingBackend {
                    inner: ReferenceBackend::new(),
                    fail: shard == 0,
                },
                Device::msp430(),
                template.arch.clone(),
                template.graph.clone(),
                template.ncls.clone(),
                template.store.clone(),
            ))
        };

        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let total = 40;
        let opts = ShardOpts { queue_depth: 64, ..ShardOpts::default() };
        let report =
            serve_sharded_opts(make, 3, &plan, frames(total), &opts).unwrap();
        // conservation with dropped ≈ 0: only the frame that poisoned
        // shard 0 can be lost
        assert_eq!(report.aggregate.frames + report.aggregate.dropped, total);
        assert!(
            report.aggregate.dropped <= 1,
            "survivors failed to absorb: {} dropped",
            report.aggregate.dropped
        );
        assert_eq!(report.frames_per_shard[0], 0);
        assert!(report.aggregate.frames >= total - 1);
        if report.aggregate.dropped == 1 {
            assert_eq!(report.shard_errors.len(), 1);
            assert_eq!(report.shard_errors[0].0, 0);
            assert!(report.shard_errors[0].1.contains("injected shard fault"));
        }
    }

    /// The skewed-workload acceptance gate: one shard paced 10x slower.
    /// Work stealing must drop strictly fewer frames than round-robin at
    /// equal queue depth, because the straggler's share is stolen by the
    /// idle siblings instead of overflowing its private queue.
    #[test]
    fn work_stealing_beats_round_robin_under_skew() {
        // single-task rounds keep per-frame compute far below the 40 ms
        // handicap even in debug builds, so the skew dominates timing
        let plan = ServePlan::unconditional(vec![0]);
        let total = 45;
        let skew = |steal: bool| ShardOpts {
            queue_depth: 2,
            batch: if steal { 4 } else { 1 },
            steal,
            local_depth: 1,
            pace: Some(Duration::from_millis(8)),
            handicap: Some((0, Duration::from_millis(40))),
        };
        let rr = serve_sharded_opts(
            make_executor,
            3,
            &plan,
            frames(total),
            &skew(false),
        )
        .unwrap();
        let ws = serve_sharded_opts(
            make_executor,
            3,
            &plan,
            frames(total),
            &skew(true),
        )
        .unwrap();
        assert_eq!(rr.aggregate.frames + rr.aggregate.dropped, total);
        assert_eq!(ws.aggregate.frames + ws.aggregate.dropped, total);
        // the baseline must actually exhibit the pathology...
        assert!(
            rr.aggregate.dropped > 0,
            "round-robin did not overflow the straggler's queue"
        );
        // ...and work stealing must strictly beat it
        assert!(
            ws.aggregate.dropped < rr.aggregate.dropped,
            "steal dropped {} vs round-robin {}",
            ws.aggregate.dropped,
            rr.aggregate.dropped
        );
    }

    #[test]
    fn all_shards_dead_still_conserves_frames() {
        struct AlwaysFail(ReferenceBackend);
        impl Backend for AlwaysFail {
            fn name(&self) -> &'static str {
                "always-fail"
            }
            fn arch(&self, name: &str) -> Result<ArchSpec> {
                self.0.arch(name)
            }
            fn arch_names(&self) -> Vec<String> {
                self.0.arch_names()
            }
            fn run_layer(
                &self,
                _arch: &ArchSpec,
                _layer: usize,
                _ncls: Option<usize>,
                _x: &Tensor,
                _w: &Tensor,
                _b: &Tensor,
            ) -> Result<Tensor> {
                anyhow::bail!("total outage")
            }
            fn train_step(
                &self,
                arch: &ArchSpec,
                ncls: usize,
                params: &mut Vec<Tensor>,
                x: &Tensor,
                y: &[i32],
                lr: f32,
            ) -> Result<f32> {
                self.0.train_step(arch, ncls, params, x, y, lr)
            }
            fn eval_logits(
                &self,
                arch: &ArchSpec,
                ncls: usize,
                params: &[Tensor],
                x: &Tensor,
            ) -> Result<Tensor> {
                self.0.eval_logits(arch, ncls, params, x)
            }
        }
        let make = |_s: usize| -> Result<BlockExecutor<AlwaysFail>> {
            let template = make_executor(0)?;
            Ok(BlockExecutor::new(
                AlwaysFail(ReferenceBackend::new()),
                Device::msp430(),
                template.arch.clone(),
                template.graph.clone(),
                template.ncls.clone(),
                template.store.clone(),
            ))
        };
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let total = 20;
        let opts = ShardOpts { queue_depth: 64, ..ShardOpts::default() };
        let report =
            serve_sharded_opts(make, 2, &plan, frames(total), &opts).unwrap();
        assert_eq!(report.aggregate.frames, 0);
        assert_eq!(report.aggregate.dropped, total);
        assert_eq!(report.shard_errors.len(), 2);
        // the zero-frame report is well-formed (the build_report guard)
        assert!(report.aggregate.throughput_fps.is_finite());
        assert_eq!(report.aggregate.latency_p99_ms, 0.0);
    }
}
