//! Sharded serving: N executors, each owning its own `Send` backend (the
//! pure-Rust reference interpreter), running on the existing
//! `exec::pool::ThreadPool`. This is the heavy-traffic serving layer:
//! one process, N cores, N independent §2.3 state machines, one
//! aggregate [`ServeReport`].
//!
//! Two schedulers:
//!
//! * **Work-stealing** (the default, [`ShardOpts::steal`]): frames land
//!   in one shared bounded injector queue, plus a small per-shard deque
//!   for frames whose tagged shard is already *warm* (its
//!   [`BlockExecutor`] has the entry segment weights resident — the
//!   residency-aware routing from the ROADMAP). Idle shards drain their
//!   own deque, then the injector, then steal from the longest sibling
//!   deque — so a stalled or dead shard never strands frames that
//!   healthy shards had capacity for. A shard whose executor fails is
//!   marked dead, its queued frames are returned to the injector, and
//!   serving continues on the survivors (the failure is reported in
//!   [`ShardReport::shard_errors`]).
//!
//! * **Round-robin** (the PR-3 baseline, kept for comparison): frames
//!   are dealt to per-shard bounded queues blindly; a full — or dead —
//!   shard queue drops the frame even while siblings idle. This is
//!   exactly the under-utilization the paper's cost model penalizes;
//!   the regression tests and `benches/runtime_hotpath.rs` measure the
//!   gap (EXPERIMENTS.md §Perf).
//!
//! Cross-frame micro-batching ([`ShardOpts::batch`]): a shard drains up
//! to `batch` queued frames in one pop and runs them through
//! [`BlockExecutor::run_round_batched`] — one batched forward per
//! segment per task, amortizing weight-block loads (the batching case
//! from *Batching-Aware Joint Model Onloading and Offloading*,
//! PAPERS.md) while the reference backend's block kernels keep the
//! predictions bitwise identical to the single-frame loop.
//!
//! Sharding is by frame, so per-sample activation reuse across tasks is
//! preserved inside every shard (a frame's whole task round runs on one
//! executor); only cross-frame weight residency is per-shard state.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::exec::pool::ThreadPool;
use crate::memory::tier::{TierConfig, TierCounters};
use crate::model::Tensor;
use crate::runtime::Backend;
use crate::sync::atomic::{AtomicIsize, AtomicUsize, Ordering};
use crate::sync::mpsc::{channel, sync_channel, Sender, TrySendError};
use crate::sync::{lock_unpoisoned, thread, wait_unpoisoned, Arc, Condvar, Mutex};

use super::audit::{FeedLedger, QueueLedger};

use super::executor::BlockExecutor;
use super::ingest::{run_ingest, IngestReport, Source};
use super::registry::{EpochOutcome, EpochRow, PlanRegistry, PlanVersion};
use super::replan::CostObs;
use super::server::{
    build_report, process_frame, process_frame_observed, Frame, FrameResult,
    ServePlan, ServeReport,
};

/// Knobs for a sharded serve.
#[derive(Debug, Clone)]
pub struct ShardOpts {
    /// Bound of the shared injector queue (work-stealing) or of each
    /// per-shard queue (round-robin). Overflow drops the frame.
    pub queue_depth: usize,
    /// Max frames a shard drains into one batched forward (1 = off).
    /// Work-stealing only: the round-robin baseline deliberately keeps
    /// PR 3's frame-at-a-time behavior and ignores this.
    pub batch: usize,
    /// Adaptive batch sizing (work-stealing only): each shard picks its
    /// next batch in `[1, batch]` from observed injector depth and its
    /// own recent service time (the [`BatchPolicy`] AIMD rule) instead
    /// of always draining `batch`.
    pub adaptive_batch: bool,
    /// Work-stealing scheduler (default) vs the round-robin baseline.
    pub steal: bool,
    /// Bound of each per-shard preferred deque (work-stealing only).
    pub local_depth: usize,
    /// Delay between produced frames (a paced sensor front-end).
    pub pace: Option<Duration>,
    /// Test/bench knob: (shard, per-frame delay) slowing one shard down
    /// to model a straggler or a core stolen by another tenant.
    pub handicap: Option<(usize, Duration)>,
    /// Two-tier weight memory (`memory::tier`): every shard executor
    /// gets its own bounded fast tier with this config; `None` keeps the
    /// flat whole-block-reload cost model. Predictions are identical
    /// either way — the tier only changes load-stall/energy accounting.
    pub tier: Option<TierConfig>,
}

impl Default for ShardOpts {
    fn default() -> ShardOpts {
        ShardOpts {
            queue_depth: 64,
            batch: 1,
            adaptive_batch: false,
            steal: true,
            local_depth: 2,
            pace: None,
            handicap: None,
            tier: None,
        }
    }
}

impl ShardOpts {
    /// The `(queue_depth, local_depth)` both schedulers actually use:
    /// depth 0 is clamped to 1 here, in ONE place, so a depth-0 serve
    /// behaves identically through every entry point (`serve`,
    /// round-robin, work-stealing, multi-producer ingest) instead of
    /// each path deciding for itself.
    pub fn effective_depths(&self) -> (usize, usize) {
        (self.queue_depth.max(1), self.local_depth.max(1))
    }
}

/// Per-shard adaptive batch sizing: AIMD on injector backlog and the
/// shard's own recent service time. The rule, unit-testable in
/// isolation:
///
/// * backlog still >= the current batch after a pop → the queue is deep,
///   **additive increase** (batch + 1, capped at `max`) — drain faster
///   by amortizing more frames per forward;
/// * backlog empty after a pop → light load, **multiplicative decrease**
///   (batch / 2, floored at 1) — stop holding frames for latency's sake;
/// * per-frame service time jumps 1.5x above its EWMA → this shard is
///   slowing (straggler, noisy neighbor), multiplicative decrease so a
///   slow shard stops hogging big batches its siblings could serve.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    max: usize,
    adaptive: bool,
    cur: usize,
    ewma_per_frame_s: Option<f64>,
}

impl BatchPolicy {
    /// Always `b` — the fixed `--batch B` behavior.
    pub fn fixed(b: usize) -> BatchPolicy {
        let b = b.max(1);
        BatchPolicy { max: b, adaptive: false, cur: b, ewma_per_frame_s: None }
    }

    /// Adapt within `[1, max]`, starting cautious at 1.
    pub fn adaptive(max: usize) -> BatchPolicy {
        BatchPolicy {
            max: max.max(1),
            adaptive: true,
            cur: 1,
            ewma_per_frame_s: None,
        }
    }

    /// The batch size to request from the next pop.
    pub fn next(&self) -> usize {
        self.cur
    }

    /// Feed back one served batch: how many frames it held, the backlog
    /// (injector + own deque) left right after the pop, and how long the
    /// batch took to serve.
    pub fn observe(&mut self, served: usize, backlog: usize, service_s: f64) {
        if !self.adaptive {
            return;
        }
        let per = service_s / served.max(1) as f64;
        let slow = self
            .ewma_per_frame_s
            .is_some_and(|e| e > 0.0 && per > 1.5 * e);
        self.ewma_per_frame_s = Some(match self.ewma_per_frame_s {
            None => per,
            Some(e) => 0.7 * e + 0.3 * per,
        });
        self.cur = if slow || backlog == 0 {
            (self.cur / 2).max(1)
        } else if backlog >= self.cur {
            (self.cur + 1).min(self.max)
        } else {
            self.cur
        };
    }
}

/// Aggregate result of a sharded serve.
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub shards: usize,
    /// Frames actually processed by each shard.
    pub frames_per_shard: Vec<usize>,
    /// Per-shard batch-size histogram: `batch_hist[s][b-1]` = number of
    /// pops of exactly `b` frames shard `s` served. Round-robin shards
    /// (frame-at-a-time) report everything in the `b = 1` bucket.
    pub batch_hist: Vec<Vec<usize>>,
    /// Shards whose executor failed mid-stream (work continued on the
    /// survivors; the poisoned frames are counted as dropped).
    pub shard_errors: Vec<(usize, String)>,
    /// Every frame's result, sorted by frame id.
    pub results: Vec<FrameResult>,
    /// Pool-wide metrics (frames/drops/latency percentiles/sim cost and
    /// layer counters summed over every shard).
    pub aggregate: ServeReport,
    /// Two-tier weight-memory counters summed over every shard —
    /// `Some` iff the serve ran with [`ShardOpts::tier`] enabled.
    pub tier: Option<TierCounters>,
    /// Plan-epoch ledger rows from the [`PlanRegistry`] the serve ran
    /// against: one row per (tenant, epoch) with its admission and
    /// retirement counts. Empty on the round-robin baseline, which has
    /// no registry.
    pub epochs: Vec<EpochRow>,
}

impl ShardReport {
    /// Number of shards that processed at least one frame.
    pub fn busy_shards(&self) -> usize {
        self.frames_per_shard.iter().filter(|&&c| c > 0).count()
    }

    /// Pool-wide batch histogram: bucket `b-1` counts pops of exactly
    /// `b` frames summed over every shard.
    pub fn total_hist(&self) -> Vec<usize> {
        let width = self.batch_hist.iter().map(|h| h.len()).max().unwrap_or(0);
        let mut agg = vec![0usize; width];
        for hist in &self.batch_hist {
            for (i, &c) in hist.iter().enumerate() {
                agg[i] += c;
            }
        }
        agg
    }

    /// Render `shard_errors` as a per-shard table for the CLI `serve`
    /// output, or `None` when every shard stayed healthy. The executor
    /// failures were always *collected* here; surfacing them is the CLI's
    /// job and this is its one formatting point (tested below so a dead
    /// shard's error string provably reaches the operator).
    pub fn shard_error_table(&self) -> Option<String> {
        if self.shard_errors.is_empty() {
            return None;
        }
        let mut t = String::from(
            "shard errors (serving continued on survivors):\n  shard  frames  error\n",
        );
        for (s, e) in &self.shard_errors {
            let served = self.frames_per_shard.get(*s).copied().unwrap_or(0);
            t.push_str(&format!("  {s:>5}  {served:>6}  {e}\n"));
        }
        Some(t)
    }

    /// Frames served per tenant, derived from the per-frame results:
    /// `(tenant, frames)` sorted by tenant id. Single-tenant serves
    /// report one row for tenant 0 — the field is threaded even there,
    /// so the admission table can always break down by tenant.
    pub fn frames_per_tenant(&self) -> Vec<(u32, usize)> {
        let mut map: BTreeMap<u32, usize> = BTreeMap::new();
        for r in &self.results {
            *map.entry(r.tenant).or_insert(0) += 1;
        }
        map.into_iter().collect()
    }

    /// Render the plan-epoch ledger as a table for the CLI `serve`
    /// output, or `None` when the serve ran without a registry (the
    /// round-robin baseline). One row per (tenant, epoch); a balanced
    /// row has `admitted == completed + failed + drained`.
    pub fn epoch_table(&self) -> Option<String> {
        if self.epochs.is_empty() {
            return None;
        }
        let mut t = String::from(
            "plan epochs:\n  tenant  epoch  admitted  completed  failed  drained  live\n",
        );
        for e in &self.epochs {
            t.push_str(&format!(
                "  {:>6}  {:>5}  {:>8}  {:>9}  {:>6}  {:>7}  {}\n",
                e.tenant,
                e.epoch,
                e.admitted,
                e.completed,
                e.failed,
                e.drained,
                if e.live { "yes" } else { "no" },
            ));
        }
        Some(t)
    }

    /// Mean frames per pop across the whole pool (from the histograms).
    pub fn mean_batch(&self) -> f64 {
        let mut frames = 0usize;
        let mut pops = 0usize;
        for (i, &c) in self.total_hist().iter().enumerate() {
            frames += (i + 1) * c;
            pops += c;
        }
        if pops == 0 {
            0.0
        } else {
            frames as f64 / pops as f64
        }
    }
}

/// What one shard worker hands back when its loop ends.
struct ShardOutcome {
    shard: usize,
    results: Vec<FrameResult>,
    tasks_skipped: usize,
    layer_execs: u64,
    layer_skips: u64,
    /// `batch_hist[b-1]` = pops of exactly `b` frames this shard served.
    batch_hist: Vec<usize>,
    /// Executor failure that killed the shard, if any.
    error: Option<String>,
    /// Frames consumed but not served because of that failure.
    failed: usize,
    /// This shard's weight-tier counters (tier-enabled serves only).
    tier: Option<TierCounters>,
}

impl ShardOutcome {
    fn new(shard: usize, max_batch: usize) -> ShardOutcome {
        ShardOutcome {
            shard,
            results: Vec::new(),
            tasks_skipped: 0,
            layer_execs: 0,
            layer_skips: 0,
            batch_hist: vec![0; max_batch.max(1)],
            error: None,
            failed: 0,
            tier: None,
        }
    }
}

/// Serve `frames` across `n_shards` executors built by `make_executor`
/// (one per shard, each owning its backend — the backend must be `Send`,
/// which the reference backend is and PJRT deliberately is not).
///
/// Compatibility wrapper over [`serve_sharded_opts`] running the
/// round-robin baseline with batching off, like PR 3's scheduler.
pub fn serve_sharded<B, F>(
    make_executor: F,
    n_shards: usize,
    plan: &ServePlan,
    frames: Vec<(u64, Tensor)>,
    queue_depth: usize,
    pace: Option<Duration>,
) -> Result<ShardReport>
where
    B: Backend + Send + 'static,
    F: FnMut(usize) -> Result<BlockExecutor<B>>,
{
    let opts = ShardOpts {
        queue_depth,
        pace,
        steal: false,
        batch: 1,
        ..ShardOpts::default()
    };
    serve_sharded_opts(make_executor, n_shards, plan, frames, &opts)
}

/// Serve `frames` across `n_shards` executors with explicit scheduler
/// options. Returns when every shard has drained and reported.
pub fn serve_sharded_opts<B, F>(
    make_executor: F,
    n_shards: usize,
    plan: &ServePlan,
    frames: Vec<(u64, Tensor)>,
    opts: &ShardOpts,
) -> Result<ShardReport>
where
    B: Backend + Send + 'static,
    F: FnMut(usize) -> Result<BlockExecutor<B>>,
{
    if opts.steal {
        serve_work_stealing(make_executor, n_shards, plan, frames, opts)
    } else {
        serve_round_robin(make_executor, n_shards, plan, frames, opts)
    }
}

/// Serve a set of independent frame [`Source`]s through the
/// multi-producer ingest tier (`coordinator::ingest`) in front of the
/// work-stealing scheduler: `producers` threads pace/admit the sources
/// and feed the shared injector concurrently with the serving shards.
/// Returns the shard report plus the per-source ingest accounting;
/// ingest drops (stale + backpressure) are the aggregate report's
/// `dropped`, so `frames + dropped == total offered` holds per source
/// and overall.
///
/// The ingest tier fronts the work-stealing scheduler only — the
/// round-robin baseline keeps its single-producer deal loop.
pub fn serve_sharded_sources<B, F>(
    make_executor: F,
    n_shards: usize,
    plan: &ServePlan,
    sources: Vec<Source>,
    producers: usize,
    opts: &ShardOpts,
) -> Result<(ShardReport, IngestReport)>
where
    B: Backend + Send + 'static,
    F: FnMut(usize) -> Result<BlockExecutor<B>>,
{
    if !opts.steal {
        return Err(anyhow!(
            "multi-producer ingest fronts the work-stealing scheduler; \
             drop --round-robin to use --producers"
        ));
    }
    let (report, ingest) =
        serve_work_stealing_core(make_executor, n_shards, plan, opts, |d| {
            let ingest = run_ingest(sources, producers, &|f| d.offer(f));
            (ingest.dropped(), Some(ingest))
        })?;
    let ingest = ingest
        .ok_or_else(|| anyhow!("ingest feeder returned no report"))?;
    Ok((report, ingest))
}

// ------------------------------------------------- multi-tenant serving

/// Tenant-routed serving over a shared shard fleet: `frames` is
/// `(id, tenant, input)`; each frame is pinned at admission to its
/// tenant's current plan version in `registry` and served on that exact
/// plan even if a new epoch is published mid-stream. `obs` optionally
/// streams per-task simulated service times to a cost-drift replanner
/// (`coordinator::replan::spawn_replanner`).
///
/// Registry routing runs on the work-stealing scheduler only — the
/// round-robin baseline deliberately keeps its pre-registry shape.
pub fn serve_sharded_registry<B, F>(
    make_executor: F,
    n_shards: usize,
    registry: Arc<PlanRegistry>,
    frames: Vec<(u64, u32, Tensor)>,
    opts: &ShardOpts,
    obs: Option<Sender<CostObs>>,
) -> Result<ShardReport>
where
    B: Backend + Send + 'static,
    F: FnMut(usize) -> Result<BlockExecutor<B>>,
{
    let pace = opts.pace;
    serve_sharded_registry_feed(
        make_executor,
        n_shards,
        registry,
        opts,
        obs,
        |d| {
            let mut dropped = 0usize;
            for (id, tenant, input) in frames {
                if !d.offer(Frame::new(id, input).with_tenant(tenant)) {
                    dropped += 1;
                }
                if let Some(p) = pace {
                    thread::sleep(p);
                }
            }
            (dropped, None)
        },
    )
    .map(|(r, _)| r)
}

/// [`serve_sharded_registry`] with a caller-supplied feeder — the hook
/// the hot-swap tests use to publish a new plan epoch at a
/// deterministic point mid-stream (offer some frames, `publish`, offer
/// the rest) while the shards serve concurrently.
pub fn serve_sharded_registry_feed<B, F, Feed>(
    make_executor: F,
    n_shards: usize,
    registry: Arc<PlanRegistry>,
    opts: &ShardOpts,
    obs: Option<Sender<CostObs>>,
    feed: Feed,
) -> Result<(ShardReport, Option<IngestReport>)>
where
    B: Backend + Send + 'static,
    F: FnMut(usize) -> Result<BlockExecutor<B>>,
    Feed: FnOnce(&WsDispatch) -> (usize, Option<IngestReport>),
{
    if !opts.steal {
        return Err(anyhow!(
            "tenant-routed serving runs on the work-stealing scheduler; \
             drop --round-robin to use --tenants"
        ));
    }
    serve_registry_core(make_executor, n_shards, registry, opts, obs, feed)
}

/// Multi-producer ingest in front of the registry scheduler: sources
/// carry their tenant tag ([`Source::with_tenant`]) and every produced
/// frame is pinned at admission like the single-producer path.
pub fn serve_sharded_sources_registry<B, F>(
    make_executor: F,
    n_shards: usize,
    registry: Arc<PlanRegistry>,
    sources: Vec<Source>,
    producers: usize,
    opts: &ShardOpts,
    obs: Option<Sender<CostObs>>,
) -> Result<(ShardReport, IngestReport)>
where
    B: Backend + Send + 'static,
    F: FnMut(usize) -> Result<BlockExecutor<B>>,
{
    if !opts.steal {
        return Err(anyhow!(
            "multi-producer ingest fronts the work-stealing scheduler; \
             drop --round-robin to use --producers"
        ));
    }
    let (report, ingest) =
        serve_registry_core(make_executor, n_shards, registry, opts, obs, |d| {
            let ingest = run_ingest(sources, producers, &|f| d.offer(f));
            (ingest.dropped(), Some(ingest))
        })?;
    let ingest =
        ingest.ok_or_else(|| anyhow!("ingest feeder returned no report"))?;
    Ok((report, ingest))
}

// --------------------------------------------------------- round-robin

/// The PR-3 baseline: deal frames to per-shard bounded queues in strict
/// rotation. Kept as the comparison point for the work-stealing
/// scheduler; its known pathology (frames offered to a full or dead
/// shard are dropped while siblings idle) is measured, not fixed.
fn serve_round_robin<B, F>(
    mut make_executor: F,
    n_shards: usize,
    plan: &ServePlan,
    frames: Vec<(u64, Tensor)>,
    opts: &ShardOpts,
) -> Result<ShardReport>
where
    B: Backend + Send + 'static,
    F: FnMut(usize) -> Result<BlockExecutor<B>>,
{
    let n = n_shards.max(1);
    let (queue_depth, _) = opts.effective_depths();
    let pool = ThreadPool::new(n);
    let (res_tx, res_rx) = channel();
    let mut frame_txs = Vec::with_capacity(n);
    for s in 0..n {
        let (tx, rx) = sync_channel::<Frame>(queue_depth);
        frame_txs.push(tx);
        let mut ex = make_executor(s)?;
        let plan = plan.clone();
        let res_tx = res_tx.clone();
        let handicap = opts.handicap;
        let tier_cfg = opts.tier;
        pool.execute(move || {
            if let Some(cfg) = tier_cfg {
                ex.enable_tier(cfg);
            }
            let mut out = ShardOutcome::new(s, 1);
            while let Ok(frame) = rx.recv() {
                if let Some((hs, d)) = handicap {
                    if hs == s {
                        thread::sleep(d);
                    }
                }
                match process_frame(&mut ex, &plan, frame) {
                    Ok((r, sk)) => {
                        out.results.push(r);
                        out.tasks_skipped += sk;
                        // lint:allow(panic) — batch_hist is sized >= 1
                        // at construction; bucket 0 is frame-at-a-time
                        out.batch_hist[0] += 1;
                    }
                    Err(e) => {
                        out.error = Some(format!("{e:#}"));
                        // keep consuming so frames already accepted into
                        // this shard's queue are accounted as dropped
                        // rather than silently vanishing
                        out.failed = 1 + rx.iter().count();
                        break;
                    }
                }
            }
            // settle in-flight prefetches and run the custody close-check
            // before the counters are read (debug builds panic here on a
            // loads-issued != completed + cancelled imbalance)
            ex.tier_close();
            out.tier = ex.tier_counters();
            out.layer_execs = ex.layer_execs;
            out.layer_skips = ex.layer_skips;
            let _ = res_tx.send(out);
        });
    }
    drop(res_tx);

    let t0 = Instant::now();
    let mut dropped = 0usize;
    // debug-build custody ledger for the deal loop (`coordinator::audit`)
    let mut ledger = FeedLedger::new(frames.len());
    for (i, (id, input)) in frames.into_iter().enumerate() {
        match frame_txs[i % n].try_send(Frame::new(id, input)) {
            Ok(()) => ledger.deliver(),
            Err(TrySendError::Full(_)) => {
                dropped += 1;
                ledger.drop_n(1);
            }
            // a dead shard's queue: the frame is dropped even when live
            // shards had capacity — the round-robin pathology the
            // work-stealing scheduler exists to fix
            Err(TrySendError::Disconnected(_)) => {
                dropped += 1;
                ledger.drop_n(1);
            }
        }
        if let Some(p) = opts.pace {
            thread::sleep(p);
        }
    }
    ledger.finish(dropped);
    drop(frame_txs); // closes every queue; shard loops drain and exit

    collect_outcomes(n, res_rx, dropped, t0)
}

// -------------------------------------------------------- work stealing

/// Shared scheduler state: one bounded injector plus per-shard deques.
struct StealState {
    global: VecDeque<Frame>,
    locals: Vec<VecDeque<Frame>>,
    dead: Vec<bool>,
    closed: bool,
    /// Debug-build custody ledger (`coordinator::audit`): every frame
    /// accepted here must leave exactly once — popped then
    /// served/failed, or drained at shutdown. Zero-sized in release.
    audit: QueueLedger,
}

impl StealState {
    /// Total frames the structure actually holds (injector + deques) —
    /// what the custody ledger reconciles against at every transition.
    /// Debug builds only, like the ledger that is its only caller.
    #[cfg(debug_assertions)]
    fn depth(&self) -> usize {
        self.global.len() + self.locals.iter().map(|l| l.len()).sum::<usize>()
    }
}

struct StealQueue {
    st: Mutex<StealState>,
    cv: Condvar,
}

impl StealQueue {
    fn new(n: usize) -> StealQueue {
        StealQueue {
            st: Mutex::new(StealState {
                global: VecDeque::new(),
                locals: (0..n).map(|_| VecDeque::new()).collect(),
                dead: vec![false; n],
                closed: false,
                audit: QueueLedger::default(),
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue one frame: onto the preferred shard's deque when that
    /// shard is live and its deque has room, else onto the bounded
    /// injector. Returns false (frame dropped) only when the injector is
    /// full — there is no per-shard overflow, so a slow shard cannot
    /// strand frames the others could serve.
    ///
    /// Plan-epoch admission is booked HERE, inside the lock's accepting
    /// branches, before the frame becomes poppable: were it booked after
    /// `push` returned, a fast worker could pop and complete the frame
    /// before its admission landed, and the epoch ledger would observe a
    /// retirement with no matching admission. Frames with no pinned
    /// version (direct queue tests, loom models) book nothing.
    fn push(
        &self,
        frame: Frame,
        preferred: Option<usize>,
        queue_depth: usize,
        local_depth: usize,
    ) -> bool {
        let mut st = lock_unpoisoned(&self.st);
        if let Some(p) = preferred {
            if p < st.locals.len() && !st.dead[p] && st.locals[p].len() < local_depth
            {
                if let Some(v) = frame.version.as_ref() {
                    v.note_admitted();
                }
                st.locals[p].push_back(frame);
                #[cfg(debug_assertions)]
                {
                    let d = st.depth();
                    st.audit.enqueue(d);
                }
                drop(st);
                self.cv.notify_all();
                return true;
            }
        }
        if st.global.len() < queue_depth {
            if let Some(v) = frame.version.as_ref() {
                v.note_admitted();
            }
            st.global.push_back(frame);
            #[cfg(debug_assertions)]
            {
                let d = st.depth();
                st.audit.enqueue(d);
            }
            drop(st);
            self.cv.notify_all();
            return true;
        }
        false
    }

    /// No more frames will be pushed; drain-and-exit.
    fn close(&self) {
        lock_unpoisoned(&self.st).closed = true;
        self.cv.notify_all();
    }

    /// Shard `s`'s executor failed: flag it and return its queued frames
    /// to the injector front so the survivors pick them up promptly.
    fn mark_dead(&self, s: usize) {
        let mut st = lock_unpoisoned(&self.st);
        st.dead[s] = true;
        let orphans: Vec<Frame> = st.locals[s].drain(..).collect();
        for f in orphans.into_iter().rev() {
            st.global.push_front(f);
        }
        #[cfg(debug_assertions)]
        {
            // the spill moves custody between deques, never in or out
            let d = st.depth();
            st.audit.reconcile(d);
        }
        drop(st);
        self.cv.notify_all();
    }

    /// A shard finished serving `n` popped frames (custody ledger only;
    /// free in release builds).
    fn note_served(&self, _n: usize) {
        #[cfg(debug_assertions)]
        lock_unpoisoned(&self.st).audit.serve(_n);
    }

    /// A shard consumed `n` popped frames but died before serving them.
    fn note_failed(&self, _n: usize) {
        #[cfg(debug_assertions)]
        lock_unpoisoned(&self.st).audit.fail(_n);
    }

    /// Pop up to `max` frames for shard `me`: own deque first, then the
    /// injector, then (only when otherwise idle) steal from the longest
    /// sibling deque. Blocks while empty; `None` once closed and fully
    /// drained. Also returns the backlog this shard still sees (injector
    /// + own deque) right after the pop — the load signal the adaptive
    /// [`BatchPolicy`] feeds on.
    ///
    /// Waiter-liveness: every transition that can make this loop's exit
    /// condition true notifies — `push` (work arrived), `mark_dead` (a
    /// sibling's deque spilled into the injector), `close` (drain and
    /// exit). `close` additionally runs from a drop guard in the
    /// scheduler ([`CloseOnDrop`]) so a feeder that panics before
    /// closing cannot strand parked waiters. The wait below is untimed:
    /// PR 5 carried a 50 ms `wait_timeout` as defense in depth against a
    /// lost wakeup, and the loom suite (`loom_tests`, `./ci.sh --loom`)
    /// now explores every interleaving of push/steal/mark_dead/close
    /// against a parked waiter — the timeout was proven removable, not
    /// assumed (CONCURRENCY.md §The condvar-timeout verdict).
    fn pop_batch(&self, me: usize, max: usize) -> Option<(Vec<Frame>, usize)> {
        let max = max.max(1);
        let mut st = lock_unpoisoned(&self.st);
        loop {
            let mut batch = Vec::new();
            while batch.len() < max {
                if let Some(f) = st.locals[me].pop_front() {
                    batch.push(f);
                    continue;
                }
                if let Some(f) = st.global.pop_front() {
                    batch.push(f);
                    continue;
                }
                break;
            }
            if batch.is_empty() {
                let victim = (0..st.locals.len())
                    .filter(|&v| v != me && !st.locals[v].is_empty())
                    .max_by_key(|&v| st.locals[v].len());
                if let Some(v) = victim {
                    while batch.len() < max {
                        match st.locals[v].pop_front() {
                            Some(f) => batch.push(f),
                            None => break,
                        }
                    }
                }
            }
            if !batch.is_empty() {
                #[cfg(debug_assertions)]
                {
                    let d = st.depth();
                    st.audit.pop(batch.len(), d);
                }
                let backlog = st.global.len() + st.locals[me].len();
                return Some((batch, backlog));
            }
            if st.closed {
                return None;
            }
            // loom-verified: loom_steal_queue_wake_and_close,
            // loom_close_on_drop_releases_parked_worker,
            // loom_mark_dead_spills_to_parked_sibling,
            // loom_worker_death_conserves_and_releases_sibling — every
            // wake source mutates under `st` before notifying, so this
            // untimed wait cannot miss a wakeup
            st = wait_unpoisoned(&self.cv, st);
        }
    }

    /// Total frames currently queued (injector + every live deque) — the
    /// backlog the network front-end's per-class admission rule reads.
    /// Always compiled (unlike the debug-only ledger reconciliation
    /// helpers): release-build QoS shedding depends on it. The value is
    /// advisory by nature — the lock is released before the caller acts
    /// on it — which only ever sheds a little early or late; the hard
    /// capacity bound stays with `push` itself.
    fn queued(&self) -> usize {
        let st = lock_unpoisoned(&self.st);
        st.global.len() + st.locals.iter().map(|l| l.len()).sum::<usize>()
    }

    /// Frames nobody will ever pop (every worker exited early). Counted
    /// as dropped so frame conservation holds even in total failure.
    /// This is also the custody ledger's close: after the drain, nothing
    /// may remain queued or in flight, and every frame ever accepted
    /// must be served, failed, or drained — checked in debug builds.
    fn drain_remaining(&self) -> usize {
        let mut st = lock_unpoisoned(&self.st);
        let mut n = 0usize;
        for f in st.global.drain(..) {
            if let Some(v) = f.version.as_ref() {
                v.note_outcome(EpochOutcome::Drained);
            }
            n += 1;
        }
        for l in st.locals.iter_mut() {
            for f in l.drain(..) {
                if let Some(v) = f.version.as_ref() {
                    v.note_outcome(EpochOutcome::Drained);
                }
                n += 1;
            }
        }
        #[cfg(debug_assertions)]
        {
            st.audit.drain(n, 0);
            st.audit.close_check();
        }
        n
    }
}

/// Per-shard weight-residency board: the group id resident in each
/// segment slot, published by the shard after every round so the
/// dispatcher can route tagged frames to already-warm executors.
struct ResidencyBoard {
    segs: Vec<AtomicIsize>,
}

impl ResidencyBoard {
    fn new(nseg: usize) -> ResidencyBoard {
        ResidencyBoard { segs: (0..nseg).map(|_| AtomicIsize::new(-1)).collect() }
    }

    fn publish(&self, resident: &[Option<usize>]) {
        for (slot, r) in self.segs.iter().zip(resident) {
            slot.store(r.map_or(-1, |g| g as isize), Ordering::Relaxed);
        }
    }

    /// True when every segment the plan needs a stable group for is
    /// already resident (`None` entries are don't-cares: segments whose
    /// group changes between tasks within a round anyway).
    fn warm_for(&self, needed: &[Option<usize>]) -> bool {
        self.segs.iter().zip(needed).all(|(slot, need)| match need {
            Some(g) => slot.load(Ordering::Relaxed) == *g as isize,
            None => true,
        })
    }
}

/// Per-shard prefetch mailbox: the dispatcher bumps it every time a
/// frame lands on that shard's preferred deque, and the shard drains it
/// (`take`) at each pop to size its tier prefetch horizon
/// (`BlockExecutor::note_backlog`) — arrivals since the last pop are
/// work the backlog count alone cannot see yet. Relaxed suffices: this
/// is a monotone counter used as a heuristic hint, and the only
/// invariant — hints added == hints consumed + hints remaining — holds
/// for atomic RMWs under any ordering
/// (`loom_tier_prefetch_signal_conserves_hints`).
struct PrefetchSignal(AtomicUsize);

impl PrefetchSignal {
    fn new() -> PrefetchSignal {
        PrefetchSignal(AtomicUsize::new(0))
    }

    fn add(&self, n: usize) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    fn take(&self) -> usize {
        self.0.swap(0, Ordering::Relaxed)
    }
}

/// Residency-aware admission into the work-stealing queue, shared by
/// every feeder (the inline single-producer loop and the multi-producer
/// ingest tier — `offer` takes `&self`, so K producers call it
/// concurrently). Returns whether the frame was accepted; a `false` is
/// a drop the feeder must account.
pub struct WsDispatch {
    queue: Arc<StealQueue>,
    boards: Vec<Arc<ResidencyBoard>>,
    signals: Vec<Arc<PrefetchSignal>>,
    needed: Vec<Option<usize>>,
    registry: Arc<PlanRegistry>,
    n: usize,
    queue_depth: usize,
    local_depth: usize,
}

impl WsDispatch {
    pub fn offer(&self, mut frame: Frame) -> bool {
        // pin the tenant's CURRENT plan version at admission time: the
        // frame will be served on this exact version even if a newer
        // epoch is published while it queues (the hot-swap contract —
        // in-flight frames finish on the plan they were admitted under)
        frame.version = Some(self.registry.current(frame.tenant));
        // residency-aware dispatch: a frame sticks to its tagged shard
        // only while that shard is warm and has deque room; otherwise it
        // goes to the injector where any idle shard takes it
        let preferred = if self.needed.is_empty() {
            None
        } else {
            let p = (frame.id as usize) % self.n;
            self.boards[p].warm_for(&self.needed).then_some(p)
        };
        let accepted = self
            .queue
            .push(frame, preferred, self.queue_depth, self.local_depth);
        // a frame aimed at a specific shard is future work that shard's
        // tier prefetcher can plan for before its next pop sees it in
        // the backlog count — signal it. Deliberately optimistic: push
        // may have diverted the frame to the injector (deque full), and
        // an inflated hint merely widens the prefetch horizon; untagged
        // injector frames reach every shard through the backlog instead

        if accepted {
            if let Some(p) = preferred {
                self.signals[p].add(1);
            }
        }
        accepted
    }

    /// Scheduler backlog right now (injector + live deques): what the
    /// per-class admission rule ([`QosClass::admit_at`]) compares against
    /// [`WsDispatch::capacity`].
    pub fn backlog(&self) -> usize {
        self.queue.queued()
    }

    /// The bounded injector's capacity — the denominator of the class
    /// admission thresholds.
    pub fn capacity(&self) -> usize {
        self.queue_depth
    }

    /// Class- and deadline-aware admission for the network front-end.
    /// Shedding order under backpressure is fixed by
    /// [`QosClass::admit_at`]: batch is refused first, then best-effort,
    /// and realtime only when the injector itself is hard-full — so
    /// realtime can never be shed at a backlog where best-effort is
    /// admitted. A frame whose client deadline already passed is shed as
    /// stale *before* the class check: it would only be dropped
    /// downstream after occupying a queue slot.
    ///
    /// The backlog read and the push are not atomic together (two lock
    /// acquisitions); the race only shifts a borderline admission by one
    /// frame against a moving queue — the hard bound is `push`'s own
    /// capacity check, and the conservation contract is indifferent to
    /// *which* bucket a shed frame lands in, only that it lands in one.
    pub fn offer_classed(&self, frame: Frame) -> Admission {
        if frame.past_deadline(Instant::now()) {
            return Admission::Stale;
        }
        if !frame.qos.admit_at(self.backlog(), self.capacity()) {
            return Admission::Backpressure;
        }
        if self.offer(frame) {
            Admission::Delivered
        } else {
            Admission::Backpressure
        }
    }
}

/// Outcome of one [`WsDispatch::offer_classed`] admission attempt — the
/// three buckets of the per-connection conservation contract
/// (`delivered + dropped_stale + dropped_backpressure (+ truncated)
/// == offered`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    Delivered,
    /// Client deadline passed before admission.
    Stale,
    /// Shed by the class rule, or the injector was hard-full.
    Backpressure,
}

/// Closes the steal queue when dropped: workers must always see `closed`
/// even when the feeder unwinds, or parked shards would wait forever and
/// the pool's join-on-drop would deadlock (the `pop_batch` audit).
struct CloseOnDrop<'a>(&'a StealQueue);

impl Drop for CloseOnDrop<'_> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// The single-producer front-end over the work-stealing core: one inline
/// loop offering `frames` in order, with optional pacing.
fn serve_work_stealing<B, F>(
    make_executor: F,
    n_shards: usize,
    plan: &ServePlan,
    frames: Vec<(u64, Tensor)>,
    opts: &ShardOpts,
) -> Result<ShardReport>
where
    B: Backend + Send + 'static,
    F: FnMut(usize) -> Result<BlockExecutor<B>>,
{
    let pace = opts.pace;
    let (report, _) =
        serve_work_stealing_core(make_executor, n_shards, plan, opts, |d| {
            let mut dropped = 0usize;
            for (id, input) in frames {
                if !d.offer(Frame::new(id, input)) {
                    dropped += 1;
                }
                if let Some(p) = pace {
                    thread::sleep(p);
                }
            }
            (dropped, None)
        })?;
    Ok(report)
}

/// Legacy single-plan entry into the registry core: wraps `plan` into a
/// one-tenant [`PlanRegistry`] at epoch 0 with no replanner. Every
/// pre-registry caller routes through here, which is exactly what the
/// single-tenant parity pin (`tests/multi_tenant.rs`) locks down.
pub(crate) fn serve_work_stealing_core<B, F, Feed>(
    make_executor: F,
    n_shards: usize,
    plan: &ServePlan,
    opts: &ShardOpts,
    feed: Feed,
) -> Result<(ShardReport, Option<IngestReport>)>
where
    B: Backend + Send + 'static,
    F: FnMut(usize) -> Result<BlockExecutor<B>>,
    Feed: FnOnce(&WsDispatch) -> (usize, Option<IngestReport>),
{
    let registry = Arc::new(PlanRegistry::single(plan.clone()));
    serve_registry_core(make_executor, n_shards, registry, opts, None, feed)
}

/// The shared-injector work-stealing scheduler with residency-aware
/// dispatch and adaptive cross-frame micro-batching, serving plans out
/// of a versioned multi-tenant [`PlanRegistry`]. Generic over the
/// feeder: it spawns the shard workers, hands the feeder a [`WsDispatch`]
/// to offer frames through, and aggregates once the feeder returns its
/// drop count (plus the ingest report, when the feeder is the
/// multi-producer tier).
///
/// Every admitted frame is pinned to its tenant's current
/// [`PlanVersion`] at `offer` time and served on that exact plan; a
/// [`PlanRegistry::publish`] concurrent with the serve redirects only
/// frames admitted after it (epoch-based hot-swap — no drain, no
/// pause). `obs` carries per-task simulated service times to the
/// cost-drift replanner (`coordinator::replan`); the batched path skips
/// observation (batched rounds amortize block loads across frames, so
/// per-frame task costs are not individually attributable).
pub(crate) fn serve_registry_core<B, F, Feed>(
    mut make_executor: F,
    n_shards: usize,
    registry: Arc<PlanRegistry>,
    opts: &ShardOpts,
    obs: Option<Sender<CostObs>>,
    feed: Feed,
) -> Result<(ShardReport, Option<IngestReport>)>
where
    B: Backend + Send + 'static,
    F: FnMut(usize) -> Result<BlockExecutor<B>>,
    Feed: FnOnce(&WsDispatch) -> (usize, Option<IngestReport>),
{
    let n = n_shards.max(1);
    // build executors up front: the dispatcher reads the graph shape for
    // residency routing before the workers take ownership
    let mut executors = Vec::with_capacity(n);
    for s in 0..n {
        executors.push(make_executor(s)?);
    }
    // a shard is "warm" when the blocks every task in the round shares
    // (the stable trunk) are resident; branch segments swap groups
    // within a round and are excluded from the test. Multi-tenant: the
    // union of every tenant's current order must agree on the segment's
    // group, because any tenant's frames can land on any shard — this
    // degenerates to the old single-plan rule when there is one tenant.
    // The vector is computed against epoch-0 plans and deliberately NOT
    // recomputed on a swap: it is a routing preference, and a stale
    // preference only costs warmth, never correctness (the residency
    // hints survive the swap; the pinned plan decides what actually runs)
    // lint:allow(panic) — `n = n_shards.max(1)` above, so the loop
    // pushed at least one executor
    let graph = &executors[0].graph;
    let nseg = graph.n_segments();
    let all_tasks: Vec<usize> = (0..registry.n_tenants())
        .flat_map(|t| registry.current(t as u32).plan.order.clone())
        .collect();
    let needed: Vec<Option<usize>> = match all_tasks.first() {
        Some(&t0) => (0..nseg)
            .map(|s| {
                let g0 = graph.group_of(s, t0);
                all_tasks
                    .iter()
                    .all(|&t| graph.group_of(s, t) == g0)
                    .then_some(g0)
            })
            .collect(),
        None => Vec::new(),
    };
    let boards: Vec<Arc<ResidencyBoard>> =
        (0..n).map(|_| Arc::new(ResidencyBoard::new(nseg))).collect();
    let signals: Vec<Arc<PrefetchSignal>> =
        (0..n).map(|_| Arc::new(PrefetchSignal::new())).collect();
    let queue = Arc::new(StealQueue::new(n));
    let pool = ThreadPool::new(n);
    let (res_tx, res_rx) = channel();
    let batch = opts.batch.max(1);
    let adaptive = opts.adaptive_batch;
    for (s, mut ex) in executors.into_iter().enumerate() {
        let queue = Arc::clone(&queue);
        let board = Arc::clone(&boards[s]);
        let signal = Arc::clone(&signals[s]);
        let registry = Arc::clone(&registry);
        let obs = obs.clone();
        let res_tx = res_tx.clone();
        let handicap = opts.handicap;
        let tier_cfg = opts.tier;
        pool.execute(move || {
            if let Some(cfg) = tier_cfg {
                ex.enable_tier(cfg);
            }
            let mut out = ShardOutcome::new(s, batch);
            let mut policy = if adaptive {
                BatchPolicy::adaptive(batch)
            } else {
                BatchPolicy::fixed(batch)
            };
            'serve: while let Some((popped, backlog)) =
                queue.pop_batch(s, policy.next())
            {
                // drain the prefetch mailbox and fold it into the tier's
                // lookahead: backlog counts what is queued *now*, the
                // hint adds deque arrivals aimed here since the last pop
                let hint = signal.take();
                ex.note_backlog(backlog + hint);
                // the service clock starts before the handicap sleep: a
                // straggler's slowness must show up in the policy's
                // service-time signal or it would keep hogging big batches
                let served_at = Instant::now();
                if let Some((hs, d)) = handicap {
                    if hs == s {
                        thread::sleep(d * popped.len() as u32);
                    }
                }
                let m = popped.len();
                // group the pop by pinned (tenant, epoch): frames from
                // different plan versions cannot share a batched round,
                // and each frame's outcome must retire on the exact
                // version it was admitted under. A frame with no pinned
                // version (direct queue pushes in tests) is admitted on
                // its tenant's current version here, so the ledger stays
                // balanced on every path.
                let mut groups: Vec<(Arc<PlanVersion>, Vec<Frame>)> =
                    Vec::new();
                for mut frame in popped {
                    let v = match frame.version.clone() {
                        Some(v) => v,
                        None => {
                            let v = registry.current(frame.tenant);
                            v.note_admitted();
                            frame.version = Some(Arc::clone(&v));
                            v
                        }
                    };
                    match groups.iter_mut().find(|(gv, _)| {
                        gv.tenant == v.tenant && gv.epoch == v.epoch
                    }) {
                        Some((_, fs)) => fs.push(frame),
                        None => groups.push((v, vec![frame])),
                    }
                }
                let mut groups = groups.into_iter();
                while let Some((v, gframes)) = groups.next() {
                    let k = gframes.len();
                    let step: Result<()> = (|| {
                        if k == 1 {
                            let Some(frame) = gframes.into_iter().next()
                            else {
                                // groups are built non-empty; if one ever
                                // were not, treat it as a served no-op
                                // rather than panicking the shard
                                return Ok(());
                            };
                            let tenant = frame.tenant;
                            let mut sink = obs.as_ref().map(|tx| {
                                let tx = tx.clone();
                                move |task: usize, secs: f64| {
                                    let _ = tx.send(CostObs {
                                        tenant,
                                        task,
                                        secs,
                                    });
                                }
                            });
                            let (r, sk) = process_frame_observed(
                                &mut ex,
                                &v.plan,
                                frame,
                                sink.as_mut()
                                    .map(|f| f as &mut dyn FnMut(usize, f64)),
                            )?;
                            out.results.push(r);
                            out.tasks_skipped += sk;
                        } else {
                            let ids: Vec<u64> =
                                gframes.iter().map(|f| f.id).collect();
                            let tenants: Vec<u32> =
                                gframes.iter().map(|f| f.tenant).collect();
                            let enq: Vec<Instant> =
                                gframes.iter().map(|f| f.enqueued).collect();
                            let inputs: Vec<&Tensor> =
                                gframes.iter().map(|f| &f.input).collect();
                            let started = Instant::now();
                            let round = ex.run_round_batched(
                                &ids,
                                &inputs,
                                &v.plan.order,
                                &v.plan.conditional,
                            )?;
                            for i in 0..k {
                                out.results.push(FrameResult {
                                    id: ids[i],
                                    tenant: tenants[i],
                                    epoch: v.epoch,
                                    predictions: round.predictions[i].clone(),
                                    sim_cost: round.costs[i],
                                    wall_latency_s: enq[i]
                                        .elapsed()
                                        .as_secs_f64(),
                                    queue_wait_s: started
                                        .duration_since(enq[i])
                                        .as_secs_f64(),
                                });
                            }
                            out.tasks_skipped += round.tasks_skipped;
                        }
                        Ok(())
                    })();
                    match step {
                        Ok(()) => {
                            for _ in 0..k {
                                v.note_outcome(EpochOutcome::Completed);
                            }
                            queue.note_served(k);
                        }
                        Err(e) => {
                            // this shard is broken: surface the error,
                            // account every popped-but-unserved frame —
                            // this group and every group not yet run —
                            // as failed on its pinned version, give the
                            // queued frames back, let the others serve
                            queue.note_failed(k);
                            for _ in 0..k {
                                v.note_outcome(EpochOutcome::Failed);
                            }
                            out.error = Some(format!("{e:#}"));
                            out.failed += k;
                            for (rv, rframes) in groups.by_ref() {
                                let rk = rframes.len();
                                queue.note_failed(rk);
                                for _ in 0..rk {
                                    rv.note_outcome(EpochOutcome::Failed);
                                }
                                out.failed += rk;
                            }
                            queue.mark_dead(s);
                            break 'serve;
                        }
                    }
                }
                board.publish(&ex.resident_snapshot());
                out.batch_hist[m - 1] += 1;
                policy.observe(m, backlog, served_at.elapsed().as_secs_f64());
            }
            // settle in-flight prefetches and close the custody ledger
            // (debug builds panic on issued != completed + cancelled)
            ex.tier_close();
            out.tier = ex.tier_counters();
            out.layer_execs = ex.layer_execs;
            out.layer_skips = ex.layer_skips;
            let _ = res_tx.send(out);
        });
    }
    drop(res_tx);
    // the workers hold the only remaining obs senders: when the last
    // worker exits, the replanner's receive loop ends and it can report
    drop(obs);

    let (queue_depth, local_depth) = opts.effective_depths();
    let dispatch = WsDispatch {
        queue: Arc::clone(&queue),
        boards,
        signals,
        needed,
        registry: Arc::clone(&registry),
        n,
        queue_depth,
        local_depth,
    };
    let t0 = Instant::now();
    // the queue must close even if the feeder unwinds (a panicking
    // producer), or parked workers would never see `closed` and the
    // pool's join-on-drop would hang — see the pop_batch audit
    let closer = CloseOnDrop(queue.as_ref());
    let (dropped, ingest) = feed(&dispatch);
    drop(closer); // normal path: close now, workers drain and report

    let report = collect_outcomes(n, res_rx, dropped, t0);
    // if every worker died early, queued frames were never consumed —
    // drain books each leftover as Drained on its pinned version, so the
    // registry close-check below still balances in total failure
    let leftover = queue.drain_remaining();
    registry.close_check();
    report.map(|mut r| {
        r.aggregate.dropped += leftover;
        r.epochs = registry.epoch_report();
        (r, ingest)
    })
}

// --------------------------------------------------------- aggregation

fn collect_outcomes(
    n: usize,
    res_rx: crate::sync::mpsc::Receiver<ShardOutcome>,
    mut dropped: usize,
    t0: Instant,
) -> Result<ShardReport> {
    let mut frames_per_shard = vec![0usize; n];
    let mut batch_hist = vec![Vec::new(); n];
    let mut shard_errors = Vec::new();
    let mut all = Vec::new();
    let mut skipped = 0usize;
    let mut layer_execs = 0u64;
    let mut layer_skips = 0u64;
    let mut tier: Option<TierCounters> = None;
    for _ in 0..n {
        let out = res_rx
            .recv()
            .map_err(|_| anyhow!("a shard worker died before reporting"))?;
        frames_per_shard[out.shard] = out.results.len();
        batch_hist[out.shard] = out.batch_hist;
        skipped += out.tasks_skipped;
        layer_execs += out.layer_execs;
        layer_skips += out.layer_skips;
        dropped += out.failed;
        if let Some(tc) = out.tier {
            tier.get_or_insert_with(TierCounters::default).merge(&tc);
        }
        if let Some(e) = out.error {
            shard_errors.push((out.shard, e));
        }
        all.extend(out.results);
    }
    shard_errors.sort_by_key(|&(s, _)| s);
    all.sort_by_key(|r| r.id);
    let wall = t0.elapsed().as_secs_f64();
    let aggregate =
        build_report(&all, dropped, wall, skipped, layer_execs, layer_skips);
    Ok(ShardReport {
        shards: n,
        frames_per_shard,
        batch_hist,
        shard_errors,
        results: all,
        aggregate,
        tier,
        epochs: Vec::new(),
    })
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::device::Device;
    use crate::model::ArchSpec;
    use crate::runtime::ReferenceBackend;
    use crate::taskgraph::{Partition, TaskGraph};
    use crate::trainer::GraphWeights;
    use crate::util::rng::Pcg32;

    fn make_executor(_shard: usize) -> Result<BlockExecutor<ReferenceBackend>> {
        let backend = ReferenceBackend::new();
        let arch = backend.arch("cnn5")?;
        let graph = TaskGraph::new(
            3,
            vec![1, 3, 4],
            vec![
                Partition(vec![0, 0, 0]),
                Partition(vec![0, 0, 0]),
                Partition(vec![0, 0, 1]),
                Partition::singletons(3),
            ],
        )?;
        let ncls = vec![2, 2, 2];
        // identical seed per shard: every shard serves the same weights
        let mut rng = Pcg32::seed(7);
        let store = GraphWeights::init(&graph, &arch, &ncls, &mut rng);
        Ok(BlockExecutor::new(
            backend,
            Device::msp430(),
            arch,
            graph,
            ncls,
            store,
        ))
    }

    fn frames(n: usize) -> Vec<(u64, Tensor)> {
        let mut rng = Pcg32::seed(15);
        (0..n as u64)
            .map(|i| {
                let data = (0..256).map(|_| rng.gauss()).collect();
                (i, Tensor::new(vec![1, 16, 16, 1], data))
            })
            .collect()
    }

    #[test]
    fn sharded_serve_covers_all_frames_across_executors() {
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        // deep queues: 24 frames over 3 shards never overflow depth 16
        let report =
            serve_sharded(make_executor, 3, &plan, frames(24), 16, None).unwrap();
        assert_eq!(report.shards, 3);
        assert_eq!(report.aggregate.dropped, 0);
        assert_eq!(report.aggregate.frames, 24);
        // round-robin with no drops: exactly even split, ≥2 shards busy
        assert_eq!(report.frames_per_shard, vec![8, 8, 8]);
        assert!(report.busy_shards() >= 2);
        // aggregate metrics are real
        assert!(report.aggregate.throughput_fps > 0.0);
        assert!(report.aggregate.sim_time_per_frame_s > 0.0);
        assert!(report.aggregate.layer_execs > 0);
        // per-frame activation reuse still happens inside each shard
        assert!(report.aggregate.layer_skips > 0);
        assert!(report.shard_errors.is_empty());
    }

    #[test]
    fn sharded_serve_conserves_frames_with_tiny_queues() {
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let total = 30;
        let report =
            serve_sharded(make_executor, 2, &plan, frames(total), 1, None).unwrap();
        assert_eq!(
            report.aggregate.frames + report.aggregate.dropped,
            total
        );
        assert_eq!(
            report.frames_per_shard.iter().sum::<usize>(),
            report.aggregate.frames
        );
    }

    #[test]
    fn single_shard_degenerates_to_plain_serve() {
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let report =
            serve_sharded(make_executor, 1, &plan, frames(6), 8, None).unwrap();
        assert_eq!(report.shards, 1);
        assert_eq!(report.aggregate.frames, 6);
        assert_eq!(report.frames_per_shard, vec![6]);
    }

    #[test]
    fn conditional_plans_work_sharded() {
        let plan = ServePlan {
            order: vec![0, 1, 2],
            conditional: vec![(0, 1), (0, 2)],
        };
        let report =
            serve_sharded(make_executor, 3, &plan, frames(18), 16, None).unwrap();
        assert_eq!(report.aggregate.frames, 18);
        assert!(report.aggregate.tasks_skipped <= 36);
    }

    #[test]
    fn work_stealing_covers_all_frames() {
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let opts = ShardOpts { queue_depth: 64, ..ShardOpts::default() };
        let report =
            serve_sharded_opts(make_executor, 3, &plan, frames(24), &opts)
                .unwrap();
        assert_eq!(report.aggregate.dropped, 0);
        assert_eq!(report.aggregate.frames, 24);
        assert!(report.shard_errors.is_empty());
        // results arrive sorted by frame id, every id exactly once
        let ids: Vec<u64> = report.results.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..24u64).collect::<Vec<_>>());
        assert_eq!(
            report.frames_per_shard.iter().sum::<usize>(),
            report.aggregate.frames
        );
    }

    #[test]
    fn work_stealing_batched_matches_single_executor_predictions() {
        let plan = ServePlan {
            order: vec![0, 1, 2],
            conditional: vec![(0, 2)],
        };
        let fr = frames(17);
        // baseline: one executor, frame at a time
        let mut ex = make_executor(0).unwrap();
        let (tx, rx) = channel();
        for (id, x) in fr.clone() {
            tx.send(Frame::new(id, x)).unwrap();
        }
        drop(tx);
        let (mut base, _) =
            crate::coordinator::server::run_executor(&mut ex, &plan, rx).unwrap();
        base.sort_by_key(|r| r.id);

        let opts = ShardOpts {
            queue_depth: 64,
            batch: 4,
            ..ShardOpts::default()
        };
        let report =
            serve_sharded_opts(make_executor, 2, &plan, fr, &opts).unwrap();
        assert_eq!(report.aggregate.dropped, 0);
        assert_eq!(report.results.len(), base.len());
        for (got, want) in report.results.iter().zip(&base) {
            assert_eq!(got.id, want.id);
            assert_eq!(
                got.predictions, want.predictions,
                "frame {} diverged under sharded batching",
                got.id
            );
        }
    }

    /// Tiered serving is a cost-model overlay, never a scheduler: at
    /// every fast-tier capacity — streaming-only 0, a bound tighter than
    /// the weight footprint, and unbounded — and with prefetch on or
    /// off, the sharded batched serve must produce frame-for-frame the
    /// predictions of the flat (tier-less) serve, and the report must
    /// carry the pool-wide tier counters.
    #[test]
    fn tiered_sharded_serve_matches_flat_and_reports_counters() {
        let plan = ServePlan {
            order: vec![0, 1, 2],
            conditional: vec![(0, 2)],
        };
        let fr = frames(15);
        let flat_opts = ShardOpts {
            queue_depth: 64,
            batch: 3,
            ..ShardOpts::default()
        };
        let flat =
            serve_sharded_opts(make_executor, 2, &plan, fr.clone(), &flat_opts)
                .unwrap();
        assert_eq!(flat.aggregate.dropped, 0);
        assert!(flat.tier.is_none(), "flat serve must not report a tier");
        for cap in [0usize, 3_000, usize::MAX] {
            for prefetch in [false, true] {
                let opts = ShardOpts {
                    tier: Some(TierConfig::for_device(
                        &Device::msp430(),
                        cap,
                        prefetch,
                    )),
                    ..flat_opts.clone()
                };
                let report =
                    serve_sharded_opts(make_executor, 2, &plan, fr.clone(), &opts)
                        .unwrap();
                assert_eq!(report.aggregate.dropped, 0);
                assert_eq!(report.results.len(), flat.results.len());
                for (got, want) in report.results.iter().zip(&flat.results) {
                    assert_eq!(got.id, want.id);
                    assert_eq!(
                        got.predictions, want.predictions,
                        "frame {} diverged under tier cap={cap} prefetch={prefetch}",
                        got.id
                    );
                }
                let tc = report.tier.expect("tier counters missing");
                assert!(
                    tc.hits + tc.misses > 0,
                    "no tier traffic at cap={cap} prefetch={prefetch}"
                );
                if cap == 0 {
                    // capacity 0 degenerates to streaming: nothing can
                    // ever become resident, so nothing can ever hit
                    assert_eq!(tc.hits, 0);
                    assert_eq!(tc.prefetch_hits, 0);
                }
                if cap == usize::MAX {
                    // an unbounded tier never needs to evict
                    assert_eq!(tc.evictions, tc.prefetch_cancelled);
                }
            }
        }
    }

    /// A backend that fails every `run_layer` when `fail` is set — the
    /// injected-fault half of the dead-shard regression tests.
    struct FailingBackend {
        inner: ReferenceBackend,
        fail: bool,
    }
    impl Backend for FailingBackend {
        fn name(&self) -> &'static str {
            "failing"
        }
        fn arch(&self, name: &str) -> Result<ArchSpec> {
            self.inner.arch(name)
        }
        fn arch_names(&self) -> Vec<String> {
            self.inner.arch_names()
        }
        fn run_layer(
            &self,
            arch: &ArchSpec,
            layer: usize,
            ncls: Option<usize>,
            x: &Tensor,
            w: &Tensor,
            b: &Tensor,
        ) -> Result<Tensor> {
            anyhow::ensure!(!self.fail, "injected shard fault");
            self.inner.run_layer(arch, layer, ncls, x, w, b)
        }
        fn train_step(
            &self,
            arch: &ArchSpec,
            ncls: usize,
            params: &mut Vec<Tensor>,
            x: &Tensor,
            y: &[i32],
            lr: f32,
        ) -> Result<f32> {
            self.inner.train_step(arch, ncls, params, x, y, lr)
        }
        fn eval_logits(
            &self,
            arch: &ArchSpec,
            ncls: usize,
            params: &[Tensor],
            x: &Tensor,
        ) -> Result<Tensor> {
            self.inner.eval_logits(arch, ncls, params, x)
        }
    }

    /// Regression for the round-robin dead-shard pathology: with work
    /// stealing, killing one shard must not strand the frames it would
    /// have been dealt — the survivors absorb them, frame conservation
    /// holds, and at most the poisoned frame itself is lost.
    #[test]
    fn dead_shard_frames_are_absorbed_by_survivors() {
        let make = |shard: usize| -> Result<BlockExecutor<FailingBackend>> {
            let template = make_executor(0)?;
            Ok(BlockExecutor::new(
                FailingBackend {
                    inner: ReferenceBackend::new(),
                    fail: shard == 0,
                },
                Device::msp430(),
                template.arch.clone(),
                template.graph.clone(),
                template.ncls.clone(),
                template.store.clone(),
            ))
        };

        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let total = 40;
        let opts = ShardOpts { queue_depth: 64, ..ShardOpts::default() };
        let report =
            serve_sharded_opts(make, 3, &plan, frames(total), &opts).unwrap();
        // conservation with dropped ≈ 0: only the frame that poisoned
        // shard 0 can be lost
        assert_eq!(report.aggregate.frames + report.aggregate.dropped, total);
        assert!(
            report.aggregate.dropped <= 1,
            "survivors failed to absorb: {} dropped",
            report.aggregate.dropped
        );
        assert_eq!(report.frames_per_shard[0], 0);
        assert!(report.aggregate.frames >= total - 1);
        if report.aggregate.dropped == 1 {
            assert_eq!(report.shard_errors.len(), 1);
            assert_eq!(report.shard_errors[0].0, 0);
            assert!(report.shard_errors[0].1.contains("injected shard fault"));
        }
    }

    /// The CLI-surfacing satellite, made deterministic with a total
    /// outage on a single shard: the executor's error string must reach
    /// `shard_errors` AND the rendered `shard_error_table` the `serve`
    /// command prints — the report was populated but never surfaced
    /// before this PR.
    #[test]
    fn dead_shard_error_string_reaches_report_and_table() {
        let make = |_s: usize| -> Result<BlockExecutor<FailingBackend>> {
            let template = make_executor(0)?;
            Ok(BlockExecutor::new(
                FailingBackend {
                    inner: ReferenceBackend::new(),
                    fail: true, // every shard: the table is guaranteed
                },
                Device::msp430(),
                template.arch.clone(),
                template.graph.clone(),
                template.ncls.clone(),
                template.store.clone(),
            ))
        };
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let opts = ShardOpts { queue_depth: 64, ..ShardOpts::default() };
        let report =
            serve_sharded_opts(make, 2, &plan, frames(6), &opts).unwrap();
        assert_eq!(report.shard_errors.len(), 2);
        for (s, e) in &report.shard_errors {
            assert!(*s < 2);
            assert!(
                e.contains("injected shard fault"),
                "shard {s} error lost its cause: {e}"
            );
        }
        let table = report
            .shard_error_table()
            .expect("errors present, table must render");
        assert!(table.contains("shard errors"));
        assert!(table.contains("injected shard fault"));
        for s in 0..2 {
            assert!(table.contains(&format!("  {s:>5}  ")), "row for shard {s}");
        }

        // and the healthy case renders nothing
        let ok =
            serve_sharded_opts(make_executor, 2, &plan, frames(6), &opts)
                .unwrap();
        assert!(ok.shard_errors.is_empty());
        assert!(ok.shard_error_table().is_none());
    }

    /// The skewed-workload acceptance gate: one shard paced 10x slower.
    /// Work stealing must drop strictly fewer frames than round-robin at
    /// equal queue depth, because the straggler's share is stolen by the
    /// idle siblings instead of overflowing its private queue.
    #[test]
    fn work_stealing_beats_round_robin_under_skew() {
        // single-task rounds keep per-frame compute far below the 40 ms
        // handicap even in debug builds, so the skew dominates timing
        let plan = ServePlan::unconditional(vec![0]);
        let total = 45;
        let skew = |steal: bool| ShardOpts {
            queue_depth: 2,
            batch: if steal { 4 } else { 1 },
            adaptive_batch: false,
            steal,
            local_depth: 1,
            pace: Some(Duration::from_millis(8)),
            handicap: Some((0, Duration::from_millis(40))),
            tier: None,
        };
        let rr = serve_sharded_opts(
            make_executor,
            3,
            &plan,
            frames(total),
            &skew(false),
        )
        .unwrap();
        let ws = serve_sharded_opts(
            make_executor,
            3,
            &plan,
            frames(total),
            &skew(true),
        )
        .unwrap();
        assert_eq!(rr.aggregate.frames + rr.aggregate.dropped, total);
        assert_eq!(ws.aggregate.frames + ws.aggregate.dropped, total);
        // the baseline must actually exhibit the pathology...
        assert!(
            rr.aggregate.dropped > 0,
            "round-robin did not overflow the straggler's queue"
        );
        // ...and work stealing must strictly beat it
        assert!(
            ws.aggregate.dropped < rr.aggregate.dropped,
            "steal dropped {} vs round-robin {}",
            ws.aggregate.dropped,
            rr.aggregate.dropped
        );
    }

    #[test]
    fn all_shards_dead_still_conserves_frames() {
        struct AlwaysFail(ReferenceBackend);
        impl Backend for AlwaysFail {
            fn name(&self) -> &'static str {
                "always-fail"
            }
            fn arch(&self, name: &str) -> Result<ArchSpec> {
                self.0.arch(name)
            }
            fn arch_names(&self) -> Vec<String> {
                self.0.arch_names()
            }
            fn run_layer(
                &self,
                _arch: &ArchSpec,
                _layer: usize,
                _ncls: Option<usize>,
                _x: &Tensor,
                _w: &Tensor,
                _b: &Tensor,
            ) -> Result<Tensor> {
                anyhow::bail!("total outage")
            }
            fn train_step(
                &self,
                arch: &ArchSpec,
                ncls: usize,
                params: &mut Vec<Tensor>,
                x: &Tensor,
                y: &[i32],
                lr: f32,
            ) -> Result<f32> {
                self.0.train_step(arch, ncls, params, x, y, lr)
            }
            fn eval_logits(
                &self,
                arch: &ArchSpec,
                ncls: usize,
                params: &[Tensor],
                x: &Tensor,
            ) -> Result<Tensor> {
                self.0.eval_logits(arch, ncls, params, x)
            }
        }
        let make = |_s: usize| -> Result<BlockExecutor<AlwaysFail>> {
            let template = make_executor(0)?;
            Ok(BlockExecutor::new(
                AlwaysFail(ReferenceBackend::new()),
                Device::msp430(),
                template.arch.clone(),
                template.graph.clone(),
                template.ncls.clone(),
                template.store.clone(),
            ))
        };
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let total = 20;
        let opts = ShardOpts { queue_depth: 64, ..ShardOpts::default() };
        let report =
            serve_sharded_opts(make, 2, &plan, frames(total), &opts).unwrap();
        assert_eq!(report.aggregate.frames, 0);
        assert_eq!(report.aggregate.dropped, total);
        assert_eq!(report.shard_errors.len(), 2);
        // the zero-frame report is well-formed (the build_report guard)
        assert!(report.aggregate.throughput_fps.is_finite());
        assert_eq!(report.aggregate.latency_p99_ms, 0.0);
    }

    /// The satellite-audit regression, queue level and deterministic: a
    /// waiter parked in `pop_batch` on an empty queue must be woken by
    /// `mark_dead` (sibling died, its deque spilled) and must exit on
    /// `close`. This test hanging = the strand bug.
    #[test]
    fn parked_waiter_survives_sibling_death_and_exits_on_close() {
        let queue = Arc::new(StealQueue::new(2));
        let q = Arc::clone(&queue);
        let waiter = thread::spawn(move || {
            let mut popped = 0usize;
            while let Some((batch, _backlog)) = q.pop_batch(1, 4) {
                popped += batch.len();
            }
            q.note_served(popped); // keep the debug custody ledger honest
            popped
        });
        // give the waiter time to park, then kill its sibling — whose
        // deque holds a frame that must spill to the injector and reach
        // the parked waiter
        thread::sleep(Duration::from_millis(20));
        let fr = frames(2);
        let mut it = fr.into_iter();
        let (id0, x0) = it.next().unwrap();
        let (id1, x1) = it.next().unwrap();
        assert!(queue.push(Frame::new(id0, x0), Some(0), 8, 2));
        queue.mark_dead(0);
        // a frame offered after the death goes to the injector (dead
        // shards take no preferred frames)
        assert!(queue.push(Frame::new(id1, x1), Some(0), 8, 2));
        thread::sleep(Duration::from_millis(20));
        queue.close();
        let popped = waiter.join().expect("parked waiter stranded");
        assert_eq!(popped, 2, "spilled + injected frames reach the waiter");
        assert_eq!(queue.drain_remaining(), 0); // ledger close_check runs
    }

    /// Serve-level variant: one shard is poisoned, the feed is slow
    /// enough that the healthy shard parks between arrivals. Whichever
    /// shard pops the poisoned frames, the serve must terminate (no
    /// stranded waiter after `mark_dead`/`close`) with conservation and
    /// at most one frame lost. Which shard wins each pop race is
    /// scheduler-dependent, so only race-free facts are asserted.
    #[test]
    fn last_live_shard_death_releases_parked_sibling() {
        let make = |shard: usize| -> Result<BlockExecutor<FailingBackend>> {
            let template = make_executor(0)?;
            Ok(BlockExecutor::new(
                FailingBackend {
                    inner: ReferenceBackend::new(),
                    fail: shard == 0,
                },
                Device::msp430(),
                template.arch.clone(),
                template.graph.clone(),
                template.ncls.clone(),
                template.store.clone(),
            ))
        };
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let total = 10;
        let opts = ShardOpts {
            queue_depth: 8,
            pace: Some(Duration::from_millis(2)),
            ..ShardOpts::default()
        };
        let report =
            serve_sharded_opts(make, 2, &plan, frames(total), &opts).unwrap();
        assert_eq!(report.aggregate.frames + report.aggregate.dropped, total);
        assert!(report.aggregate.dropped <= 1);
        // the poisoned shard can never complete a frame
        assert_eq!(report.frames_per_shard[0], 0);
        assert!(report.shard_errors.len() <= 1);
        if let Some((s, e)) = report.shard_errors.first() {
            assert_eq!(*s, 0);
            assert!(e.contains("injected shard fault"));
        }
    }

    /// The depth-semantics satellite: a depth-0 serve must behave
    /// identically through every entry point — clamped to depth 1, never
    /// a panic or a zero-capacity deadlock — because both schedulers
    /// share `ShardOpts::effective_depths`.
    #[test]
    fn depth_zero_is_clamped_identically_in_both_schedulers() {
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let total = 12;
        for steal in [false, true] {
            let opts = ShardOpts {
                queue_depth: 0,
                local_depth: 0,
                steal,
                ..ShardOpts::default()
            };
            let report =
                serve_sharded_opts(make_executor, 2, &plan, frames(total), &opts)
                    .unwrap();
            assert_eq!(
                report.aggregate.frames + report.aggregate.dropped,
                total,
                "steal={steal}"
            );
            assert!(report.aggregate.frames > 0, "steal={steal}");
        }
    }

    #[test]
    fn adaptive_batching_matches_fixed_predictions_and_fills_histogram() {
        let plan = ServePlan {
            order: vec![0, 1, 2],
            conditional: vec![(0, 2)],
        };
        let fr = frames(21);
        let fixed = ShardOpts {
            queue_depth: 64,
            batch: 4,
            ..ShardOpts::default()
        };
        let adaptive = ShardOpts { adaptive_batch: true, ..fixed.clone() };
        let a = serve_sharded_opts(make_executor, 2, &plan, fr.clone(), &fixed)
            .unwrap();
        let b = serve_sharded_opts(make_executor, 2, &plan, fr, &adaptive)
            .unwrap();
        assert_eq!(a.aggregate.dropped, 0);
        assert_eq!(b.aggregate.dropped, 0);
        // batch size never changes predictions (batched kernels are
        // bitwise identical to batch-1), so adaptive == fixed frame-wise
        assert_eq!(a.results.len(), b.results.len());
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.predictions, y.predictions);
        }
        // the histogram is complete: every served frame is in some bucket,
        // every bucket within [1, batch]
        for report in [&a, &b] {
            assert_eq!(report.batch_hist.len(), 2);
            let mut counted = 0usize;
            for hist in &report.batch_hist {
                assert_eq!(hist.len(), 4);
                for (i, &c) in hist.iter().enumerate() {
                    counted += (i + 1) * c;
                }
            }
            assert_eq!(counted, report.aggregate.frames);
            let mb = report.mean_batch();
            assert!((1.0..=4.0).contains(&mb), "mean batch {mb}");
        }
    }

    /// Multi-producer ingest in front of the work-stealing scheduler:
    /// per-source and aggregate conservation, and the same predictions
    /// the single-producer path computes.
    #[test]
    fn multi_source_ingest_serve_conserves_per_source() {
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let all = frames(30);
        let sources: Vec<Source> = (0..3)
            .map(|s| {
                let fr: Vec<(u64, Tensor)> = all
                    .iter()
                    .filter(|(id, _)| (*id as usize) % 3 == s)
                    .cloned()
                    .collect();
                Source::flood(&format!("src{s}"), fr)
            })
            .collect();
        let opts = ShardOpts {
            queue_depth: 64,
            batch: 4,
            adaptive_batch: true,
            ..ShardOpts::default()
        };
        let (report, ingest) =
            serve_sharded_sources(make_executor, 3, &plan, sources, 3, &opts)
                .unwrap();
        assert_eq!(ingest.producers, 3);
        assert_eq!(ingest.offered(), 30);
        for s in &ingest.sources {
            assert_eq!(s.offered, 10);
            assert_eq!(s.delivered + s.dropped(), s.offered);
        }
        // deep queue, no schedule: nothing is shed at ingest
        assert_eq!(ingest.dropped(), 0);
        assert_eq!(
            report.aggregate.frames + report.aggregate.dropped,
            ingest.offered()
        );
        assert_eq!(report.aggregate.frames, 30);
        // every id exactly once, same predictions as the single-producer
        // work-stealing path over the same frames
        let ws = serve_sharded_opts(
            make_executor,
            3,
            &plan,
            all,
            &ShardOpts { queue_depth: 64, ..ShardOpts::default() },
        )
        .unwrap();
        assert_eq!(report.results.len(), ws.results.len());
        for (got, want) in report.results.iter().zip(&ws.results) {
            assert_eq!(got.id, want.id);
            assert_eq!(got.predictions, want.predictions);
        }
    }

    #[test]
    fn multi_producer_requires_work_stealing() {
        let plan = ServePlan::unconditional(vec![0]);
        let opts = ShardOpts { steal: false, ..ShardOpts::default() };
        let err = serve_sharded_sources(
            make_executor,
            2,
            &plan,
            vec![Source::flood("a", frames(4))],
            2,
            &opts,
        )
        .unwrap_err();
        assert!(err.to_string().contains("work-stealing"));
    }

    /// Two tenants with different orders over one shared fleet: every
    /// frame is served on its tenant's plan, the report breaks frames
    /// down per tenant, and the plan-epoch ledger balances and renders.
    /// The legacy single-plan path must also report its one epoch-0 row
    /// — every work-stealing serve is a registry serve now.
    #[test]
    fn registry_serve_routes_tenants_and_books_epochs() {
        let registry = Arc::new(PlanRegistry::new(vec![
            ServePlan::unconditional(vec![0, 1, 2]),
            ServePlan::unconditional(vec![2, 1, 0]),
        ]));
        let fr: Vec<(u64, u32, Tensor)> = frames(20)
            .into_iter()
            .map(|(id, x)| (id, (id % 2) as u32, x))
            .collect();
        let opts = ShardOpts {
            queue_depth: 64,
            batch: 3,
            ..ShardOpts::default()
        };
        let report = serve_sharded_registry(
            make_executor,
            2,
            Arc::clone(&registry),
            fr,
            &opts,
            None,
        )
        .unwrap();
        assert_eq!(report.aggregate.dropped, 0);
        assert_eq!(report.aggregate.frames, 20);
        assert_eq!(report.frames_per_tenant(), vec![(0, 10), (1, 10)]);
        for r in &report.results {
            assert_eq!(r.tenant, (r.id % 2) as u32);
            assert_eq!(r.epoch, 0);
        }
        assert_eq!(report.epochs.len(), 2);
        for e in &report.epochs {
            assert_eq!(e.admitted, 10);
            assert_eq!(e.completed, 10);
            assert_eq!(e.failed + e.drained, 0);
            assert!(e.live);
        }
        let table =
            report.epoch_table().expect("registry serve renders epochs");
        assert!(table.contains("plan epochs"));

        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let legacy = serve_sharded_opts(
            make_executor,
            2,
            &plan,
            frames(6),
            &ShardOpts { queue_depth: 64, ..ShardOpts::default() },
        )
        .unwrap();
        assert_eq!(legacy.epochs.len(), 1);
        assert_eq!(legacy.epochs[0].admitted, 6);
        assert_eq!(legacy.epochs[0].completed, 6);
    }

    // ---- BatchPolicy in isolation (the adaptive rule is pure state)

    #[test]
    fn batch_policy_fixed_never_moves() {
        let mut p = BatchPolicy::fixed(6);
        for _ in 0..32 {
            assert_eq!(p.next(), 6);
            p.observe(6, 0, 1.0); // empty backlog, wild service time
        }
        assert_eq!(BatchPolicy::fixed(0).next(), 1); // clamped
    }

    #[test]
    fn batch_policy_grows_additively_under_backlog() {
        let mut p = BatchPolicy::adaptive(8);
        assert_eq!(p.next(), 1);
        for step in 0..16 {
            let before = p.next();
            p.observe(before, 64, 0.001 * before as f64); // deep backlog
            assert!(p.next() <= before + 1, "step {step} jumped");
            assert!(p.next() >= before, "step {step} shrank");
        }
        assert_eq!(p.next(), 8); // reached and capped at max
    }

    #[test]
    fn batch_policy_collapses_multiplicatively_when_idle() {
        let mut p = BatchPolicy::adaptive(8);
        for _ in 0..16 {
            let b = p.next();
            p.observe(b, 64, 0.001 * b as f64);
        }
        assert_eq!(p.next(), 8);
        p.observe(8, 0, 0.008); // queue drained
        assert_eq!(p.next(), 4);
        p.observe(4, 0, 0.004);
        assert_eq!(p.next(), 2);
        p.observe(2, 0, 0.002);
        p.observe(1, 0, 0.001);
        assert_eq!(p.next(), 1); // floored, never 0
    }

    #[test]
    fn batch_policy_backs_off_on_service_time_spike() {
        let mut p = BatchPolicy::adaptive(8);
        // steady 1 ms/frame service under backlog: grows to max
        for _ in 0..16 {
            let b = p.next();
            p.observe(b, 64, 0.001 * b as f64);
        }
        assert_eq!(p.next(), 8);
        // the shard slows 10x (noisy neighbor): even with deep backlog
        // the policy must halve rather than keep hogging big batches
        p.observe(8, 64, 0.010 * 8.0);
        assert_eq!(p.next(), 4);
    }

    #[test]
    fn batch_policy_stays_in_bounds_on_arbitrary_feedback() {
        let mut p = BatchPolicy::adaptive(5);
        let mut rng = Pcg32::seed(99);
        for _ in 0..500 {
            let b = p.next();
            assert!((1..=5).contains(&b));
            p.observe(
                b,
                rng.below(20),
                rng.f64() * 0.01,
            );
        }
    }
}

/// Exhaustive model checks of the steal queue's wake/close/custody
/// protocols (`./ci.sh --loom`; `RUSTFLAGS="--cfg loom" cargo test
/// --release --lib loom_`). These are the schedules stress tests only
/// sample: loom interleaves every execution (bounded at 3 preemptions)
/// and a lost wakeup surfaces as a hung model, which is precisely the
/// evidence that let `pop_batch` drop its 50 ms timeout — see the
/// `loom-verified:` annotation there and CONCURRENCY.md.
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;

    fn tiny(id: u64) -> Frame {
        Frame::new(id, Tensor::new(vec![1, 1, 1, 1], vec![0.0]))
    }

    fn model() -> loom::model::Builder {
        let mut b = loom::model::Builder::new();
        b.preemption_bound = Some(3);
        b
    }

    /// Protocol 1 — wake on push racing close: a waiter parked on the
    /// empty queue must see a frame pushed concurrently with `close`
    /// under EVERY interleaving (push-then-close, close-then-push,
    /// park-before-either). Conservation: exactly one frame is popped,
    /// none drained.
    #[test]
    fn loom_steal_queue_wake_and_close() {
        model().check(|| {
            let queue = Arc::new(StealQueue::new(1));
            let q = Arc::clone(&queue);
            let waiter = thread::spawn(move || {
                let mut got = 0usize;
                while let Some((batch, _)) = q.pop_batch(0, 2) {
                    got += batch.len();
                    q.note_served(batch.len());
                }
                got
            });
            assert!(queue.push(tiny(0), None, 4, 1));
            queue.close();
            let got = waiter.join().unwrap();
            assert_eq!(got, 1, "pushed frame lost across close");
            assert_eq!(queue.drain_remaining(), 0);
        });
    }

    /// Protocol 2 — the `CloseOnDrop` guard: the feeder "unwinds" (its
    /// guard drops without an explicit close) while a worker is parked.
    /// The drop-path close must release the waiter in every schedule —
    /// a miss deadlocks the join, which loom reports as a hang.
    #[test]
    fn loom_close_on_drop_releases_parked_worker() {
        model().check(|| {
            let queue = Arc::new(StealQueue::new(1));
            let q = Arc::clone(&queue);
            let waiter = thread::spawn(move || {
                let mut got = 0usize;
                while let Some((batch, _)) = q.pop_batch(0, 2) {
                    got += batch.len();
                    q.note_served(batch.len());
                }
                got
            });
            let q2 = Arc::clone(&queue);
            let feeder = thread::spawn(move || {
                let closer = CloseOnDrop(q2.as_ref());
                q2.push(tiny(0), None, 4, 1);
                // no explicit close(): the guard's Drop is the only
                // close, exactly the feeder-panic unwind path
                drop(closer);
            });
            feeder.join().unwrap();
            let got = waiter.join().unwrap();
            assert_eq!(got, 1);
            assert_eq!(queue.drain_remaining(), 0);
        });
    }

    /// Protocol 3 — dead-shard absorption: shard 0's deque holds a
    /// frame when shard 0 dies; the spill to the injector must wake and
    /// reach shard 1 even if shard 1 parked before `mark_dead` ran.
    #[test]
    fn loom_mark_dead_spills_to_parked_sibling() {
        model().check(|| {
            let queue = Arc::new(StealQueue::new(2));
            assert!(queue.push(tiny(0), Some(0), 4, 2));
            let q = Arc::clone(&queue);
            let sibling = thread::spawn(move || {
                let mut got = 0usize;
                // shard 1 never looks at shard 0's deque until it is
                // otherwise idle — the spill is what hands the frame over
                while let Some((batch, _)) = q.pop_batch(1, 2) {
                    got += batch.len();
                    q.note_served(batch.len());
                }
                got
            });
            let q2 = Arc::clone(&queue);
            let killer = thread::spawn(move || {
                q2.mark_dead(0);
                q2.close();
            });
            killer.join().unwrap();
            let got = sibling.join().unwrap();
            assert_eq!(got, 1, "dead shard's frame stranded");
            assert_eq!(queue.drain_remaining(), 0);
        });
    }

    /// Protocol 4 — last-live-shard death with a parked sibling:
    /// worker 0 pops a frame, fails it, marks itself dead while worker 1
    /// is parked and the feeder closes concurrently. Custody must
    /// balance (served + failed + drained == enqueued) and both workers
    /// must exit in every schedule.
    #[test]
    fn loom_worker_death_conserves_and_releases_sibling() {
        model().check(|| {
            let queue = Arc::new(StealQueue::new(2));
            assert!(queue.push(tiny(0), Some(0), 4, 2));
            let q = Arc::clone(&queue);
            let dying = thread::spawn(move || {
                let mut failed = 0usize;
                // the sibling may steal the frame first; a closed empty
                // queue then returns None and this worker just exits
                if let Some((batch, _)) = q.pop_batch(0, 1) {
                    // executor failure: consumed but never served
                    failed = batch.len();
                    q.note_failed(batch.len());
                    q.mark_dead(0);
                }
                failed
            });
            let q2 = Arc::clone(&queue);
            let sibling = thread::spawn(move || {
                let mut got = 0usize;
                while let Some((batch, _)) = q2.pop_batch(1, 1) {
                    got += batch.len();
                    q2.note_served(batch.len());
                }
                got
            });
            // close before joining: whichever worker loses the pop race
            // must still be released (close is drain-then-exit, so the
            // already-queued frame is never abandoned by closing early)
            queue.close();
            let failed = dying.join().unwrap();
            let got = sibling.join().unwrap();
            let drained = queue.drain_remaining();
            assert_eq!(
                got + failed + drained,
                1,
                "custody imbalance: served {got} failed {failed} drained {drained}"
            );
        });
    }

    /// Protocol 5 — the tier prefetch mailbox (`PrefetchSignal`): two
    /// dispatcher threads bump a shard's signal while the shard drains
    /// it with `take` (the pop-time swap). Hints must be conserved under
    /// every interleaving — added == consumed + remaining — even though
    /// every access is Relaxed: atomic RMWs never lose increments, which
    /// is exactly why the mailbox needs no stronger ordering (it carries
    /// a heuristic count, not a happens-before edge; see CONCURRENCY.md
    /// §Two-tier weight memory).
    #[test]
    fn loom_tier_prefetch_signal_conserves_hints() {
        model().check(|| {
            let sig = Arc::new(PrefetchSignal::new());
            let producers: Vec<_> = (0..2)
                .map(|_| {
                    let s = Arc::clone(&sig);
                    thread::spawn(move || s.add(1))
                })
                .collect();
            // the shard's pop-time drain races both producers
            let mut consumed = sig.take();
            for p in producers {
                p.join().unwrap();
            }
            // post-join drain picks up whatever the racing take missed
            consumed += sig.take();
            assert_eq!(consumed, 2, "prefetch hints lost or duplicated");
        });
    }
}
