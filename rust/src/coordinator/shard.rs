//! Sharded serving: round-robin frames across N executors, each owning
//! its own `Send` backend (the pure-Rust reference interpreter), running
//! on the existing `exec::pool::ThreadPool`. This is the first step
//! toward the heavy-traffic serving north star: one process, N cores,
//! N independent §2.3 state machines, one aggregate [`ServeReport`].
//!
//! Sharding is by frame, so per-sample activation reuse across tasks is
//! preserved inside every shard (a frame's whole task round runs on one
//! executor); only cross-frame weight residency is per-shard state.

use std::sync::mpsc::{channel, sync_channel, TrySendError};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::exec::pool::ThreadPool;
use crate::model::Tensor;
use crate::runtime::Backend;

use super::executor::BlockExecutor;
use super::server::{build_report, run_executor, Frame, ServePlan, ServeReport};

/// Aggregate result of a sharded serve.
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub shards: usize,
    /// Frames actually processed by each shard.
    pub frames_per_shard: Vec<usize>,
    /// Pool-wide metrics (frames/drops/latency percentiles/sim cost and
    /// layer counters summed over every shard).
    pub aggregate: ServeReport,
}

impl ShardReport {
    /// Number of shards that processed at least one frame.
    pub fn busy_shards(&self) -> usize {
        self.frames_per_shard.iter().filter(|&&c| c > 0).count()
    }
}

/// Serve `frames` across `n_shards` executors built by `make_executor`
/// (one per shard, each owning its backend — the backend must be `Send`,
/// which the reference backend is and PJRT deliberately is not).
///
/// Frames are distributed round-robin over per-shard bounded queues;
/// a full shard queue drops the frame (counted), like the single-executor
/// loop. Returns when every shard has drained its queue.
pub fn serve_sharded<B, F>(
    mut make_executor: F,
    n_shards: usize,
    plan: &ServePlan,
    frames: Vec<(u64, Tensor)>,
    queue_depth: usize,
    pace: Option<std::time::Duration>,
) -> Result<ShardReport>
where
    B: Backend + Send + 'static,
    F: FnMut(usize) -> Result<BlockExecutor<B>>,
{
    let n = n_shards.max(1);
    let pool = ThreadPool::new(n);
    let (res_tx, res_rx) = channel();
    let mut frame_txs = Vec::with_capacity(n);
    for s in 0..n {
        let (tx, rx) = sync_channel::<Frame>(queue_depth.max(1));
        frame_txs.push(tx);
        let mut ex = make_executor(s)?;
        let plan = plan.clone();
        let res_tx = res_tx.clone();
        pool.execute(move || {
            let out = run_executor(&mut ex, &plan, rx).map(|(results, skipped)| {
                (results, skipped, ex.layer_execs, ex.layer_skips)
            });
            let _ = res_tx.send((s, out));
        });
    }
    drop(res_tx);

    let t0 = Instant::now();
    let mut dropped = 0usize;
    for (i, (id, input)) in frames.into_iter().enumerate() {
        let frame = Frame { id, input, enqueued: Instant::now() };
        match frame_txs[i % n].try_send(frame) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => dropped += 1,
            // a dead shard's queue: count the frame as dropped and keep
            // feeding the others — the collection loop below propagates
            // the worker's actual error
            Err(TrySendError::Disconnected(_)) => dropped += 1,
        }
        if let Some(p) = pace {
            std::thread::sleep(p);
        }
    }
    drop(frame_txs); // closes every queue; shard loops drain and exit

    let mut frames_per_shard = vec![0usize; n];
    let mut all = Vec::new();
    let mut skipped = 0usize;
    let mut layer_execs = 0u64;
    let mut layer_skips = 0u64;
    for _ in 0..n {
        let (s, out) = res_rx
            .recv()
            .map_err(|_| anyhow!("a shard worker died before reporting"))?;
        let (results, sk, le, ls) = out?;
        frames_per_shard[s] = results.len();
        skipped += sk;
        layer_execs += le;
        layer_skips += ls;
        all.extend(results);
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok(ShardReport {
        shards: n,
        frames_per_shard,
        aggregate: build_report(&all, dropped, wall, skipped, layer_execs, layer_skips),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Device;
    use crate::runtime::ReferenceBackend;
    use crate::taskgraph::{Partition, TaskGraph};
    use crate::trainer::GraphWeights;
    use crate::util::rng::Pcg32;

    fn make_executor(_shard: usize) -> Result<BlockExecutor<ReferenceBackend>> {
        let backend = ReferenceBackend::new();
        let arch = backend.arch("cnn5")?;
        let graph = TaskGraph::new(
            3,
            vec![1, 3, 4],
            vec![
                Partition(vec![0, 0, 0]),
                Partition(vec![0, 0, 0]),
                Partition(vec![0, 0, 1]),
                Partition::singletons(3),
            ],
        )?;
        let ncls = vec![2, 2, 2];
        // identical seed per shard: every shard serves the same weights
        let mut rng = Pcg32::seed(7);
        let store = GraphWeights::init(&graph, &arch, &ncls, &mut rng);
        Ok(BlockExecutor::new(
            backend,
            Device::msp430(),
            arch,
            graph,
            ncls,
            store,
        ))
    }

    fn frames(n: usize) -> Vec<(u64, Tensor)> {
        let mut rng = Pcg32::seed(15);
        (0..n as u64)
            .map(|i| {
                let data = (0..256).map(|_| rng.gauss()).collect();
                (i, Tensor::new(vec![1, 16, 16, 1], data))
            })
            .collect()
    }

    #[test]
    fn sharded_serve_covers_all_frames_across_executors() {
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        // deep queues: 24 frames over 3 shards never overflow depth 16
        let report =
            serve_sharded(make_executor, 3, &plan, frames(24), 16, None).unwrap();
        assert_eq!(report.shards, 3);
        assert_eq!(report.aggregate.dropped, 0);
        assert_eq!(report.aggregate.frames, 24);
        // round-robin with no drops: exactly even split, ≥2 shards busy
        assert_eq!(report.frames_per_shard, vec![8, 8, 8]);
        assert!(report.busy_shards() >= 2);
        // aggregate metrics are real
        assert!(report.aggregate.throughput_fps > 0.0);
        assert!(report.aggregate.sim_time_per_frame_s > 0.0);
        assert!(report.aggregate.layer_execs > 0);
        // per-frame activation reuse still happens inside each shard
        assert!(report.aggregate.layer_skips > 0);
    }

    #[test]
    fn sharded_serve_conserves_frames_with_tiny_queues() {
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let total = 30;
        let report =
            serve_sharded(make_executor, 2, &plan, frames(total), 1, None).unwrap();
        assert_eq!(
            report.aggregate.frames + report.aggregate.dropped,
            total
        );
        assert_eq!(
            report.frames_per_shard.iter().sum::<usize>(),
            report.aggregate.frames
        );
    }

    #[test]
    fn single_shard_degenerates_to_plain_serve() {
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let report =
            serve_sharded(make_executor, 1, &plan, frames(6), 8, None).unwrap();
        assert_eq!(report.shards, 1);
        assert_eq!(report.aggregate.frames, 6);
        assert_eq!(report.frames_per_shard, vec![6]);
    }

    #[test]
    fn conditional_plans_work_sharded() {
        let plan = ServePlan {
            order: vec![0, 1, 2],
            conditional: vec![(0, 1), (0, 2)],
        };
        let report =
            serve_sharded(make_executor, 3, &plan, frames(18), 16, None).unwrap();
        assert_eq!(report.aggregate.frames, 18);
        assert!(report.aggregate.tasks_skipped <= 36);
    }
}
