//! Runtime invariant auditor: checked frame-custody ledgers for the
//! serving path, compiled to zero-sized no-ops in release builds
//! (`debug_assertions` off).
//!
//! Every frame offered to the serving stack must end in exactly one
//! terminal state — served, dropped at admission, failed with a dead
//! shard, or drained after total failure — and the conservation
//! invariant `delivered + dropped == offered` that every report-level
//! test asserts is only as trustworthy as the counters feeding it.
//! These ledgers re-derive the same totals from the *transitions*
//! (enqueue/pop/serve/fail/drain for queue custody,
//! deliver/stale/backpressure for ingest custody, deliver/drop for the
//! single-producer feed) and panic at the first transition that could
//! not have come from a conserving execution. Wired into the steal
//! queue (`shard::StealQueue`), `server::feed_frames`, and the ingest
//! cursors (`ingest::produce`), so in debug builds every existing test
//! and property run doubles as an invariant check.
//!
//! The ledgers are plain structs, NOT synchronized: each lives under
//! the lock (or on the thread) that already guards the counters it
//! shadows, so they add no lock-ordering surface. The loom lane runs
//! `--release`, which compiles them out — the model checker explores
//! the protocol, the auditor polices the accounting; CONCURRENCY.md
//! describes the split.

/// Custody ledger for the work-stealing queue: frames accepted into the
/// queue must leave it exactly once — popped by a shard or drained at
/// shutdown — and every popped frame must be reported back as served or
/// failed before the run closes.
#[cfg(debug_assertions)]
#[derive(Debug, Default)]
pub struct QueueLedger {
    enqueued: u64,
    popped: u64,
    served: u64,
    failed: u64,
    drained: u64,
}

#[cfg(debug_assertions)]
impl QueueLedger {
    fn queued(&self) -> u64 {
        match self.enqueued.checked_sub(self.popped + self.drained) {
            Some(q) => q,
            // lint:allow(panic) — the auditor's teeth: a conservation
            // breach must halt the debug run at the violation site
            None => panic!(
                "custody violation: removed more frames than enqueued \
                 ({} popped + {} drained > {} enqueued)",
                self.popped, self.drained, self.enqueued
            ),
        }
    }

    fn in_flight(&self) -> u64 {
        match self.popped.checked_sub(self.served + self.failed) {
            Some(f) => f,
            // lint:allow(panic) — the auditor's teeth: a conservation
            // breach must halt the debug run at the violation site
            None => panic!(
                "custody violation: reported more frames than popped \
                 ({} served + {} failed > {} popped)",
                self.served, self.failed, self.popped
            ),
        }
    }

    /// Cross-check the ledger's queued count against the structure's
    /// actual depth (injector + every deque) — catches a frame lost or
    /// duplicated by a queue edit even when the counters self-balance.
    pub fn reconcile(&self, depth_now: usize) {
        assert_eq!(
            self.queued(),
            depth_now as u64,
            "custody violation: ledger says {} queued, queue holds {}",
            self.queued(),
            depth_now
        );
    }

    /// One frame accepted into the queue (injector or a deque);
    /// `depth_now` is the structure's depth right after the insert.
    pub fn enqueue(&mut self, depth_now: usize) {
        self.enqueued += 1;
        self.reconcile(depth_now);
    }

    /// `n` frames handed to a shard in one pop; `depth_now` right after.
    pub fn pop(&mut self, n: usize, depth_now: usize) {
        self.popped += n as u64;
        self.reconcile(depth_now);
        self.in_flight(); // popped never exceeds enqueued via queued()
    }

    /// A shard completed `n` popped frames successfully.
    pub fn serve(&mut self, n: usize) {
        self.served += n as u64;
        self.in_flight();
    }

    /// A shard consumed `n` popped frames but died before serving them.
    pub fn fail(&mut self, n: usize) {
        self.failed += n as u64;
        self.in_flight();
    }

    /// `n` frames drained at shutdown because no worker remained.
    pub fn drain(&mut self, n: usize, depth_now: usize) {
        self.drained += n as u64;
        self.reconcile(depth_now);
    }

    /// End of run: nothing queued, nothing in flight, and the terminal
    /// states sum back to everything accepted.
    pub fn close_check(&self) {
        assert_eq!(self.queued(), 0, "custody violation: frames left queued");
        assert_eq!(
            self.in_flight(),
            0,
            "custody violation: popped frames never reported served/failed"
        );
        assert_eq!(
            self.served + self.failed + self.drained,
            self.enqueued,
            "custody violation: {} served + {} failed + {} drained != {} \
             enqueued",
            self.served,
            self.failed,
            self.drained,
            self.enqueued
        );
    }
}

/// Custody ledger for one ingest source: every offered frame becomes
/// delivered, stale, or backpressure-dropped — and the cursor's own
/// counters must agree with the transitions at the shutdown barrier.
#[cfg(debug_assertions)]
#[derive(Debug)]
pub struct SourceLedger {
    offered: usize,
    delivered: usize,
    stale: usize,
    backpressure: usize,
}

#[cfg(debug_assertions)]
impl SourceLedger {
    pub fn new(offered: usize) -> SourceLedger {
        SourceLedger { offered, delivered: 0, stale: 0, backpressure: 0 }
    }

    fn taken(&self) -> usize {
        self.delivered + self.stale + self.backpressure
    }

    fn take_one(&mut self, what: &str) {
        assert!(
            self.taken() < self.offered,
            "custody violation: source {} a frame beyond its {} offered",
            what,
            self.offered
        );
    }

    pub fn deliver(&mut self) {
        self.take_one("delivered");
        self.delivered += 1;
    }

    pub fn stale(&mut self) {
        self.take_one("shed (stale)");
        self.stale += 1;
    }

    pub fn backpressure(&mut self) {
        self.take_one("shed (backpressure)");
        self.backpressure += 1;
    }

    /// Barrier check: the cursor's counters must match the transition
    /// ledger exactly, and every offered frame must be accounted.
    pub fn reconcile(
        &self,
        delivered: usize,
        stale: usize,
        backpressure: usize,
    ) {
        assert!(
            (delivered, stale, backpressure)
                == (self.delivered, self.stale, self.backpressure),
            "custody violation: cursor counted {delivered}/{stale}/\
             {backpressure} (delivered/stale/backpressure), ledger saw \
             {}/{}/{}",
            self.delivered,
            self.stale,
            self.backpressure
        );
        assert_eq!(
            self.taken(),
            self.offered,
            "custody violation: source retired {} of {} offered frames",
            self.taken(),
            self.offered
        );
    }
}

/// Custody ledger for a single-producer feed (`server::feed_frames` and
/// the round-robin deal loop): offered == delivered + dropped, with the
/// drop count cross-checked against what the feeder reports upstream.
#[cfg(debug_assertions)]
#[derive(Debug)]
pub struct FeedLedger {
    offered: usize,
    delivered: usize,
    dropped: usize,
}

#[cfg(debug_assertions)]
impl FeedLedger {
    pub fn new(offered: usize) -> FeedLedger {
        FeedLedger { offered, delivered: 0, dropped: 0 }
    }

    pub fn deliver(&mut self) {
        self.delivered += 1;
        self.bounded();
    }

    pub fn drop_n(&mut self, n: usize) {
        self.dropped += n;
        self.bounded();
    }

    fn bounded(&self) {
        assert!(
            self.delivered + self.dropped <= self.offered,
            "custody violation: feed retired {} frames of {} offered",
            self.delivered + self.dropped,
            self.offered
        );
    }

    /// End of feed: every offered frame retired, and the drop count the
    /// feeder is about to report upstream matches the transitions.
    pub fn finish(&self, reported_dropped: usize) {
        assert_eq!(
            self.delivered + self.dropped,
            self.offered,
            "custody violation: feed retired {} of {} offered frames \
             (mid-feed hangup remainder lost?)",
            self.delivered + self.dropped,
            self.offered
        );
        assert_eq!(
            reported_dropped, self.dropped,
            "custody violation: feeder reports {} dropped, ledger saw {}",
            reported_dropped, self.dropped
        );
    }
}

/// Custody ledger for one network connection (`coordinator::net`):
/// unlike a [`SourceLedger`], the offered total is not known up front —
/// frames are offered as they decode off the socket — so `offer` grows
/// the total and every retirement must stay within it. Each offered
/// frame becomes exactly one of delivered / stale / backpressure /
/// truncated (the fourth bucket is the mid-frame-hangup remainder and
/// the malformed-record case — bytes that never became a well-formed
/// frame still get counted, mirroring the PR-5 `feed_frames` fix at the
/// socket edge). `close` reconciles the connection's own counters
/// against the transitions when the connection ends.
#[cfg(debug_assertions)]
#[derive(Debug, Default)]
pub struct ConnLedger {
    offered: usize,
    delivered: usize,
    stale: usize,
    backpressure: usize,
    truncated: usize,
}

#[cfg(debug_assertions)]
impl ConnLedger {
    pub fn new() -> ConnLedger {
        ConnLedger::default()
    }

    fn taken(&self) -> usize {
        self.delivered + self.stale + self.backpressure + self.truncated
    }

    /// A frame surfaced at this connection: decoded off the wire, or a
    /// partial/malformed record about to be counted truncated.
    pub fn offer(&mut self) {
        self.offered += 1;
    }

    fn take_one(&mut self, what: &str) {
        assert!(
            self.taken() < self.offered,
            "custody violation: connection {} a frame beyond its {} offered",
            what,
            self.offered
        );
    }

    pub fn deliver(&mut self) {
        self.take_one("delivered");
        self.delivered += 1;
    }

    pub fn stale(&mut self) {
        self.take_one("shed (stale)");
        self.stale += 1;
    }

    pub fn backpressure(&mut self) {
        self.take_one("shed (backpressure)");
        self.backpressure += 1;
    }

    pub fn truncate(&mut self) {
        self.take_one("truncated");
        self.truncated += 1;
    }

    /// Connection close: the connection's counters must match the
    /// transitions exactly and every offered frame must be retired —
    /// `delivered + stale + backpressure + truncated == offered`.
    pub fn close(
        &self,
        delivered: usize,
        stale: usize,
        backpressure: usize,
        truncated: usize,
    ) {
        assert!(
            (delivered, stale, backpressure, truncated)
                == (self.delivered, self.stale, self.backpressure, self.truncated),
            "custody violation: connection counted {delivered}/{stale}/\
             {backpressure}/{truncated} (delivered/stale/backpressure/\
             truncated), ledger saw {}/{}/{}/{}",
            self.delivered,
            self.stale,
            self.backpressure,
            self.truncated
        );
        assert_eq!(
            self.taken(),
            self.offered,
            "custody violation: connection retired {} of {} offered frames \
             (hangup remainder lost?)",
            self.taken(),
            self.offered
        );
    }
}

/// Custody ledger for the fast weight tier (`memory::tier`): every
/// slow-tier load issued — prefetch, demand, or stream-through — must
/// be retired exactly once, as completed (data arrived) or cancelled
/// (in-flight entry evicted), and insertions minus evictions must
/// always equal the tier's resident count. The tier calls `reconcile`
/// after every transition, so a single corrupted step panics at the
/// step, not at close.
#[cfg(debug_assertions)]
#[derive(Debug, Default)]
pub struct TierLedger {
    issued: u64,
    completed: u64,
    cancelled: u64,
    inserted: u64,
    evicted: u64,
}

#[cfg(debug_assertions)]
impl TierLedger {
    pub fn new() -> TierLedger {
        TierLedger::default()
    }

    fn in_flight(&self) -> u64 {
        match self.issued.checked_sub(self.completed + self.cancelled) {
            Some(f) => f,
            // lint:allow(panic) — the auditor's teeth: a conservation
            // breach must halt the debug run at the violation site
            None => panic!(
                "custody violation: tier retired more loads than issued \
                 ({} completed + {} cancelled > {} issued)",
                self.completed, self.cancelled, self.issued
            ),
        }
    }

    fn resident(&self) -> u64 {
        match self.inserted.checked_sub(self.evicted) {
            Some(r) => r,
            // lint:allow(panic) — the auditor's teeth: a conservation
            // breach must halt the debug run at the violation site
            None => panic!(
                "custody violation: tier evicted more blocks than inserted \
                 ({} evicted > {} inserted)",
                self.evicted, self.inserted
            ),
        }
    }

    /// A slow-tier load issued; `cached` means the block got a fast-tier
    /// entry (prefetch or demand fill) rather than streaming through.
    pub fn issue(&mut self, cached: bool) {
        self.issued += 1;
        if cached {
            self.inserted += 1;
        }
        self.in_flight();
    }

    /// An issued load's data arrived (settled entry or stream finished).
    pub fn complete(&mut self) {
        self.completed += 1;
        self.in_flight();
    }

    /// An in-flight entry was evicted before its load completed.
    pub fn cancel(&mut self) {
        self.cancelled += 1;
        self.evicted += 1;
        self.in_flight();
        self.resident();
    }

    /// A settled entry was evicted.
    pub fn evict(&mut self) {
        self.evicted += 1;
        self.resident();
    }

    /// Cross-check against the tier structure itself: resident entries
    /// and in-flight (unsettled) entries must match the transitions.
    pub fn reconcile(&self, n_entries: usize, n_in_flight: usize) {
        assert_eq!(
            self.resident(),
            n_entries as u64,
            "custody violation: tier ledger says {} blocks resident, \
             tier holds {}",
            self.resident(),
            n_entries
        );
        assert_eq!(
            self.in_flight(),
            n_in_flight as u64,
            "custody violation: tier ledger says {} loads in flight, \
             tier tracks {}",
            self.in_flight(),
            n_in_flight
        );
    }

    /// End of a shard's run: loads issued == completed + cancelled.
    pub fn close_check(&self) {
        assert_eq!(
            self.issued,
            self.completed + self.cancelled,
            "custody violation: {} loads issued != {} completed + {} \
             cancelled",
            self.issued,
            self.completed,
            self.cancelled
        );
    }
}

/// Custody ledger for one plan version (`coordinator::registry`): every
/// frame admitted under an epoch must retire exactly once — completed,
/// failed with its shard, or drained at shutdown — on the *same*
/// version that admitted it, so a plan hot-swap can neither drop nor
/// double-serve a frame. Unlike the other ledgers this one lives under
/// its own mutex inside `PlanVersion` (admissions book inside the steal
/// queue's lock, retirements on worker threads), but the lock order is
/// strictly queue → ledger and the guard never crosses a blocking call.
/// `close_check` cross-checks the version's atomic counters against the
/// transitions and requires full retirement.
#[cfg(debug_assertions)]
#[derive(Debug, Default)]
pub struct PlanEpochLedger {
    admitted: u64,
    completed: u64,
    failed: u64,
    drained: u64,
}

#[cfg(debug_assertions)]
impl PlanEpochLedger {
    pub fn new() -> PlanEpochLedger {
        PlanEpochLedger::default()
    }

    fn in_flight(&self) -> u64 {
        match self.admitted.checked_sub(
            self.completed + self.failed + self.drained,
        ) {
            Some(f) => f,
            // lint:allow(panic) — the auditor's teeth: a conservation
            // breach must halt the debug run at the violation site
            None => panic!(
                "custody violation: epoch retired more frames than admitted \
                 ({} completed + {} failed + {} drained > {} admitted)",
                self.completed, self.failed, self.drained, self.admitted
            ),
        }
    }

    /// One frame pinned this version at admission.
    pub fn admit(&mut self) {
        self.admitted += 1;
    }

    /// An admitted frame's round finished on this plan.
    pub fn complete(&mut self) {
        self.completed += 1;
        self.in_flight();
    }

    /// An admitted frame's shard died before serving it.
    pub fn fail(&mut self) {
        self.failed += 1;
        self.in_flight();
    }

    /// An admitted frame was cleared unserved at shutdown.
    pub fn drain(&mut self) {
        self.drained += 1;
        self.in_flight();
    }

    /// End of serving: the version's atomic counters must match the
    /// transitions exactly, and every admission must be retired.
    pub fn close_check(
        &self,
        admitted: usize,
        completed: usize,
        failed: usize,
        drained: usize,
    ) {
        assert!(
            (admitted as u64, completed as u64, failed as u64, drained as u64)
                == (self.admitted, self.completed, self.failed, self.drained),
            "custody violation: version counted {admitted}/{completed}/\
             {failed}/{drained} (admitted/completed/failed/drained), ledger \
             saw {}/{}/{}/{}",
            self.admitted,
            self.completed,
            self.failed,
            self.drained
        );
        assert_eq!(
            self.in_flight(),
            0,
            "custody violation: {} admitted frames never retired \
             (completed/failed/drained) on their epoch",
            self.in_flight()
        );
    }
}

// ------------------------------------------------------------ release
// Zero-sized, inlined-away stubs: the serving path keeps one unsendable
// code shape in both profiles, and release builds pay nothing.

#[cfg(not(debug_assertions))]
#[derive(Debug, Default)]
pub struct QueueLedger;

#[cfg(not(debug_assertions))]
impl QueueLedger {
    #[inline(always)]
    pub fn reconcile(&self, _depth_now: usize) {}
    #[inline(always)]
    pub fn enqueue(&mut self, _depth_now: usize) {}
    #[inline(always)]
    pub fn pop(&mut self, _n: usize, _depth_now: usize) {}
    #[inline(always)]
    pub fn serve(&mut self, _n: usize) {}
    #[inline(always)]
    pub fn fail(&mut self, _n: usize) {}
    #[inline(always)]
    pub fn drain(&mut self, _n: usize, _depth_now: usize) {}
    #[inline(always)]
    pub fn close_check(&self) {}
}

#[cfg(not(debug_assertions))]
#[derive(Debug)]
pub struct SourceLedger;

#[cfg(not(debug_assertions))]
impl SourceLedger {
    #[inline(always)]
    pub fn new(_offered: usize) -> SourceLedger {
        SourceLedger
    }
    #[inline(always)]
    pub fn deliver(&mut self) {}
    #[inline(always)]
    pub fn stale(&mut self) {}
    #[inline(always)]
    pub fn backpressure(&mut self) {}
    #[inline(always)]
    pub fn reconcile(&self, _d: usize, _s: usize, _b: usize) {}
}

#[cfg(not(debug_assertions))]
#[derive(Debug)]
pub struct FeedLedger;

#[cfg(not(debug_assertions))]
impl FeedLedger {
    #[inline(always)]
    pub fn new(_offered: usize) -> FeedLedger {
        FeedLedger
    }
    #[inline(always)]
    pub fn deliver(&mut self) {}
    #[inline(always)]
    pub fn drop_n(&mut self, _n: usize) {}
    #[inline(always)]
    pub fn finish(&self, _reported_dropped: usize) {}
}

#[cfg(not(debug_assertions))]
#[derive(Debug, Default)]
pub struct ConnLedger;

#[cfg(not(debug_assertions))]
impl ConnLedger {
    #[inline(always)]
    pub fn new() -> ConnLedger {
        ConnLedger
    }
    #[inline(always)]
    pub fn offer(&mut self) {}
    #[inline(always)]
    pub fn deliver(&mut self) {}
    #[inline(always)]
    pub fn stale(&mut self) {}
    #[inline(always)]
    pub fn backpressure(&mut self) {}
    #[inline(always)]
    pub fn truncate(&mut self) {}
    #[inline(always)]
    pub fn close(&self, _d: usize, _s: usize, _b: usize, _t: usize) {}
}

#[cfg(not(debug_assertions))]
#[derive(Debug, Default)]
pub struct TierLedger;

#[cfg(not(debug_assertions))]
impl TierLedger {
    #[inline(always)]
    pub fn new() -> TierLedger {
        TierLedger
    }
    #[inline(always)]
    pub fn issue(&mut self, _cached: bool) {}
    #[inline(always)]
    pub fn complete(&mut self) {}
    #[inline(always)]
    pub fn cancel(&mut self) {}
    #[inline(always)]
    pub fn evict(&mut self) {}
    #[inline(always)]
    pub fn reconcile(&self, _n_entries: usize, _n_in_flight: usize) {}
    #[inline(always)]
    pub fn close_check(&self) {}
}

#[cfg(not(debug_assertions))]
#[derive(Debug, Default)]
pub struct PlanEpochLedger;

#[cfg(not(debug_assertions))]
impl PlanEpochLedger {
    #[inline(always)]
    pub fn new() -> PlanEpochLedger {
        PlanEpochLedger
    }
    #[inline(always)]
    pub fn admit(&mut self) {}
    #[inline(always)]
    pub fn complete(&mut self) {}
    #[inline(always)]
    pub fn fail(&mut self) {}
    #[inline(always)]
    pub fn drain(&mut self) {}
    #[inline(always)]
    pub fn close_check(&self, _a: usize, _c: usize, _f: usize, _d: usize) {}
}

// The teeth tests: the auditor is only worth its wiring if a corrupted
// transition actually panics. Debug builds only — release compiles the
// ledgers (and these tests) away.
#[cfg(all(test, debug_assertions, not(loom)))]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn queue_ledger_accepts_a_conserving_run() {
        let mut l = QueueLedger::default();
        l.enqueue(1);
        l.enqueue(2);
        l.pop(2, 0);
        l.serve(2);
        l.enqueue(1);
        l.pop(1, 0);
        l.fail(1);
        l.drain(0, 0);
        l.close_check();
    }

    /// The headline teeth test: a deliberately corrupted transition —
    /// a shard reporting a frame it never popped — must panic.
    #[test]
    #[should_panic(expected = "custody violation")]
    fn queue_ledger_panics_on_phantom_serve() {
        let mut l = QueueLedger::default();
        l.enqueue(1);
        l.pop(1, 0);
        l.serve(1);
        l.serve(1); // corrupt: served twice, popped once
    }

    #[test]
    #[should_panic(expected = "custody violation")]
    fn queue_ledger_panics_on_lost_frame_at_close() {
        let mut l = QueueLedger::default();
        l.enqueue(1);
        l.pop(1, 0);
        // corrupt: the popped frame is never reported served or failed
        l.close_check();
    }

    #[test]
    #[should_panic(expected = "custody violation")]
    fn queue_ledger_panics_on_depth_mismatch() {
        let mut l = QueueLedger::default();
        // corrupt: the structure says two frames are queued after one
        // enqueue — a duplicated frame in the deques
        l.enqueue(2);
    }

    #[test]
    #[should_panic(expected = "custody violation")]
    fn source_ledger_panics_on_overdrawn_source() {
        let mut l = SourceLedger::new(1);
        l.deliver();
        l.deliver(); // corrupt: delivered more than offered
    }

    #[test]
    #[should_panic(expected = "custody violation")]
    fn feed_ledger_panics_on_lost_hangup_remainder() {
        let mut l = FeedLedger::new(5);
        l.deliver();
        l.deliver();
        // corrupt: receiver hung up with 3 frames in hand, feeder counts
        // only the in-hand frame (the exact PR-5 bug class)
        l.drop_n(1);
        l.finish(1);
    }

    #[test]
    #[should_panic(expected = "custody violation")]
    fn feed_ledger_panics_on_misreported_drop_count() {
        let mut l = FeedLedger::new(2);
        l.deliver();
        l.drop_n(1);
        l.finish(0); // corrupt: feeder under-reports upstream
    }

    /// Property: random *valid* custody walks never panic; the same walk
    /// with one random transition corrupted always does. This is the
    /// auditor's coverage argument — its teeth are verified over the
    /// transition space, not assumed from one handpicked case.
    #[test]
    fn prop_random_walks_pass_and_random_corruptions_panic() {
        for seed in 0..200u64 {
            let mut rng = Pcg32::seed(seed);
            // build a random conserving schedule: each frame's lifecycle
            // enqueue -> pop -> (serve | fail), stragglers drained
            let frames = 1 + rng.below(6);
            let mut plan: Vec<(u8, usize)> = Vec::new(); // (op, n)
            let mut queued = 0usize;
            let mut popped = 0usize;
            for _ in 0..frames {
                plan.push((0, 1)); // enqueue
                queued += 1;
                if rng.below(2) == 0 && queued > 0 {
                    let n = 1 + rng.below(queued);
                    plan.push((1, n)); // pop n
                    queued -= n;
                    popped += n;
                }
                while popped > 0 {
                    let n = 1 + rng.below(popped);
                    plan.push((if rng.below(4) == 0 { 3 } else { 2 }, n));
                    popped -= n;
                }
            }
            plan.push((4, queued)); // drain the leftovers

            let run = |corrupt_at: Option<usize>| {
                let mut l = QueueLedger::default();
                let mut depth = 0usize;
                for (i, &(op, n)) in plan.iter().enumerate() {
                    // corruption: lie about the depth by one — the
                    // signature of a lost or duplicated frame
                    let fudge = usize::from(corrupt_at == Some(i));
                    match op {
                        0 => {
                            depth += 1;
                            l.enqueue(depth + fudge);
                        }
                        1 => {
                            depth -= n;
                            l.pop(n, depth + fudge);
                        }
                        2 => l.serve(n + fudge),
                        3 => l.fail(n + fudge),
                        _ => {
                            depth -= n;
                            l.drain(n, depth + fudge);
                        }
                    }
                }
                l.close_check();
            };

            // the valid walk must pass...
            run(None);
            // ...and corrupting any single transition must panic
            let at = rng.below(plan.len());
            let caught =
                catch_unwind(AssertUnwindSafe(|| run(Some(at)))).is_err();
            assert!(
                caught,
                "seed {seed}: corruption at step {at} of {:?} went undetected",
                plan
            );
        }
    }

    #[test]
    fn conn_ledger_accepts_a_conserving_connection() {
        let mut l = ConnLedger::new();
        l.offer();
        l.deliver();
        l.offer();
        l.backpressure();
        l.offer();
        l.stale();
        l.offer();
        l.truncate(); // the hangup remainder
        l.close(1, 1, 1, 1);
    }

    #[test]
    #[should_panic(expected = "custody violation")]
    fn conn_ledger_panics_on_retire_without_offer() {
        let mut l = ConnLedger::new();
        l.offer();
        l.deliver();
        l.deliver(); // corrupt: retired a frame the wire never produced
    }

    #[test]
    #[should_panic(expected = "custody violation")]
    fn conn_ledger_panics_on_lost_hangup_remainder_at_close() {
        let mut l = ConnLedger::new();
        l.offer();
        l.deliver();
        l.offer(); // a partial record was on the wire at hangup...
        // ...but nobody counted it truncated (the PR-5 bug class at the
        // socket edge)
        l.close(1, 0, 0, 0);
    }

    #[test]
    #[should_panic(expected = "custody violation")]
    fn conn_ledger_panics_on_counter_ledger_disagreement() {
        let mut l = ConnLedger::new();
        l.offer();
        l.backpressure();
        // corrupt: the connection reports the drop in the wrong bucket
        l.close(0, 1, 0, 0);
    }

    #[test]
    fn tier_ledger_accepts_a_conserving_run() {
        let mut l = TierLedger::new();
        l.issue(true); // prefetch in flight
        l.reconcile(1, 1);
        l.complete(); // settles
        l.reconcile(1, 0);
        l.issue(false); // stream-through
        l.complete();
        l.reconcile(1, 0);
        l.issue(true); // second prefetch...
        l.cancel(); // ...evicted before its data arrived
        l.reconcile(1, 0);
        l.evict(); // the settled block leaves too
        l.reconcile(0, 0);
        l.close_check();
    }

    #[test]
    #[should_panic(expected = "custody violation")]
    fn tier_ledger_panics_on_phantom_complete() {
        let mut l = TierLedger::new();
        l.issue(true);
        l.complete();
        l.complete(); // corrupt: one load, two arrivals
    }

    #[test]
    #[should_panic(expected = "custody violation")]
    fn tier_ledger_panics_on_evicting_uninserted_block() {
        let mut l = TierLedger::new();
        l.issue(false); // stream: never inserted
        l.complete();
        l.evict(); // corrupt: evicting a block the tier never held
    }

    #[test]
    #[should_panic(expected = "custody violation")]
    fn tier_ledger_panics_on_cancel_without_issue() {
        let mut l = TierLedger::new();
        l.cancel(); // corrupt: cancelling a load never issued
    }

    #[test]
    #[should_panic(expected = "custody violation")]
    fn tier_ledger_panics_on_resident_count_drift() {
        let mut l = TierLedger::new();
        l.issue(true);
        l.complete();
        // corrupt: the tier structure holds two entries after one insert
        // — the signature of a duplicated map entry
        l.reconcile(2, 0);
    }

    #[test]
    #[should_panic(expected = "custody violation")]
    fn tier_ledger_panics_on_unretired_load_at_close() {
        let mut l = TierLedger::new();
        l.issue(true); // in flight forever
        l.close_check();
    }

    #[test]
    fn plan_epoch_ledger_accepts_a_conserving_epoch() {
        let mut l = PlanEpochLedger::new();
        l.admit();
        l.admit();
        l.admit();
        l.complete();
        l.fail();
        l.drain();
        l.close_check(3, 1, 1, 1);
    }

    #[test]
    #[should_panic(expected = "custody violation")]
    fn plan_epoch_ledger_panics_on_phantom_completion() {
        let mut l = PlanEpochLedger::new();
        l.admit();
        l.complete();
        l.complete(); // corrupt: one admission, two completions — the
                      // double-serve a hot-swap must never produce
    }

    #[test]
    #[should_panic(expected = "custody violation")]
    fn plan_epoch_ledger_panics_on_unretired_admission_at_close() {
        let mut l = PlanEpochLedger::new();
        l.admit();
        // corrupt: the admitted frame neither completed, failed, nor
        // drained — the dropped-by-swap case
        l.close_check(1, 0, 0, 0);
    }

    #[test]
    #[should_panic(expected = "custody violation")]
    fn plan_epoch_ledger_panics_on_counter_disagreement() {
        let mut l = PlanEpochLedger::new();
        l.admit();
        l.complete();
        // corrupt: the version's atomics claim a drain the transitions
        // never saw (a frame retired on the wrong version)
        l.close_check(1, 0, 0, 1);
    }

    /// Property: random valid epoch custody walks pass, and corrupting
    /// any single retirement (replaying it against the ledger without a
    /// matching admission) panics — over admit/complete/fail/drain.
    #[test]
    fn prop_plan_epoch_walks_pass_and_random_corruptions_panic() {
        for seed in 0..200u64 {
            let mut rng = Pcg32::seed(seed);
            // ops: 0 = admit, 1 = complete, 2 = fail, 3 = drain
            let mut plan: Vec<u8> = Vec::new();
            let mut open = 0usize;
            for _ in 0..(3 + rng.below(12)) {
                let op = if open == 0 { 0 } else { rng.below(4) as u8 };
                match op {
                    0 => open += 1,
                    _ => open -= 1,
                }
                plan.push(op);
            }
            // retire the stragglers so the valid walk closes balanced
            for _ in 0..open {
                plan.push(1 + rng.below(3) as u8);
            }

            let run = |corrupt_at: Option<usize>| {
                let mut l = PlanEpochLedger::new();
                let (mut a, mut c, mut f, mut d) = (0usize, 0, 0, 0);
                for (i, &op) in plan.iter().enumerate() {
                    match op {
                        0 => {
                            l.admit();
                            a += 1;
                        }
                        1 => {
                            l.complete();
                            c += 1;
                        }
                        2 => {
                            l.fail();
                            f += 1;
                        }
                        _ => {
                            l.drain();
                            d += 1;
                        }
                    }
                    if corrupt_at == Some(i) {
                        // replay the ledger half without the structure
                        // half: a retirement that never happened
                        match op {
                            0 => l.drain(), // admit corrupted to a phantom
                            1 => l.complete(),
                            2 => l.fail(),
                            _ => l.drain(),
                        }
                        d += usize::from(op == 0); // keep close counters
                        c += usize::from(op == 1); // aligned so the walk
                        f += usize::from(op == 2); // panics at the breach,
                        d += usize::from(op == 3); // not the cross-check
                    }
                }
                l.close_check(a, c, f, d);
            };

            run(None);
            let at = rng.below(plan.len());
            let caught =
                catch_unwind(AssertUnwindSafe(|| run(Some(at)))).is_err();
            assert!(
                caught,
                "seed {seed}: epoch corruption at step {at} of {:?} went \
                 undetected",
                plan
            );
        }
    }

    /// Property: random valid tier custody walks (issue/complete/cancel/
    /// evict with streams mixed in) never panic, and duplicating the
    /// ledger call of any single step — a transition that did not happen
    /// in the structure — always panics by the next reconcile. Same
    /// coverage argument as the queue walk above, over the load/evict/
    /// cancel transition space.
    #[test]
    fn prop_tier_walks_pass_and_random_corruptions_panic() {
        for seed in 0..200u64 {
            let mut rng = Pcg32::seed(seed);
            // ops: 0 = issue cached, 1 = stream (issue + complete),
            //      2 = complete an in-flight entry, 3 = cancel one,
            //      4 = evict a settled entry
            let mut plan: Vec<u8> = Vec::new();
            let (mut inflight, mut settled) = (0usize, 0usize);
            for _ in 0..(4 + rng.below(12)) {
                let mut choices = vec![0u8, 1];
                if inflight > 0 {
                    choices.push(2);
                    choices.push(3);
                }
                if settled > 0 {
                    choices.push(4);
                }
                let op = choices[rng.below(choices.len())];
                match op {
                    0 => inflight += 1,
                    2 => {
                        inflight -= 1;
                        settled += 1;
                    }
                    3 => inflight -= 1,
                    4 => settled -= 1,
                    _ => {}
                }
                plan.push(op);
            }
            // drain in-flight loads so the valid walk can close
            for _ in 0..inflight {
                plan.push(if rng.below(2) == 0 { 2 } else { 3 });
            }

            let run = |corrupt_at: Option<usize>| {
                let mut l = TierLedger::new();
                let (mut inflight, mut settled) = (0usize, 0usize);
                for (i, &op) in plan.iter().enumerate() {
                    match op {
                        0 => {
                            l.issue(true);
                            inflight += 1;
                        }
                        1 => {
                            l.issue(false);
                            l.complete();
                        }
                        2 => {
                            l.complete();
                            inflight -= 1;
                            settled += 1;
                        }
                        3 => {
                            l.cancel();
                            inflight -= 1;
                        }
                        4 => {
                            l.evict();
                            settled -= 1;
                        }
                        _ => unreachable!(),
                    }
                    if corrupt_at == Some(i) {
                        // replay the ledger half of the step without the
                        // structure half: a transition that didn't happen
                        match op {
                            0 => l.issue(true),
                            1 => l.issue(false), // stream that never lands
                            2 => l.complete(),
                            3 => l.cancel(),
                            _ => l.evict(),
                        }
                    }
                    l.reconcile(inflight + settled, inflight);
                }
                l.close_check();
            };

            run(None);
            let at = rng.below(plan.len());
            let caught =
                catch_unwind(AssertUnwindSafe(|| run(Some(at)))).is_err();
            assert!(
                caught,
                "seed {seed}: tier corruption at step {at} of {:?} went \
                 undetected",
                plan
            );
        }
    }
}
