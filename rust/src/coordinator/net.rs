//! Framed TCP serving front-end: many concurrent client connections,
//! multiplexed onto K producer threads, feeding the work-stealing
//! scheduler through class-aware admission (`coordinator::wire`).
//!
//! Layout mirrors the ingest tier it sits beside: an acceptor deals
//! connections to K producers by *position* round-robin (connection `i`
//! to producer `i % k` — the same rule `run_ingest` uses for sources,
//! so there is exactly one assignment convention in the crate); each
//! producer rotates fairly over its live connections, reading a bounded
//! chunk per visit so a firehose client cannot starve its siblings
//! (the ingest tier's fairness rule, applied to sockets); every decoded
//! record passes [`WsDispatch::offer_classed`], which sheds batch and
//! best-effort traffic before realtime under backpressure and sheds
//! deadline-expired frames as stale before they occupy a queue slot.
//!
//! Accounting is per connection and exact, with a fourth drop bucket
//! the in-process tier never needed: `delivered + dropped_stale +
//! dropped_backpressure + dropped_truncated == offered`. *Truncated*
//! counts bytes that never became a well-formed frame — a mid-record
//! client hangup (the remainder is one offered, truncated frame: the
//! PR-5 `feed_frames` rule at the socket edge) or a malformed record
//! (counted, then the connection is closed). The contract is reconciled
//! by a debug-build [`ConnLedger`] at every connection close and
//! re-asserted in release builds after the shutdown barrier.
//!
//! Shutdown protocol (CONCURRENCY.md §Listener shutdown): the acceptor
//! stops at `max_conns` (or when no client arrives within
//! `accept_grace`), drops the producer channels — disconnection IS the
//! signal, there is no shared flag — each producer finishes draining
//! its live connections and returns its reports, and the
//! `thread::scope` joins are the barrier; a producer panic re-raises on
//! the caller rather than vanishing into a bogus report.

use std::io::{self, Read};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::model::Tensor;
use crate::runtime::Backend;
use crate::sync::mpsc;
use crate::sync::thread;

use super::audit::ConnLedger;
use super::executor::BlockExecutor;
use super::registry::PlanRegistry;
use super::replan::CostObs;
use super::server::{Frame, ServePlan};
use super::shard::{
    serve_registry_core, serve_work_stealing_core, Admission, ShardOpts,
    ShardReport, WsDispatch,
};
use crate::sync::mpsc::Sender;
use crate::sync::Arc;
use super::wire::{decode_frame, QosClass, WireFrame};

/// Bytes read from one connection per fair-rotation visit. Bounded so a
/// connection with megabytes buffered cannot monopolize its producer.
const READ_CHUNK: usize = 16 * 1024;
/// Producer nap when a full rotation made no progress (pacing only —
/// never a correctness mechanism; a yield under loom).
const POLL_IDLE: Duration = Duration::from_micros(200);
/// Acceptor nap between nonblocking accept attempts.
const ACCEPT_POLL: Duration = Duration::from_micros(500);

/// Front-end knobs for [`serve_net`].
#[derive(Debug, Clone)]
pub struct NetOpts {
    /// Producer threads multiplexing the connections (≥ 1).
    pub producers: usize,
    /// The serve accepts exactly this many connections, then stops
    /// accepting and drains — the run's natural end. 0 serves nobody.
    pub max_conns: usize,
    /// Per-class admission on/off. Off bypasses BOTH the class shedding
    /// rule and client-deadline staleness (every frame is offered
    /// plainly, dropped only by a hard-full injector) — the measured
    /// baseline the QoS experiments compare against.
    pub qos: bool,
    /// Stop accepting early when no client has connected for this long
    /// (so a run whose clients died does not wait forever).
    pub accept_grace: Duration,
}

impl Default for NetOpts {
    fn default() -> NetOpts {
        NetOpts {
            producers: 1,
            max_conns: 1,
            qos: true,
            accept_grace: Duration::from_millis(500),
        }
    }
}

/// Per-connection accounting, the `SourceReport` of the network edge.
#[derive(Debug, Clone)]
pub struct ConnReport {
    /// Accept-order index (connection `conn` went to producer
    /// `conn % producers`).
    pub conn: usize,
    /// Tenant id from the connection's first decoded record (0 when no
    /// record ever decoded).
    pub tenant: u32,
    pub offered: usize,
    pub delivered: usize,
    /// Shed because the client deadline passed before admission.
    pub dropped_stale: usize,
    /// Shed by the class rule or a hard-full injector.
    pub dropped_backpressure: usize,
    /// Bytes that never became a well-formed frame: the mid-record
    /// hangup remainder, or a malformed record (connection then closed).
    pub dropped_truncated: usize,
}

impl ConnReport {
    pub fn dropped(&self) -> usize {
        self.dropped_stale + self.dropped_backpressure + self.dropped_truncated
    }

    fn empty(conn: usize) -> ConnReport {
        ConnReport {
            conn,
            tenant: 0,
            offered: 0,
            delivered: 0,
            dropped_stale: 0,
            dropped_backpressure: 0,
            dropped_truncated: 0,
        }
    }
}

/// Per-class accounting across every connection. Truncated frames carry
/// no class (the class byte never fully arrived or was garbage), so the
/// class rows cover decoded records only:
/// `Σ classes.offered + truncated == Σ conns.offered`.
#[derive(Debug, Clone)]
pub struct ClassReport {
    pub qos: QosClass,
    pub offered: usize,
    pub delivered: usize,
    pub dropped_stale: usize,
    pub dropped_backpressure: usize,
}

impl ClassReport {
    pub fn dropped(&self) -> usize {
        self.dropped_stale + self.dropped_backpressure
    }
}

/// Aggregate result of one network serve.
#[derive(Debug, Clone)]
pub struct NetReport {
    /// Producer threads actually used.
    pub producers: usize,
    /// Per-connection accounting, in accept order.
    pub conns: Vec<ConnReport>,
    /// Per-class accounting, in shedding-priority order
    /// ([`QosClass::ALL`]).
    pub classes: Vec<ClassReport>,
}

impl NetReport {
    pub fn offered(&self) -> usize {
        self.conns.iter().map(|c| c.offered).sum()
    }

    pub fn delivered(&self) -> usize {
        self.conns.iter().map(|c| c.delivered).sum()
    }

    pub fn dropped(&self) -> usize {
        self.conns.iter().map(|c| c.dropped()).sum()
    }

    pub fn dropped_truncated(&self) -> usize {
        self.conns.iter().map(|c| c.dropped_truncated).sum()
    }

    pub fn class(&self, qos: QosClass) -> &ClassReport {
        &self.classes[qos as usize]
    }

    /// The per-class table `serve --listen` prints (same shape as the
    /// shard error table and the per-source ingest table).
    pub fn class_table(&self) -> String {
        let mut t = String::from(
            "per-class admission (network front-end):\n  class        \
             offered  delivered  stale  backpressure\n",
        );
        for c in &self.classes {
            t.push_str(&format!(
                "  {:<11}  {:>7}  {:>9}  {:>5}  {:>12}\n",
                c.qos.name(),
                c.offered,
                c.delivered,
                c.dropped_stale,
                c.dropped_backpressure
            ));
        }
        let trunc = self.dropped_truncated();
        if trunc > 0 {
            t.push_str(&format!(
                "  ({trunc} truncated/malformed record(s) carry no class)\n"
            ));
        }
        t
    }

    /// Per-tenant row breakdown of the admission table: connections
    /// grouped by the tenant their records declared, with the same
    /// conservation columns as the per-connection reports. Rendered
    /// under the per-class table by `serve --listen`.
    pub fn tenant_table(&self) -> String {
        let mut rows: std::collections::BTreeMap<u32, ConnReport> =
            std::collections::BTreeMap::new();
        for c in &self.conns {
            let r = rows
                .entry(c.tenant)
                .or_insert_with(|| ConnReport::empty(0));
            r.offered += c.offered;
            r.delivered += c.delivered;
            r.dropped_stale += c.dropped_stale;
            r.dropped_backpressure += c.dropped_backpressure;
            r.dropped_truncated += c.dropped_truncated;
        }
        let mut t = String::from(
            "per-tenant admission (network front-end):\n  tenant  \
             offered  delivered  stale  backpressure  truncated\n",
        );
        for (tenant, r) in rows {
            t.push_str(&format!(
                "  {:>6}  {:>7}  {:>9}  {:>5}  {:>12}  {:>9}\n",
                tenant,
                r.offered,
                r.delivered,
                r.dropped_stale,
                r.dropped_backpressure,
                r.dropped_truncated
            ));
        }
        t
    }
}

/// Per-class tallies one producer accumulates (merged at the barrier).
#[derive(Debug, Clone, Copy, Default)]
struct ClassTally {
    offered: usize,
    delivered: usize,
    stale: usize,
    backpressure: usize,
}

/// One live client connection on a producer thread.
struct Conn {
    idx: usize,
    stream: TcpStream,
    /// Bytes read but not yet decoded (at most one partial record after
    /// each pump).
    buf: Vec<u8>,
    /// Arrival stamp of the oldest buffered byte: client deadlines are
    /// measured from the first byte of the record's read burst, stamped
    /// when `buf` goes empty → nonempty. Conservative for frames that
    /// share one burst (they inherit the earliest stamp), which can only
    /// shed a deadline frame early, never admit it late.
    read_at: Instant,
    tenant: Option<u32>,
    offered: usize,
    delivered: usize,
    stale: usize,
    backpressure: usize,
    truncated: usize,
    eof: bool,
    /// Debug-build custody ledger: every offered frame retired exactly
    /// once, reconciled at close (`coordinator::audit`).
    audit: ConnLedger,
}

impl Conn {
    fn new(idx: usize, stream: TcpStream) -> io::Result<Conn> {
        stream.set_nonblocking(true)?;
        Ok(Conn {
            idx,
            stream,
            buf: Vec::new(),
            read_at: Instant::now(),
            tenant: None,
            offered: 0,
            delivered: 0,
            stale: 0,
            backpressure: 0,
            truncated: 0,
            eof: false,
            audit: ConnLedger::new(),
        })
    }

    /// Admit one decoded record through the dispatcher and book the
    /// outcome in the connection, the ledger, and the class tally.
    fn admit(
        &mut self,
        wf: WireFrame,
        d: &WsDispatch,
        qos_on: bool,
        tally: &mut [ClassTally; 3],
    ) {
        let cls = wf.qos;
        self.offered += 1;
        self.audit.offer();
        if self.tenant.is_none() {
            self.tenant = Some(wf.tenant);
        }
        let t = &mut tally[cls as usize];
        t.offered += 1;
        // the client deadline is relative to arrival — the network twin
        // of the ingest tier's `due + slack`
        let deadline = (wf.deadline_us > 0)
            .then(|| self.read_at + Duration::from_micros(wf.deadline_us as u64));
        // the tenant field used to be decoded and dropped here — plan
        // selection ignored it. It now rides the frame into dispatch,
        // where the registry pins the tenant's current plan version
        let frame =
            Frame::with_qos(wf.id, Tensor::new(wf.shape, wf.data), cls, deadline)
                .with_tenant(wf.tenant);
        let adm = if qos_on {
            d.offer_classed(frame)
        } else if d.offer(frame) {
            Admission::Delivered
        } else {
            Admission::Backpressure
        };
        match adm {
            Admission::Delivered => {
                self.delivered += 1;
                self.audit.deliver();
                t.delivered += 1;
            }
            Admission::Stale => {
                self.stale += 1;
                self.audit.stale();
                t.stale += 1;
            }
            Admission::Backpressure => {
                self.backpressure += 1;
                self.audit.backpressure();
                t.backpressure += 1;
            }
        }
    }

    /// One fair-rotation visit: read at most [`READ_CHUNK`] bytes, then
    /// decode and admit every complete record buffered. Returns whether
    /// the visit made progress (bytes read, records admitted, or state
    /// advanced) — a full no-progress rotation is what lets the
    /// producer nap.
    fn pump(
        &mut self,
        d: &WsDispatch,
        qos_on: bool,
        tally: &mut [ClassTally; 3],
    ) -> bool {
        let mut progress = false;
        if !self.eof {
            let mut scratch = [0u8; READ_CHUNK];
            match self.stream.read(&mut scratch) {
                Ok(0) => {
                    self.eof = true;
                    progress = true;
                }
                Ok(n) => {
                    if self.buf.is_empty() {
                        self.read_at = Instant::now();
                    }
                    self.buf.extend_from_slice(&scratch[..n]);
                    progress = true;
                }
                Err(e)
                    if e.kind() == io::ErrorKind::WouldBlock
                        || e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => {
                    // abrupt reset: same custody rule as a clean EOF —
                    // whatever is buffered either decodes below or is
                    // counted truncated at finish
                    self.eof = true;
                    progress = true;
                }
            }
        }
        loop {
            match decode_frame(&self.buf) {
                Ok(Some((wf, used))) => {
                    self.buf.drain(..used);
                    self.admit(wf, d, qos_on, tally);
                    progress = true;
                }
                Ok(None) => break,
                Err(_) => {
                    // a record no conforming client produces: count it
                    // (conservation includes garbage), drop the rest of
                    // the stream, close the connection
                    self.offered += 1;
                    self.audit.offer();
                    self.truncated += 1;
                    self.audit.truncate();
                    self.buf.clear();
                    self.eof = true;
                    progress = true;
                    break;
                }
            }
        }
        progress
    }

    /// Closed and fully decoded: on EOF `pump` has already drained every
    /// complete record, so only an unfinishable partial can remain (it
    /// is counted at [`Conn::finish`]).
    fn done(&self) -> bool {
        self.eof
    }

    /// Connection close: count the mid-record remainder, reconcile the
    /// custody ledger, and emit the report.
    fn finish(mut self) -> ConnReport {
        if !self.buf.is_empty() {
            // mid-frame hangup: the client started a record it never
            // finished — one offered, truncated frame, so
            // delivered + drops == offered survives the hangup
            self.offered += 1;
            self.audit.offer();
            self.truncated += 1;
            self.audit.truncate();
            self.buf.clear();
        }
        self.audit.close(
            self.delivered,
            self.stale,
            self.backpressure,
            self.truncated,
        );
        ConnReport {
            conn: self.idx,
            tenant: self.tenant.unwrap_or(0),
            offered: self.offered,
            delivered: self.delivered,
            dropped_stale: self.stale,
            dropped_backpressure: self.backpressure,
            dropped_truncated: self.truncated,
        }
    }
}

/// What one producer hands back at the barrier.
struct ProducerOut {
    conns: Vec<ConnReport>,
    tally: [ClassTally; 3],
}

/// One producer thread's loop: accept handed-off connections, rotate
/// fairly over the live ones, exit when the acceptor has hung up the
/// channel AND every owned connection has drained.
fn net_produce(
    rx: mpsc::Receiver<(usize, TcpStream)>,
    d: &WsDispatch,
    qos_on: bool,
) -> ProducerOut {
    let mut live: Vec<Conn> = Vec::new();
    let mut done: Vec<ConnReport> = Vec::new();
    let mut tally = [ClassTally::default(); 3];
    let mut accepting = true;
    loop {
        if accepting {
            // idle producers park in recv (no spinning before the first
            // connection); busy ones drain opportunistically
            if live.is_empty() {
                match rx.recv() {
                    Ok((idx, stream)) => match Conn::new(idx, stream) {
                        Ok(c) => live.push(c),
                        // a connection dead before its first read still
                        // gets a (zero) report — conns in == reports out
                        Err(_) => done.push(ConnReport::empty(idx)),
                    },
                    Err(_) => accepting = false,
                }
            }
            loop {
                match rx.try_recv() {
                    Ok((idx, stream)) => match Conn::new(idx, stream) {
                        Ok(c) => live.push(c),
                        Err(_) => done.push(ConnReport::empty(idx)),
                    },
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        accepting = false;
                        break;
                    }
                }
            }
        }
        if live.is_empty() {
            if accepting {
                continue; // park in recv above
            }
            break; // channel closed, every connection drained
        }
        let mut progress = false;
        let mut i = 0;
        while i < live.len() {
            progress |= live[i].pump(d, qos_on, &mut tally);
            if live[i].done() {
                done.push(live.swap_remove(i).finish());
            } else {
                i += 1;
            }
        }
        if !progress {
            thread::sleep(POLL_IDLE); // pacing only, never correctness
        }
    }
    ProducerOut { conns: done, tally }
}

/// Accept up to `net.max_conns` connections, deal them to K producers,
/// and run the multiplex until every connection drains. Called on the
/// feeder thread inside the work-stealing core.
fn run_listener(
    listener: &TcpListener,
    d: &WsDispatch,
    net: &NetOpts,
) -> NetReport {
    let k = net.producers.max(1);
    let outs: Vec<ProducerOut> = thread::scope(|scope| {
        let mut txs = Vec::with_capacity(k);
        let mut handles = Vec::with_capacity(k);
        for _ in 0..k {
            let (tx, rx) = mpsc::channel::<(usize, TcpStream)>();
            txs.push(tx);
            let qos_on = net.qos;
            handles.push(scope.spawn(move || net_produce(rx, d, qos_on)));
        }
        // the acceptor runs inline: connection i to producer i % k — the
        // same positional round-robin rule run_ingest uses for sources
        let mut accepted = 0usize;
        let mut last = Instant::now();
        while accepted < net.max_conns {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    // a send can only fail if the producer died, and a
                    // producer only dies by panicking — the scope join
                    // below re-raises that; stop feeding it meanwhile
                    if txs[accepted % k].send((accepted, stream)).is_err() {
                        break;
                    }
                    accepted += 1;
                    last = Instant::now();
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    if last.elapsed() > net.accept_grace {
                        break; // nobody is coming; drain and report
                    }
                    thread::sleep(ACCEPT_POLL);
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => break, // listener broke; serve what arrived
            }
        }
        // dropping the senders IS the shutdown signal (no shared flag):
        // each producer drains its live connections, sees Disconnected,
        // and returns its reports; these joins are the barrier
        drop(txs);
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(out) => out,
                Err(payload) => std::panic::resume_unwind(payload),
            })
            .collect()
    });

    let mut conns: Vec<ConnReport> = Vec::new();
    let mut merged = [ClassTally::default(); 3];
    for out in outs {
        conns.extend(out.conns);
        for (m, t) in merged.iter_mut().zip(out.tally) {
            m.offered += t.offered;
            m.delivered += t.delivered;
            m.stale += t.stale;
            m.backpressure += t.backpressure;
        }
    }
    conns.sort_by_key(|c| c.conn);
    // the conservation contract is enforced in release builds too, per
    // connection, exactly as run_ingest enforces it per source
    for c in &conns {
        assert_eq!(
            c.delivered + c.dropped(),
            c.offered,
            "connection {} leaks frames",
            c.conn
        );
    }
    let classes: Vec<ClassReport> = QosClass::ALL
        .into_iter()
        .map(|q| {
            let t = merged[q as usize];
            ClassReport {
                qos: q,
                offered: t.offered,
                delivered: t.delivered,
                dropped_stale: t.stale,
                dropped_backpressure: t.backpressure,
            }
        })
        .collect();
    NetReport { producers: k, conns, classes }
}

/// Serve frames arriving over `listener` through the work-stealing
/// scheduler: the network twin of `serve_sharded_sources`. Returns the
/// shard report plus per-connection / per-class accounting; network
/// drops (stale + backpressure + truncated) are the aggregate report's
/// `dropped`, so `frames + dropped == total offered` holds across the
/// socket boundary.
pub fn serve_net<B, F>(
    make_executor: F,
    n_shards: usize,
    plan: &ServePlan,
    listener: TcpListener,
    net: &NetOpts,
    opts: &ShardOpts,
) -> Result<(ShardReport, NetReport)>
where
    B: Backend + Send + 'static,
    F: FnMut(usize) -> Result<BlockExecutor<B>>,
{
    if !opts.steal {
        return Err(anyhow!(
            "the network front-end fronts the work-stealing scheduler; \
             drop --round-robin to use --listen"
        ));
    }
    listener
        .set_nonblocking(true)
        .map_err(|e| anyhow!("cannot make listener nonblocking: {e}"))?;
    let mut slot: Option<NetReport> = None;
    let (report, _) =
        serve_work_stealing_core(make_executor, n_shards, plan, opts, |d| {
            let nr = run_listener(&listener, d, net);
            let dropped = nr.dropped();
            slot = Some(nr);
            (dropped, None)
        })?;
    let nr =
        slot.ok_or_else(|| anyhow!("network feeder returned no report"))?;
    Ok((report, nr))
}

/// Tenant-routed network serving: like [`serve_net`] but frames are
/// dispatched through a [`PlanRegistry`] — each record's wire `tenant`
/// field selects that tenant's current plan version at admission, and
/// hot-swaps published mid-stream take effect for frames admitted after
/// the publish. `obs` (when provided) streams per-task simulated costs
/// to the background replanner.
pub fn serve_net_registry<B, F>(
    make_executor: F,
    n_shards: usize,
    registry: Arc<PlanRegistry>,
    listener: TcpListener,
    net: &NetOpts,
    opts: &ShardOpts,
    obs: Option<Sender<CostObs>>,
) -> Result<(ShardReport, NetReport)>
where
    B: Backend + Send + 'static,
    F: FnMut(usize) -> Result<BlockExecutor<B>>,
{
    if !opts.steal {
        return Err(anyhow!(
            "the network front-end fronts the work-stealing scheduler; \
             drop --round-robin to use --listen"
        ));
    }
    listener
        .set_nonblocking(true)
        .map_err(|e| anyhow!("cannot make listener nonblocking: {e}"))?;
    let mut slot: Option<NetReport> = None;
    let (report, _) = serve_registry_core(
        make_executor,
        n_shards,
        registry,
        opts,
        obs,
        |d| {
            let nr = run_listener(&listener, d, net);
            let dropped = nr.dropped();
            slot = Some(nr);
            (dropped, None)
        },
    )?;
    let nr =
        slot.ok_or_else(|| anyhow!("network feeder returned no report"))?;
    Ok((report, nr))
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::coordinator::wire::encode_frame;
    use crate::device::Device;
    use crate::runtime::ReferenceBackend;
    use crate::taskgraph::{Partition, TaskGraph};
    use crate::trainer::GraphWeights;
    use crate::util::rng::Pcg32;
    use std::io::Write;
    use std::net::TcpStream as ClientStream;

    fn make_executor(_s: usize) -> Result<BlockExecutor<ReferenceBackend>> {
        let backend = ReferenceBackend::new();
        let arch = backend.arch("cnn5")?;
        let graph = TaskGraph::new(
            3,
            vec![1, 3, 4],
            vec![
                Partition(vec![0, 0, 0]),
                Partition(vec![0, 0, 0]),
                Partition(vec![0, 0, 1]),
                Partition::singletons(3),
            ],
        )?;
        let ncls = vec![2, 2, 2];
        let mut rng = Pcg32::seed(7);
        let store = GraphWeights::init(&graph, &arch, &ncls, &mut rng);
        Ok(BlockExecutor::new(
            backend,
            Device::msp430(),
            arch,
            graph,
            ncls,
            store,
        ))
    }

    /// A well-formed wire record the test executor accepts (its graph
    /// takes 1×16×16×1 inputs).
    fn record(id: u64, tenant: u32, qos: QosClass, deadline_us: u32) -> Vec<u8> {
        let mut rng = Pcg32::seed(id ^ 0x5eed);
        encode_frame(&WireFrame {
            id,
            tenant,
            qos,
            deadline_us,
            shape: vec![1, 16, 16, 1],
            data: (0..256).map(|_| rng.gauss() as f32).collect(),
        })
    }

    fn net_opts(conns: usize, producers: usize) -> NetOpts {
        NetOpts {
            producers,
            max_conns: conns,
            qos: true,
            accept_grace: Duration::from_secs(5),
        }
    }

    #[test]
    fn loopback_frames_are_served_and_conserved() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let clients: Vec<_> = (0..3u32)
            .map(|t| {
                thread::spawn(move || {
                    let mut s = ClientStream::connect(addr).unwrap();
                    for i in 0..4u64 {
                        let rec = record(
                            u64::from(t) * 100 + i,
                            t,
                            QosClass::Realtime,
                            0,
                        );
                        s.write_all(&rec).unwrap();
                    }
                })
            })
            .collect();
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let (sr, nr) = serve_net(
            make_executor,
            2,
            &plan,
            listener,
            &net_opts(3, 2),
            &ShardOpts::default(),
        )
        .unwrap();
        for c in clients {
            c.join().unwrap();
        }
        assert_eq!(nr.conns.len(), 3);
        assert_eq!(nr.offered(), 12);
        for c in &nr.conns {
            assert_eq!(
                c.delivered + c.dropped(),
                c.offered,
                "conn {} leaks",
                c.conn
            );
            assert_eq!(c.offered, 4);
            assert_eq!(c.dropped_truncated, 0);
        }
        // tenants map 1:1 onto connections (accept order is arbitrary)
        let mut tenants: Vec<u32> = nr.conns.iter().map(|c| c.tenant).collect();
        tenants.sort_unstable();
        assert_eq!(tenants, vec![0, 1, 2]);
        // the serve side saw exactly the delivered frames
        assert_eq!(sr.aggregate.frames + sr.aggregate.dropped, 12);
        assert_eq!(sr.aggregate.frames, nr.delivered());
        assert_eq!(nr.class(QosClass::Realtime).offered, 12);
        assert!(nr.class_table().contains("realtime"));
        // the wire tenant rides the frame all the way into the shard
        // results (it used to be decoded and dropped at admission)
        for r in &sr.results {
            assert_eq!(u64::from(r.tenant), r.id / 100, "frame {}", r.id);
        }
        let per_tenant = sr.frames_per_tenant();
        assert_eq!(per_tenant, vec![(0, 4), (1, 4), (2, 4)]);
        let tt = nr.tenant_table();
        for tenant in 0..3u32 {
            assert!(tt.contains(&format!("\n  {tenant:>6}  ")), "{tt}");
        }
    }

    #[test]
    fn registry_routes_wire_tenants_to_their_own_plans() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let clients: Vec<_> = (0..2u32)
            .map(|t| {
                thread::spawn(move || {
                    let mut s = ClientStream::connect(addr).unwrap();
                    for i in 0..5u64 {
                        let rec = record(
                            u64::from(t) * 100 + i,
                            t,
                            QosClass::Realtime,
                            0,
                        );
                        s.write_all(&rec).unwrap();
                    }
                })
            })
            .collect();
        let registry = Arc::new(PlanRegistry::new(vec![
            ServePlan::unconditional(vec![0, 1, 2]),
            ServePlan::unconditional(vec![2, 1, 0]),
        ]));
        let (sr, nr) = serve_net_registry(
            make_executor,
            2,
            Arc::clone(&registry),
            listener,
            &net_opts(2, 2),
            &ShardOpts::default(),
            None,
        )
        .unwrap();
        for c in clients {
            c.join().unwrap();
        }
        assert_eq!(nr.offered(), 10);
        assert_eq!(sr.frames_per_tenant(), vec![(0, 5), (1, 5)]);
        // every epoch the registry tracked balanced and retired its pins
        registry.close_check();
        assert_eq!(sr.epochs.len(), 2);
        for row in &sr.epochs {
            assert_eq!(row.admitted, row.completed, "{row:?}");
        }
    }

    #[test]
    fn mid_record_hangup_counts_the_remainder_truncated() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = thread::spawn(move || {
            let mut s = ClientStream::connect(addr).unwrap();
            s.write_all(&record(1, 9, QosClass::Realtime, 0)).unwrap();
            s.write_all(&record(2, 9, QosClass::BestEffort, 0)).unwrap();
            // start a third record and hang up mid-frame
            let partial = record(3, 9, QosClass::Realtime, 0);
            s.write_all(&partial[..partial.len() / 2]).unwrap();
            // dropping the stream closes the socket abruptly
        });
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let (_, nr) = serve_net(
            make_executor,
            1,
            &plan,
            listener,
            &net_opts(1, 1),
            &ShardOpts::default(),
        )
        .unwrap();
        client.join().unwrap();
        let c = &nr.conns[0];
        assert_eq!(c.tenant, 9);
        // the two whole records plus the unfinished one are all offered;
        // the remainder is truncated, not vanished
        assert_eq!(c.offered, 3);
        assert_eq!(c.dropped_truncated, 1);
        assert_eq!(c.delivered + c.dropped(), c.offered);
        assert_eq!(nr.dropped_truncated(), 1);
        // class rows cover decoded records only; truncated has no class
        let class_offered: usize =
            nr.classes.iter().map(|cl| cl.offered).sum();
        assert_eq!(class_offered + nr.dropped_truncated(), nr.offered());
    }

    #[test]
    fn malformed_record_is_counted_and_closes_the_connection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = thread::spawn(move || {
            let mut s = ClientStream::connect(addr).unwrap();
            s.write_all(&record(1, 4, QosClass::Batch, 0)).unwrap();
            // corrupt the class byte of an otherwise valid record
            let mut bad = record(2, 4, QosClass::Batch, 0);
            bad[16] = 7;
            s.write_all(&bad).unwrap();
            // a valid record after the garbage must NOT be admitted —
            // framing is unrecoverable after a malformed record
            s.write_all(&record(3, 4, QosClass::Batch, 0)).unwrap();
        });
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let (_, nr) = serve_net(
            make_executor,
            1,
            &plan,
            listener,
            &net_opts(1, 1),
            &ShardOpts::default(),
        )
        .unwrap();
        client.join().unwrap();
        let c = &nr.conns[0];
        assert_eq!(c.offered, 2, "one good record + the malformed one");
        assert_eq!(c.dropped_truncated, 1);
        assert_eq!(c.delivered + c.dropped(), c.offered);
    }

    #[test]
    fn expired_client_deadline_sheds_stale_before_the_queue() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = thread::spawn(move || {
            let mut s = ClientStream::connect(addr).unwrap();
            // a ~1 MiB record with a 1 µs deadline: the multi-chunk
            // transfer alone takes far longer than the budget, so it is
            // stale on arrival in any schedule
            let mut rng = Pcg32::seed(3);
            let big = encode_frame(&WireFrame {
                id: 1,
                tenant: 2,
                qos: QosClass::Realtime,
                deadline_us: 1,
                shape: vec![1, 512, 512, 1],
                data: (0..512 * 512).map(|_| rng.gauss() as f32).collect(),
            });
            s.write_all(&big).unwrap();
            // a small no-deadline record on the same connection still
            // gets through — staleness is per frame, not per connection
            s.write_all(&record(2, 2, QosClass::Realtime, 0)).unwrap();
        });
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let (_, nr) = serve_net(
            make_executor,
            1,
            &plan,
            listener,
            &net_opts(1, 1),
            &ShardOpts::default(),
        )
        .unwrap();
        client.join().unwrap();
        let c = &nr.conns[0];
        assert_eq!(c.offered, 2);
        assert_eq!(c.dropped_stale, 1, "expired deadline must shed");
        assert_eq!(c.delivered, 1);
        assert_eq!(nr.class(QosClass::Realtime).dropped_stale, 1);
    }

    #[test]
    fn zero_conns_serves_nobody_and_reports_cleanly() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let (sr, nr) = serve_net(
            make_executor,
            1,
            &plan,
            listener,
            &net_opts(0, 1),
            &ShardOpts::default(),
        )
        .unwrap();
        assert!(nr.conns.is_empty());
        assert_eq!(nr.offered(), 0);
        assert_eq!(sr.aggregate.frames, 0);
    }

    #[test]
    fn round_robin_baseline_is_rejected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let opts = ShardOpts { steal: false, ..ShardOpts::default() };
        let err = serve_net(
            make_executor,
            1,
            &plan,
            listener,
            &net_opts(1, 1),
            &opts,
        )
        .err()
        .map(|e| e.to_string())
        .unwrap_or_default();
        assert!(err.contains("--listen"), "unexpected error: {err}");
    }
}
