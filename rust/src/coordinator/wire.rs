//! Wire format for the framed TCP serving front-end
//! (`coordinator::net`): length-prefixed records carrying
//! `{frame id, tenant id, QoS class, client deadline, tensor}` — and the
//! pure per-class admission rule the listener applies against the
//! scheduler's bounded injector.
//!
//! Record layout (all integers little-endian):
//!
//! ```text
//! u32  len         bytes that follow this field (exactly)
//! u64  id          frame id (client-chosen; per-source FIFO order)
//! u32  tenant      tenant / source id for per-tenant accounting
//! u8   qos         0 = realtime, 1 = best-effort, 2 = batch
//! u32  deadline_us client deadline in µs from the frame's arrival at
//!                  the server; 0 = none. Plays exactly the role of the
//!                  ingest tier's staleness `slack`: a frame admitted
//!                  more than `deadline_us` after its first byte arrived
//!                  is shed as stale, before any downstream cost.
//! u8   ndims       tensor rank, 1..=MAX_DIMS
//! u32 × ndims      dims (each nonzero; product ≤ MAX_ELEMS)
//! f32 × prod(dims) payload
//! ```
//!
//! Decoding is incremental: [`decode_frame`] returns `Ok(None)` while
//! the buffer holds only part of a record (read more), and a hard
//! [`WireError`] for a record no well-behaved client produces — the
//! connection is then closed and the offending frame is *counted*, not
//! leaked (the conservation contract extends to garbage input).

use crate::model::Tensor;

/// Admission class carried by every wire frame. The declaration order is
/// the shedding order, most protected first: under backpressure the
/// listener sheds [`QosClass::Batch`] traffic before
/// [`QosClass::BestEffort`] and both before [`QosClass::Realtime`] —
/// see [`QosClass::admit_at`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum QosClass {
    /// Admitted whenever the injector has any room at all.
    Realtime = 0,
    /// Refused above 3/4 injector occupancy.
    BestEffort = 1,
    /// Refused above 1/2 injector occupancy.
    Batch = 2,
}

impl QosClass {
    /// All classes, in shedding-priority order (most protected first) —
    /// the canonical iteration order for per-class report tables.
    pub const ALL: [QosClass; 3] =
        [QosClass::Realtime, QosClass::BestEffort, QosClass::Batch];

    pub fn from_u8(v: u8) -> Option<QosClass> {
        match v {
            0 => Some(QosClass::Realtime),
            1 => Some(QosClass::BestEffort),
            2 => Some(QosClass::Batch),
            _ => None,
        }
    }

    /// Parse a CLI / config spelling.
    pub fn parse(s: &str) -> Option<QosClass> {
        match s {
            "realtime" | "rt" => Some(QosClass::Realtime),
            "best-effort" | "be" => Some(QosClass::BestEffort),
            "batch" => Some(QosClass::Batch),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            QosClass::Realtime => "realtime",
            QosClass::BestEffort => "best-effort",
            QosClass::Batch => "batch",
        }
    }

    /// The per-class admission rule: may a frame of this class enter the
    /// scheduler when `backlog` frames are already queued against a
    /// bounded injector of `capacity`? Realtime uses the whole queue
    /// (only a hard-full injector can drop it); best-effort yields the
    /// top quarter of the queue to realtime; batch yields the top half.
    /// Integer arithmetic, no rounding surprises:
    ///
    /// * realtime: always true (the push itself enforces `capacity`);
    /// * best-effort: `backlog * 4 < capacity * 3` (below 3/4 full);
    /// * batch: `backlog * 2 < capacity` (below 1/2 full).
    ///
    /// The rule is monotone in both directions — a class is never
    /// admitted at a deeper backlog than a more-protected class, and
    /// admission never resumes as backlog grows — which is exactly the
    /// "never drop realtime before best-effort" ordering the property
    /// test replays (`prop_qos_shedding_never_drops_realtime_before_best_effort`).
    pub fn admit_at(self, backlog: usize, capacity: usize) -> bool {
        match self {
            QosClass::Realtime => true,
            QosClass::BestEffort => backlog * 4 < capacity * 3,
            QosClass::Batch => backlog * 2 < capacity,
        }
    }
}

impl std::fmt::Display for QosClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One decoded wire record.
#[derive(Debug, Clone, PartialEq)]
pub struct WireFrame {
    pub id: u64,
    pub tenant: u32,
    pub qos: QosClass,
    /// Client deadline in µs from arrival; 0 = none.
    pub deadline_us: u32,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl WireFrame {
    pub fn into_tensor(self) -> Tensor {
        Tensor::new(self.shape, self.data)
    }
}

/// A record no conforming client produces (bad class byte, absurd
/// shape, inconsistent length). Fatal for the connection.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError(pub String);

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "wire error: {}", self.0)
    }
}

impl std::error::Error for WireError {}

/// Max tensor rank a record may declare.
pub const MAX_DIMS: usize = 8;
/// Max payload elements a record may declare (4 MiB of f32) — the
/// allocation bound that keeps a hostile length field from OOMing the
/// producer before validation.
pub const MAX_ELEMS: usize = 1 << 20;

/// Fixed header bytes after the length prefix: id(8) + tenant(4) +
/// qos(1) + deadline(4) + ndims(1).
const FIXED: usize = 18;
/// Upper bound of `len` for any valid record.
const MAX_LEN: usize = FIXED + 4 * MAX_DIMS + 4 * MAX_ELEMS;

/// Encode one record (the client side; tests and `examples/` use it).
pub fn encode_frame(f: &WireFrame) -> Vec<u8> {
    let numel: usize = f.shape.iter().product();
    debug_assert_eq!(numel, f.data.len(), "shape/data mismatch");
    let len = FIXED + 4 * f.shape.len() + 4 * f.data.len();
    let mut out = Vec::with_capacity(4 + len);
    out.extend_from_slice(&(len as u32).to_le_bytes());
    out.extend_from_slice(&f.id.to_le_bytes());
    out.extend_from_slice(&f.tenant.to_le_bytes());
    out.push(f.qos as u8);
    out.extend_from_slice(&f.deadline_us.to_le_bytes());
    out.push(f.shape.len() as u8);
    for &d in &f.shape {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    for &v in &f.data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn rd_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

fn rd_u64(b: &[u8], at: usize) -> u64 {
    let mut raw = [0u8; 8];
    raw.copy_from_slice(&b[at..at + 8]);
    u64::from_le_bytes(raw)
}

/// Try to decode one record from the front of `buf`.
///
/// * `Ok(Some((frame, consumed)))` — a complete record; the caller
///   drains `consumed` bytes and admits the frame.
/// * `Ok(None)` — the buffer ends inside the record; read more. If the
///   connection closes here instead, the partial record is the
///   "mid-frame hangup remainder" the caller must count as dropped.
/// * `Err(_)` — malformed; close the connection and count the record.
pub fn decode_frame(
    buf: &[u8],
) -> Result<Option<(WireFrame, usize)>, WireError> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = rd_u32(buf, 0) as usize;
    if !(FIXED + 4..=MAX_LEN).contains(&len) {
        return Err(WireError(format!(
            "record length {len} outside [{}, {MAX_LEN}]",
            FIXED + 4
        )));
    }
    // validate the class byte and the declared shape as soon as their
    // bytes exist — a hostile header is rejected before its (possibly
    // huge) payload is ever awaited
    if buf.len() >= 4 + FIXED {
        let qos_byte = buf[4 + 12];
        if QosClass::from_u8(qos_byte).is_none() {
            return Err(WireError(format!("unknown QoS class {qos_byte}")));
        }
        let ndims = buf[4 + 17] as usize;
        if !(1..=MAX_DIMS).contains(&ndims) {
            return Err(WireError(format!(
                "rank {ndims} outside [1, {MAX_DIMS}]"
            )));
        }
        // a lying header whose `len` ends inside its own dims list would
        // otherwise reach the unchecked reads below when the buffer ends
        // exactly at `4 + len` (the dims validation just after is guarded
        // on the dims bytes existing). Requiring `len` to cover the rank
        // plus one element makes that validation unskippable before a
        // full decode.
        if len < FIXED + 4 * ndims + 4 {
            return Err(WireError(format!(
                "length {len} too small for rank {ndims}"
            )));
        }
        if buf.len() >= 4 + FIXED + 4 * ndims {
            let mut numel = 1usize;
            for i in 0..ndims {
                let d = rd_u32(buf, 4 + FIXED + 4 * i) as usize;
                if d == 0 {
                    return Err(WireError("zero dim".into()));
                }
                numel = numel.saturating_mul(d);
            }
            if numel > MAX_ELEMS {
                return Err(WireError(format!(
                    "payload {numel} elements exceeds {MAX_ELEMS}"
                )));
            }
            if len != FIXED + 4 * ndims + 4 * numel {
                return Err(WireError(format!(
                    "length {len} disagrees with rank {ndims} × {numel} \
                     elements"
                )));
            }
        }
    }
    if buf.len() < 4 + len {
        return Ok(None); // incomplete: need more bytes
    }
    let id = rd_u64(buf, 4);
    let tenant = rd_u32(buf, 12);
    // lint:allow(panic) — framing: `buf.len() >= 4 + len` was checked
    // above and `len >= FIXED`, so every fixed-header byte is in range
    let qos_byte = buf[16];
    let qos = match QosClass::from_u8(qos_byte) {
        Some(q) => q,
        None => return Err(WireError(format!("unknown QoS class {qos_byte}"))),
    };
    let deadline_us = rd_u32(buf, 17);
    let ndims = buf[21] as usize; // lint:allow(panic) — within the checked fixed header
    let mut shape = Vec::with_capacity(ndims);
    let mut numel = 1usize;
    for i in 0..ndims {
        let d = rd_u32(buf, 22 + 4 * i) as usize;
        shape.push(d);
        numel = numel.saturating_mul(d);
    }
    let base = 22 + 4 * ndims;
    let mut data = Vec::with_capacity(numel);
    for i in 0..numel {
        let at = base + 4 * i;
        data.push(f32::from_le_bytes([
            buf[at],
            buf[at + 1],
            buf[at + 2],
            buf[at + 3],
        ]));
    }
    Ok(Some((WireFrame { id, tenant, qos, deadline_us, shape, data }, 4 + len)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(id: u64, qos: QosClass) -> WireFrame {
        WireFrame {
            id,
            tenant: 7,
            qos,
            deadline_us: 250,
            shape: vec![1, 2, 2, 1],
            data: vec![0.5, -1.25, 3.0, 0.0],
        }
    }

    #[test]
    fn roundtrip_exact() {
        for qos in QosClass::ALL {
            let f = frame(42, qos);
            let bytes = encode_frame(&f);
            let (got, used) = decode_frame(&bytes).unwrap().unwrap();
            assert_eq!(used, bytes.len());
            assert_eq!(got, f);
        }
    }

    #[test]
    fn decodes_back_to_back_records() {
        let a = frame(1, QosClass::Realtime);
        let b = frame(2, QosClass::Batch);
        let mut bytes = encode_frame(&a);
        bytes.extend(encode_frame(&b));
        let (got_a, used) = decode_frame(&bytes).unwrap().unwrap();
        assert_eq!(got_a, a);
        let (got_b, used_b) = decode_frame(&bytes[used..]).unwrap().unwrap();
        assert_eq!(got_b, b);
        assert_eq!(used + used_b, bytes.len());
    }

    #[test]
    fn incomplete_record_wants_more_at_every_prefix() {
        let bytes = encode_frame(&frame(9, QosClass::BestEffort));
        for cut in 0..bytes.len() {
            assert_eq!(
                decode_frame(&bytes[..cut]).unwrap(),
                None,
                "prefix of {cut} bytes should be incomplete"
            );
        }
    }

    #[test]
    fn rejects_bad_class_rank_dims_and_length() {
        let good = encode_frame(&frame(1, QosClass::Realtime));
        // class byte 3 is undefined
        let mut bad = good.clone();
        bad[16] = 3;
        assert!(decode_frame(&bad).is_err());
        // rank 0 and rank > MAX_DIMS
        let mut bad = good.clone();
        bad[21] = 0;
        assert!(decode_frame(&bad).is_err());
        let mut bad = good.clone();
        bad[21] = MAX_DIMS as u8 + 1;
        assert!(decode_frame(&bad).is_err());
        // zero dim
        let mut bad = good.clone();
        bad[22..26].copy_from_slice(&0u32.to_le_bytes());
        assert!(decode_frame(&bad).is_err());
        // length prefix disagreeing with the shape
        let mut bad = good.clone();
        let wrong = (FIXED + 4 * 4 + 4 * 5) as u32; // claims 5 elements
        bad[0..4].copy_from_slice(&wrong.to_le_bytes());
        assert!(decode_frame(&bad).is_err());
        // absurd length rejected before the payload is awaited
        let mut bad = good[..8].to_vec();
        bad[0..4].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(decode_frame(&bad).is_err());
    }

    #[test]
    fn lying_length_ending_inside_the_dims_list_is_rejected() {
        // len = 22 passes the range check but cannot cover the 8 dims
        // the rank byte declares; with the buffer ending exactly at
        // 4 + len, the dims reads would run off the end of the record.
        let mut bad = Vec::new();
        bad.extend_from_slice(&22u32.to_le_bytes()); // len = FIXED + 4
        bad.extend_from_slice(&1u64.to_le_bytes()); // id
        bad.extend_from_slice(&0u32.to_le_bytes()); // tenant
        bad.push(0); // qos realtime
        bad.extend_from_slice(&0u32.to_le_bytes()); // deadline
        bad.push(8); // rank 8: needs 32 dim bytes, len leaves 4
        bad.extend_from_slice(&[0xAA; 4]); // buffer ends at 4 + len
        assert_eq!(bad.len(), 26);
        assert!(decode_frame(&bad).is_err());
        // and every prefix is still a clean "incomplete" or the same error
        for cut in 0..bad.len() {
            let _ = decode_frame(&bad[..cut]);
        }
    }

    #[test]
    fn hostile_shape_rejected_before_payload_arrives() {
        // a header declaring MAX_ELEMS+ elements is rejected from the
        // header bytes alone — no multi-megabyte buffering first
        let f = WireFrame {
            id: 1,
            tenant: 0,
            qos: QosClass::Realtime,
            deadline_us: 0,
            shape: vec![2048, 2048], // 4M elements > MAX_ELEMS
            data: vec![],
        };
        let mut bytes = encode_frame(&f);
        // fix up the length field to what the shape implies so only the
        // element bound can object
        let len = (FIXED + 4 * 2 + 4 * 2048 * 2048) as u32;
        bytes[0..4].copy_from_slice(&len.to_le_bytes());
        let header_only = &bytes[..4 + FIXED + 8];
        assert!(decode_frame(header_only).is_err());
    }

    #[test]
    fn admit_rule_is_monotone_and_ordered() {
        let cap = 64;
        for backlog in 0..=cap {
            let rt = QosClass::Realtime.admit_at(backlog, cap);
            let be = QosClass::BestEffort.admit_at(backlog, cap);
            let ba = QosClass::Batch.admit_at(backlog, cap);
            // shedding order: batch first, realtime last
            assert!(rt || !be, "best-effort admitted where realtime shed");
            assert!(be || !ba, "batch admitted where best-effort shed");
            assert!(rt, "realtime never refused by the class rule");
        }
        // thresholds land exactly at 1/2 and 3/4
        assert!(QosClass::Batch.admit_at(31, 64));
        assert!(!QosClass::Batch.admit_at(32, 64));
        assert!(QosClass::BestEffort.admit_at(47, 64));
        assert!(!QosClass::BestEffort.admit_at(48, 64));
        // monotone in backlog: admission never resumes as the queue grows
        for cls in [QosClass::BestEffort, QosClass::Batch] {
            let mut admitted = true;
            for backlog in 0..=64 {
                let now = cls.admit_at(backlog, 64);
                assert!(admitted || !now, "{cls} re-admitted at {backlog}");
                admitted = now;
            }
        }
    }

    #[test]
    fn parse_names() {
        assert_eq!(QosClass::parse("realtime"), Some(QosClass::Realtime));
        assert_eq!(QosClass::parse("best-effort"), Some(QosClass::BestEffort));
        assert_eq!(QosClass::parse("batch"), Some(QosClass::Batch));
        assert_eq!(QosClass::parse("bulk"), None);
        for q in QosClass::ALL {
            assert_eq!(QosClass::parse(q.name()), Some(q));
            assert_eq!(QosClass::from_u8(q as u8), Some(q));
        }
    }
}
