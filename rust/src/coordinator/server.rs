//! The serving loop: sensor frames → request queue → ordered multitask
//! execution with conditional skipping → metrics.
//!
//! The executor owns its backend on one dedicated thread — for PJRT
//! because the engine is `Rc`-based (!Send), and in general as the
//! faithful model of the paper's single-core MCU. Producers (sensor
//! sources) and the metrics collector run on their own threads and talk
//! over channels; backpressure is a bounded queue (frames dropped when
//! the device cannot keep up, counted in the report, as a real sampling
//! front-end would). For multi-core serving over `Send` backends, see
//! `coordinator::shard`.

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::device::Cost;
use crate::model::Tensor;
use crate::runtime::Backend;
use crate::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use crate::sync::{thread, Arc};
use crate::util::stats;

use super::audit::FeedLedger;
use super::executor::BlockExecutor;
use super::registry::PlanVersion;
use super::wire::QosClass;

/// Ordering + runtime-dependency plan for the task set.
#[derive(Debug, Clone)]
pub struct ServePlan {
    /// Execution order (already satisfies precedence constraints).
    pub order: Vec<usize>,
    /// (prereq, dependent): dependent is skipped at runtime when the
    /// prerequisite's predicted class is 0 ("absent") — the §4.3
    /// conditional mechanism.
    pub conditional: Vec<(usize, usize)>,
}

impl ServePlan {
    pub fn unconditional(order: Vec<usize>) -> ServePlan {
        ServePlan { order, conditional: vec![] }
    }
}

/// One sensor frame to classify with every task.
pub struct Frame {
    pub id: u64,
    pub input: Tensor, // batch-1
    pub enqueued: Instant,
    /// Admission class (network front-end; `coordinator::wire`).
    /// In-process sources are [`QosClass::Realtime`], which the class
    /// rule always admits — so every pre-existing path is unchanged.
    pub qos: QosClass,
    /// Absolute client deadline, the network-edge twin of the ingest
    /// tier's staleness `slack`: a frame admitted after this instant is
    /// shed as `dropped_stale` before any downstream cost. `None` =
    /// no deadline.
    pub deadline: Option<Instant>,
    /// Plan-routing tenant (`coordinator::wire` decodes it off the
    /// network; in-process sources default to 0). The registry maps it
    /// to a [`ServePlan`] at admission.
    pub tenant: u32,
    /// The plan version this frame was admitted under — pinned at
    /// dispatch (`WsDispatch::offer`) by cloning the tenant's current
    /// `Arc<PlanVersion>` into the frame, so an epoch hot-swap cannot
    /// change the plan of a frame already in flight. `None` on paths
    /// that never touch a registry (the single-executor loop, the
    /// round-robin baseline).
    pub version: Option<Arc<PlanVersion>>,
}

impl Frame {
    /// Stamp a frame at hand-off time: `enqueued` starts the
    /// queue-wait/latency clocks every serving path reports.
    pub fn new(id: u64, input: Tensor) -> Frame {
        Frame {
            id,
            input,
            enqueued: Instant::now(),
            qos: QosClass::Realtime,
            deadline: None,
            tenant: 0,
            version: None,
        }
    }

    /// A classed frame from the network front-end.
    pub fn with_qos(
        id: u64,
        input: Tensor,
        qos: QosClass,
        deadline: Option<Instant>,
    ) -> Frame {
        Frame {
            id,
            input,
            enqueued: Instant::now(),
            qos,
            deadline,
            tenant: 0,
            version: None,
        }
    }

    /// Same frame, routed to `tenant`'s plan.
    pub fn with_tenant(mut self, tenant: u32) -> Frame {
        self.tenant = tenant;
        self
    }

    /// Has the client deadline passed as of `now`? (`false` when the
    /// frame carries none.)
    pub fn past_deadline(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now > d)
    }
}

/// Per-frame inference result.
#[derive(Debug, Clone)]
pub struct FrameResult {
    pub id: u64,
    /// Tenant whose plan served this frame (0 on single-tenant paths).
    pub tenant: u32,
    /// Plan epoch the frame was admitted under (0 on paths with no
    /// registry). The hot-swap property test keys its per-epoch
    /// baselines off this field.
    pub epoch: u64,
    /// Predicted class per task; None = skipped by a conditional.
    pub predictions: Vec<Option<usize>>,
    pub sim_cost: Cost,
    pub wall_latency_s: f64,
    pub queue_wait_s: f64,
}

/// Aggregate serving metrics (the serving-paper deliverable: latency /
/// throughput / simulated device cost).
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub frames: usize,
    pub dropped: usize,
    pub wall_s: f64,
    pub throughput_fps: f64,
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_p99_ms: f64,
    pub sim_time_per_frame_s: f64,
    pub sim_energy_per_frame_j: f64,
    pub tasks_skipped: usize,
    pub layer_execs: u64,
    pub layer_skips: u64,
}

/// Aggregate per-frame results into a [`ServeReport`] — shared by the
/// single-executor loop and the sharded pool.
pub fn build_report(
    results: &[FrameResult],
    dropped: usize,
    wall_s: f64,
    tasks_skipped: usize,
    layer_execs: u64,
    layer_skips: u64,
) -> ServeReport {
    if results.is_empty() {
        // all frames dropped (or none offered): an explicitly well-formed
        // zero report — no percentiles over an empty sample, no 0/0
        return ServeReport {
            frames: 0,
            dropped,
            wall_s,
            throughput_fps: 0.0,
            latency_p50_ms: 0.0,
            latency_p95_ms: 0.0,
            latency_p99_ms: 0.0,
            sim_time_per_frame_s: 0.0,
            sim_energy_per_frame_j: 0.0,
            tasks_skipped,
            layer_execs,
            layer_skips,
        };
    }
    let lat_ms: Vec<f64> =
        results.iter().map(|r| r.wall_latency_s * 1e3).collect();
    let n = results.len();
    ServeReport {
        frames: results.len(),
        dropped,
        wall_s,
        throughput_fps: results.len() as f64 / wall_s.max(1e-12),
        latency_p50_ms: stats::percentile(&lat_ms, 50.0),
        latency_p95_ms: stats::percentile(&lat_ms, 95.0),
        latency_p99_ms: stats::percentile(&lat_ms, 99.0),
        sim_time_per_frame_s: results.iter().map(|r| r.sim_cost.time()).sum::<f64>()
            / n as f64,
        sim_energy_per_frame_j: results
            .iter()
            .map(|r| r.sim_cost.energy())
            .sum::<f64>()
            / n as f64,
        tasks_skipped,
        layer_execs,
        layer_skips,
    }
}

/// Execute one frame's full multitask round on the executor. Returns the
/// frame's result plus the number of conditionally skipped tasks — the
/// unit of work shared by the single-executor loop and every shard
/// scheduler (`coordinator::shard`).
pub fn process_frame<B: Backend>(
    exec: &mut BlockExecutor<B>,
    plan: &ServePlan,
    frame: Frame,
) -> Result<(FrameResult, usize)> {
    process_frame_observed(exec, plan, frame, None)
}

/// [`process_frame`] with an optional per-task cost observer: `obs` is
/// called `(task, simulated_seconds)` after each executed task — the
/// signal the cost-drift replanner (`coordinator::replan`) accumulates.
/// Simulated device seconds, not host wall time, so the observations
/// are deterministic and comparable to the `Device` cost model the
/// plans were compiled from. `None` skips all observation bookkeeping.
pub fn process_frame_observed<B: Backend>(
    exec: &mut BlockExecutor<B>,
    plan: &ServePlan,
    frame: Frame,
    mut obs: Option<&mut dyn FnMut(usize, f64)>,
) -> Result<(FrameResult, usize)> {
    let started = Instant::now();
    let queue_wait = started.duration_since(frame.enqueued).as_secs_f64();
    let n = exec.graph.n_tasks;
    let mut preds: Vec<Option<usize>> = vec![None; n];
    let mut cost = Cost::default();
    let mut skipped = 0usize;
    for &t in &plan.order {
        // conditional skip: prerequisite predicted "absent" (class 0)
        let gated = plan
            .conditional
            .iter()
            .any(|&(pre, dep)| dep == t && preds[pre] == Some(0));
        if gated {
            skipped += 1;
            continue;
        }
        let (pred, c) = exec.run_task(frame.id, t, &frame.input)?;
        if let Some(f) = obs.as_deref_mut() {
            f(t, c.time());
        }
        preds[t] = Some(pred);
        cost.add(c);
    }
    Ok((
        FrameResult {
            id: frame.id,
            tenant: frame.tenant,
            epoch: frame.version.as_ref().map_or(0, |v| v.epoch),
            predictions: preds,
            sim_cost: cost,
            wall_latency_s: frame.enqueued.elapsed().as_secs_f64(),
            queue_wait_s: queue_wait,
        },
        skipped,
    ))
}

/// Run the executor loop over a frame receiver until it closes.
pub fn run_executor<B: Backend>(
    exec: &mut BlockExecutor<B>,
    plan: &ServePlan,
    rx: Receiver<Frame>,
) -> Result<(Vec<FrameResult>, usize)> {
    let mut results = Vec::new();
    let mut skipped = 0usize;
    while let Ok(frame) = rx.recv() {
        let (result, sk) = process_frame(exec, plan, frame)?;
        results.push(result);
        skipped += sk;
    }
    Ok((results, skipped))
}

/// Source that feeds `frames` into a bounded queue, dropping on overflow.
/// Returns the number dropped.
pub fn feed_frames(
    tx: SyncSender<Frame>,
    mut frames: Vec<(u64, Tensor)>,
    pace: Option<std::time::Duration>,
) -> usize {
    let mut dropped = 0;
    // debug-build custody ledger: every offered frame must be counted
    // delivered or dropped, and `finish` cross-checks the return value —
    // the mid-feed-hangup remainder bug (PR 5) is the exact class this
    // catches (see `coordinator::audit`)
    let mut ledger = FeedLedger::new(frames.len());
    let mut it = frames.drain(..);
    while let Some((id, input)) = it.next() {
        match tx.try_send(Frame::new(id, input)) {
            Ok(()) => ledger.deliver(),
            Err(TrySendError::Full(_)) => {
                dropped += 1;
                ledger.drop_n(1);
            }
            Err(TrySendError::Disconnected(_)) => {
                // the receiver hung up mid-feed: the frame in hand AND the
                // whole undelivered remainder are dropped, not vanished —
                // `frames + dropped == total` must survive a hangup
                dropped += 1 + it.len();
                ledger.drop_n(1 + it.len());
                break;
            }
        }
        if let Some(p) = pace {
            thread::sleep(p);
        }
    }
    ledger.finish(dropped);
    dropped
}

/// End-to-end serve: spawn a producer thread over `frames`, run the
/// executor loop on this thread (it owns the backend), aggregate.
pub fn serve<B: Backend>(
    exec: &mut BlockExecutor<B>,
    plan: &ServePlan,
    frames: Vec<(u64, Tensor)>,
    queue_depth: usize,
    pace: Option<std::time::Duration>,
) -> Result<ServeReport> {
    let (tx, rx) = sync_channel::<Frame>(queue_depth.max(1));
    let producer = thread::spawn(move || feed_frames(tx, frames, pace));
    let t0 = Instant::now();
    let execs_before = exec.layer_execs;
    let skips_before = exec.layer_skips;
    let (results, skipped) = run_executor(exec, plan, rx)?;
    let wall = t0.elapsed().as_secs_f64();
    let dropped = producer
        .join()
        .map_err(|_| anyhow!("frame producer panicked mid-serve"))?;
    Ok(build_report(
        &results,
        dropped,
        wall,
        skipped,
        exec.layer_execs - execs_before,
        exec.layer_skips - skips_before,
    ))
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::device::Device;
    use crate::runtime::ReferenceBackend;
    use crate::taskgraph::{Partition, TaskGraph};
    use crate::trainer::GraphWeights;
    use crate::util::rng::Pcg32;

    fn executor<B: Backend>(backend: B) -> BlockExecutor<B> {
        let arch = backend.arch("cnn5").unwrap();
        let graph = TaskGraph::new(
            3,
            vec![1, 3, 4],
            vec![
                Partition(vec![0, 0, 0]),
                Partition(vec![0, 0, 0]),
                Partition(vec![0, 0, 1]),
                Partition::singletons(3),
            ],
        )
        .unwrap();
        let ncls = vec![2, 2, 2];
        let mut rng = Pcg32::seed(7);
        let store = GraphWeights::init(&graph, &arch, &ncls, &mut rng);
        BlockExecutor::new(backend, Device::msp430(), arch, graph, ncls, store)
    }

    fn frames(n: usize) -> Vec<(u64, Tensor)> {
        let mut rng = Pcg32::seed(9);
        (0..n as u64)
            .map(|i| {
                let data = (0..256).map(|_| rng.gauss()).collect();
                (i, Tensor::new(vec![1, 16, 16, 1], data))
            })
            .collect()
    }

    #[test]
    fn serve_processes_all_frames() {
        let mut ex = executor(ReferenceBackend::new());
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let report = serve(&mut ex, &plan, frames(12), 16, None).unwrap();
        assert_eq!(report.frames, 12);
        assert_eq!(report.dropped, 0);
        assert!(report.throughput_fps > 0.0);
        assert!(report.latency_p50_ms > 0.0);
        assert!(report.sim_time_per_frame_s > 0.0);
        // sharing must be visible: skips happened
        assert!(report.layer_skips > 0);
    }

    #[test]
    fn conditional_plan_skips_dependents() {
        let mut ex = executor(ReferenceBackend::new());
        // gate tasks 1,2 on task 0; with random weights task 0 will emit
        // class 0 for at least some frames
        let plan = ServePlan {
            order: vec![0, 1, 2],
            conditional: vec![(0, 1), (0, 2)],
        };
        let report = serve(&mut ex, &plan, frames(20), 32, None).unwrap();
        assert_eq!(report.frames, 20);
        // every frame ran task 0; dependents only when pred != 0
        assert!(report.tasks_skipped <= 40);
    }

    #[test]
    fn bounded_queue_drops_when_consumer_stalls() {
        // a live receiver that never drains: capacity-1 queue accepts the
        // first frame, every later try_send hits TrySendError::Full
        let (tx, rx) = sync_channel::<Frame>(1);
        let dropped = feed_frames(tx, frames(5), None);
        assert_eq!(dropped, 4);
        // the one accepted frame is still in the queue
        assert_eq!(rx.try_iter().count(), 1);
    }

    #[test]
    fn zero_frame_report_is_well_formed() {
        // the all-frames-dropped case: every metric must be a finite,
        // sensible zero — not a percentile over an empty sample
        let r = build_report(&[], 7, 0.25, 0, 0, 0);
        assert_eq!(r.frames, 0);
        assert_eq!(r.dropped, 7);
        for v in [
            r.throughput_fps,
            r.latency_p50_ms,
            r.latency_p95_ms,
            r.latency_p99_ms,
            r.sim_time_per_frame_s,
            r.sim_energy_per_frame_j,
        ] {
            assert!(v.is_finite(), "non-finite metric in zero-frame report");
            assert_eq!(v, 0.0);
        }
        // degenerate wall clock must not poison throughput either
        let r0 = build_report(&[], 0, 0.0, 0, 0, 0);
        assert!(r0.throughput_fps.is_finite());
    }

    #[test]
    fn feed_stops_on_disconnected_receiver() {
        // a hung-up consumer ends the feed, and every undelivered frame —
        // the one in hand plus the remainder — is counted as dropped so
        // conservation holds: 0 served + 5 dropped == 5 offered
        let (tx, rx) = sync_channel::<Frame>(1);
        drop(rx);
        let dropped = feed_frames(tx, frames(5), None);
        assert_eq!(dropped, 5);
    }

    #[test]
    fn feed_counts_remainder_dropped_on_midstream_hangup() {
        // the consumer takes up to two frames, then hangs up mid-feed. A
        // rendezvous channel (capacity 0) has no buffer a frame could be
        // stranded in, so conservation is exact and race-free: every
        // try_send either hands off to the parked consumer or is counted
        // dropped (Full before the hangup, Disconnected after).
        let (tx, rx) = sync_channel::<Frame>(0);
        let consumer = thread::spawn(move || {
            let a = rx.recv().is_ok() as usize;
            let b = rx.recv().is_ok() as usize;
            drop(rx);
            a + b
        });
        // pace the feed so the consumer has time to park in recv
        let dropped = feed_frames(
            tx,
            frames(8),
            Some(std::time::Duration::from_millis(2)),
        );
        let delivered = consumer.join().unwrap();
        assert!(delivered <= 2);
        assert_eq!(delivered + dropped, 8);
    }

    /// The two-tier weight memory (`memory::tier`) is a cost overlay on
    /// the single-executor loop too: at every capacity × prefetch
    /// setting the served predictions are frame-for-frame the flat
    /// executor's, only the load-stall/energy accounting moves.
    #[test]
    fn tiered_executor_serve_matches_flat_frame_for_frame() {
        use crate::memory::tier::TierConfig;

        let plan = ServePlan {
            order: vec![0, 1, 2],
            conditional: vec![(0, 2)],
        };
        let run = |tier: Option<TierConfig>| {
            let mut ex = executor(ReferenceBackend::new());
            if let Some(cfg) = tier {
                ex.enable_tier(cfg);
            }
            let (tx, rx) = sync_channel::<Frame>(16);
            for (id, x) in frames(10) {
                tx.send(Frame::new(id, x)).unwrap();
            }
            drop(tx);
            let (results, skipped) = run_executor(&mut ex, &plan, rx).unwrap();
            ex.tier_close(); // custody close-check (panics on imbalance)
            (results, skipped, ex.tier_counters())
        };
        let (base, base_sk, no_tier) = run(None);
        assert!(no_tier.is_none());
        for cap in [0usize, 2_000, usize::MAX] {
            for prefetch in [false, true] {
                let cfg =
                    TierConfig::for_device(&Device::msp430(), cap, prefetch);
                let (got, sk, counters) = run(Some(cfg));
                assert_eq!(sk, base_sk, "cap={cap} prefetch={prefetch}");
                assert_eq!(got.len(), base.len());
                for (g, w) in got.iter().zip(&base) {
                    assert_eq!(g.id, w.id);
                    assert_eq!(
                        g.predictions, w.predictions,
                        "frame {} diverged at cap={cap} prefetch={prefetch}",
                        g.id
                    );
                }
                let tc = counters.expect("tier enabled but no counters");
                assert!(tc.hits + tc.misses > 0);
            }
        }
    }

    #[test]
    fn serve_conserves_frames_under_pressure() {
        // a depth-1 queue against a compute-bound executor: whatever is
        // not served must have been counted as dropped
        let mut ex = executor(ReferenceBackend::new());
        let plan = ServePlan::unconditional(vec![0, 1, 2]);
        let total = 40;
        let report = serve(&mut ex, &plan, frames(total), 1, None).unwrap();
        assert_eq!(report.frames + report.dropped, total);
        assert!(report.frames > 0);
    }

    /// PJRT variants — kept behind artifact detection.
    #[cfg(feature = "pjrt")]
    mod pjrt {
        use super::*;
        use crate::runtime::pjrt_test_engine as engine;

        #[test]
        fn serve_processes_all_frames_pjrt() {
            let Some(eng) = engine() else { return };
            let mut ex = executor(&eng);
            let plan = ServePlan::unconditional(vec![0, 1, 2]);
            let report = serve(&mut ex, &plan, frames(12), 16, None).unwrap();
            assert_eq!(report.frames, 12);
            assert_eq!(report.dropped, 0);
            assert!(report.layer_skips > 0);
        }
    }
}
