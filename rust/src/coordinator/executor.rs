//! The live block executor: the same §2.3 state machine as
//! `memory::ExecSim`, but actually running layers on an execution
//! [`Backend`] (PJRT artifacts or the pure-Rust reference interpreter).
//! `ExecSim` plans each task's segment actions (cached / execute /
//! load+execute) and accounts simulated device time+energy; this executor
//! obeys the plan, reusing cached branch-point activations so shared
//! blocks genuinely execute once per sample — the runtime and the cost
//! model cannot drift apart.

use anyhow::{anyhow, Result};

use crate::device::{Cost, Device};
use crate::memory::{ExecSim, SegmentAction};
use crate::model::{ArchSpec, Tensor};
use crate::runtime::Backend;
use crate::taskgraph::TaskGraph;
use crate::trainer::GraphWeights;

pub struct BlockExecutor<B: Backend> {
    pub backend: B,
    pub arch: ArchSpec,
    pub graph: TaskGraph,
    pub ncls: Vec<usize>,
    pub store: GraphWeights,
    sim: OwnedSim,
    /// Cached output activation per segment: (sample, group, tensor).
    act: Vec<Option<(u64, usize, Tensor)>>,
    /// Backend layer executions actually performed (hot-path perf counter).
    pub layer_execs: u64,
    /// Layer executions skipped thanks to activation caching.
    pub layer_skips: u64,
}

/// ExecSim borrows device/arch/graph; to keep the executor self-contained
/// we own those and rebuild the sim with unsafe-free cloning instead.
struct OwnedSim {
    device: Device,
    resident: Vec<Option<usize>>,
    act_cache: Vec<Option<(u64, usize)>>,
}

impl<B: Backend> BlockExecutor<B> {
    pub fn new(
        backend: B,
        device: Device,
        arch: ArchSpec,
        graph: TaskGraph,
        ncls: Vec<usize>,
        store: GraphWeights,
    ) -> BlockExecutor<B> {
        let nseg = graph.n_segments();
        BlockExecutor {
            backend,
            arch,
            graph,
            ncls,
            store,
            sim: OwnedSim {
                device,
                resident: vec![None; nseg],
                act_cache: vec![None; nseg],
            },
            act: vec![None; nseg],
            layer_execs: 0,
            layer_skips: 0,
        }
    }

    pub fn reset(&mut self) {
        let nseg = self.graph.n_segments();
        self.sim.resident = vec![None; nseg];
        self.sim.act_cache = vec![None; nseg];
        self.act = vec![None; nseg];
    }

    /// Warm the backend's compilation caches for this graph (startup).
    /// A no-op (0) on backends that don't compile.
    pub fn warmup(&self) -> Result<usize> {
        self.backend.warmup(&self.arch, &self.ncls)
    }

    fn plan(&mut self, sample: u64, task: usize) -> (Vec<SegmentAction>, Cost) {
        let mut sim =
            ExecSim::new(&self.sim.device, &self.arch, &self.graph, &self.ncls);
        sim.restore(self.sim.resident.clone(), self.sim.act_cache.clone());
        let (plan, cost) = sim.plan_and_cost(sample, task);
        let (r, a) = sim.snapshot();
        self.sim.resident = r;
        self.sim.act_cache = a;
        (plan, cost)
    }

    /// Execute `task` on a batch-1 `input` sample. Returns (predicted
    /// class, simulated device cost).
    pub fn run_task(
        &mut self,
        sample: u64,
        task: usize,
        input: &Tensor,
    ) -> Result<(usize, Cost)> {
        assert_eq!(input.shape[0], 1, "serving path is batch-1");
        let (plan, cost) = self.plan(sample, task);
        let mut x: Option<Tensor> = None;
        for (s, action) in plan.iter().enumerate() {
            let group = self.graph.group_of(s, task);
            match action {
                SegmentAction::CachedActivation => {
                    let cached = self.act[s]
                        .as_ref()
                        .filter(|(sm, g, _)| *sm == sample && *g == group)
                        .ok_or_else(|| anyhow!("plan says cached but buffer empty"))?;
                    self.layer_skips +=
                        self.graph.segment_layers(&self.arch, s).len() as u64;
                    x = Some(cached.2.clone());
                }
                SegmentAction::Execute | SegmentAction::LoadAndExecute => {
                    let mut cur = match x {
                        Some(t) => t,
                        None => input.clone(),
                    };
                    let weights = &self.store.blocks[s][group];
                    let mut wi = 0;
                    for l in self.graph.segment_layers(&self.arch, s) {
                        let is_logits = self.arch.layers[l].is_logits();
                        let ncls = is_logits.then_some(self.ncls[task]);
                        cur = self.backend.run_layer(
                            &self.arch,
                            l,
                            ncls,
                            &cur,
                            &weights[wi],
                            &weights[wi + 1],
                        )?;
                        wi += 2;
                        self.layer_execs += 1;
                    }
                    self.act[s] = Some((sample, group, cur.clone()));
                    x = Some(cur);
                }
            }
        }
        let logits = x.ok_or_else(|| anyhow!("no segments executed"))?;
        let pred = logits
            .data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        Ok((pred, cost))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ReferenceBackend;
    use crate::taskgraph::Partition;
    use crate::util::rng::Pcg32;

    fn setup<B: Backend>(backend: B) -> BlockExecutor<B> {
        let arch = backend.arch("cnn5").unwrap();
        let graph = TaskGraph::new(
            3,
            vec![1, 3, 4],
            vec![
                Partition(vec![0, 0, 0]),
                Partition(vec![0, 0, 1]),
                Partition(vec![0, 1, 2]),
                Partition::singletons(3),
            ],
        )
        .unwrap();
        let ncls = vec![2, 2, 2];
        let mut rng = Pcg32::seed(11);
        let store = GraphWeights::init(&graph, &arch, &ncls, &mut rng);
        BlockExecutor::new(backend, Device::msp430(), arch, graph, ncls, store)
    }

    #[test]
    fn shared_prefix_executes_once_per_sample() {
        let mut ex = setup(ReferenceBackend::new());
        let x = Tensor::full(vec![1, 16, 16, 1], 0.3);
        let (_, c0) = ex.run_task(0, 0, &x).unwrap();
        let execs_after_first = ex.layer_execs;
        assert_eq!(execs_after_first, 5); // all five layers
        let (_, c1) = ex.run_task(0, 1, &x).unwrap();
        // task 1 shares segments 0,1 (layers 0,1,2) -> only 2 more layers
        assert_eq!(ex.layer_execs, execs_after_first + 2);
        assert_eq!(ex.layer_skips, 3);
        assert!(c1.time() < c0.time());
    }

    #[test]
    fn matches_whole_network_inference() {
        // blockwise execution must equal running the task's full param
        // list through the backend's whole-network eval
        let mut ex = setup(ReferenceBackend::new());
        let mut rng = Pcg32::seed(13);
        let data: Vec<f32> = (0..256).map(|_| rng.gauss()).collect();
        let x = Tensor::new(vec![1, 16, 16, 1], data);
        let (pred, _) = ex.run_task(0, 2, &x).unwrap();
        let params = ex.store.assemble(&ex.graph, &ex.arch, 2);
        let logits = ex
            .backend
            .eval_logits(&ex.arch, 2, &params, &x)
            .unwrap();
        let want = (logits.data[1] > logits.data[0]) as usize;
        assert_eq!(pred, want);
    }

    #[test]
    fn new_sample_recomputes() {
        let mut ex = setup(ReferenceBackend::new());
        let x = Tensor::full(vec![1, 16, 16, 1], 0.3);
        ex.run_task(0, 0, &x).unwrap();
        let execs = ex.layer_execs;
        ex.run_task(1, 0, &x).unwrap();
        assert_eq!(ex.layer_execs, execs + 5); // full path again
    }

    #[test]
    fn warmup_is_noop_on_reference_backend() {
        let ex = setup(ReferenceBackend::new());
        assert_eq!(ex.warmup().unwrap(), 0);
    }

    /// PJRT variants — kept behind artifact detection.
    #[cfg(feature = "pjrt")]
    mod pjrt {
        use super::*;
        use crate::runtime::pjrt_test_engine as engine;

        #[test]
        fn shared_prefix_executes_once_per_sample_pjrt() {
            let Some(eng) = engine() else { return };
            let mut ex = setup(&eng);
            ex.warmup().unwrap();
            let x = Tensor::full(vec![1, 16, 16, 1], 0.3);
            ex.run_task(0, 0, &x).unwrap();
            let execs_after_first = ex.layer_execs;
            assert_eq!(execs_after_first, 5);
            ex.run_task(0, 1, &x).unwrap();
            assert_eq!(ex.layer_execs, execs_after_first + 2);
            assert_eq!(ex.layer_skips, 3);
        }
    }
}
