//! The live block executor: the same §2.3 state machine as
//! `memory::ExecSim`, but actually running layers on an execution
//! [`Backend`] (PJRT artifacts or the pure-Rust reference interpreter).
//! `ExecSim` plans each task's segment actions (cached / execute /
//! load+execute) and accounts simulated device time+energy; this executor
//! obeys the plan, reusing cached branch-point activations so shared
//! blocks genuinely execute once per sample — the runtime and the cost
//! model cannot drift apart.

use anyhow::{anyhow, ensure, Result};

use crate::device::{Cost, Device};
use crate::memory::tier::{RoundStep, TierConfig, TierCounters, WeightTier};
use crate::memory::{ExecSim, SegmentAction};
use crate::model::{ArchSpec, Tensor};
use crate::runtime::Backend;
use crate::taskgraph::TaskGraph;
use crate::trainer::GraphWeights;

/// Output of [`BlockExecutor::run_round_batched`]: one full multitask
/// round over a micro-batch of frames.
#[derive(Debug, Clone)]
pub struct BatchRound {
    /// `predictions[i][t]`: predicted class of task `t` for frame `i`;
    /// `None` = skipped by a runtime conditional.
    pub predictions: Vec<Vec<Option<usize>>>,
    /// Per-frame simulated device cost. Weight-block loads happen once
    /// per batch and are amortized evenly over the frames that used the
    /// block — the batching win of the cost model.
    pub costs: Vec<Cost>,
    /// (frame, task) pairs skipped by conditionals.
    pub tasks_skipped: usize,
}

/// Batch-level activation cache entry: the output of one segment for the
/// batch rows named by `ids` (in row order), computed under `group`.
struct BatchAct {
    ids: Vec<u64>,
    group: usize,
    out: Tensor,
}

pub struct BlockExecutor<B: Backend> {
    pub backend: B,
    pub arch: ArchSpec,
    pub graph: TaskGraph,
    pub ncls: Vec<usize>,
    pub store: GraphWeights,
    sim: OwnedSim,
    /// Cached output activation per segment: (sample, group, tensor).
    act: Vec<Option<(u64, usize, Tensor)>>,
    /// Backend layer executions actually performed (hot-path perf counter).
    pub layer_execs: u64,
    /// Layer executions skipped thanks to activation caching.
    pub layer_skips: u64,
    /// Two-tier weight memory (None = flat residency, the baseline).
    /// Purely a cost/accounting model: weights always come from
    /// `store`, so predictions are identical either way.
    tier: Option<WeightTier>,
    /// Frames already visible behind the current round (injector
    /// backlog + dispatch prefetch hints) — feeds the tier's eviction
    /// stickiness.
    backlog_hint: usize,
}

/// ExecSim borrows device/arch/graph; to keep the executor self-contained
/// we own those and rebuild the sim with unsafe-free cloning instead.
struct OwnedSim {
    device: Device,
    resident: Vec<Option<usize>>,
    act_cache: Vec<Option<(u64, usize)>>,
}

impl<B: Backend> BlockExecutor<B> {
    pub fn new(
        backend: B,
        device: Device,
        arch: ArchSpec,
        graph: TaskGraph,
        ncls: Vec<usize>,
        store: GraphWeights,
    ) -> BlockExecutor<B> {
        let nseg = graph.n_segments();
        BlockExecutor {
            backend,
            arch,
            graph,
            ncls,
            store,
            sim: OwnedSim {
                device,
                resident: vec![None; nseg],
                act_cache: vec![None; nseg],
            },
            act: vec![None; nseg],
            layer_execs: 0,
            layer_skips: 0,
            tier: None,
            backlog_hint: 0,
        }
    }

    /// Enable the two-tier weight memory with the given configuration.
    /// Replaces any previous tier state (counters reset).
    pub fn enable_tier(&mut self, cfg: TierConfig) {
        self.tier = Some(WeightTier::new(cfg));
    }

    /// Tier statistics so far, `None` when running flat residency.
    pub fn tier_counters(&self) -> Option<TierCounters> {
        self.tier.as_ref().map(|t| t.counters)
    }

    /// Record how many frames are already visible behind the round the
    /// executor is about to run (injector backlog + prefetch-signal
    /// hints from dispatch). With visible backlog the tier treats the
    /// round's blocks as reused next round, making them sticky.
    pub fn note_backlog(&mut self, n: usize) {
        self.backlog_hint = n;
    }

    /// Residency view for the dispatch board: the tier's settled blocks
    /// when enabled, the flat residency slots otherwise — dispatch
    /// stickiness works unchanged over either.
    pub fn resident_snapshot(&self) -> Vec<Option<usize>> {
        match &self.tier {
            Some(t) => t.segment_view(self.graph.n_segments()),
            None => self.sim.resident.clone(),
        }
    }

    /// Drain-time custody check: every tier load issued was completed
    /// or cancelled. Panics in debug builds on violation; no-op flat.
    pub fn tier_close(&mut self) {
        if let Some(t) = self.tier.as_mut() {
            t.close_check();
        }
    }

    /// One step of a round's block sequence for (`segment`, `task`):
    /// block id, byte size, and the task-graph sharer count (the
    /// affinity signal the eviction scorer keeps sticky).
    fn round_step(&self, s: usize, task: usize) -> RoundStep {
        let group = self.graph.group_of(s, task);
        let sharers = (0..self.graph.n_tasks)
            .filter(|&t| self.graph.group_of(s, t) == group)
            .count();
        RoundStep {
            block: (s, group),
            bytes: self.graph.segment_bytes(&self.arch, s, task, &self.ncls),
            sharers,
        }
    }

    pub fn reset(&mut self) {
        let nseg = self.graph.n_segments();
        self.sim.resident = vec![None; nseg];
        self.sim.act_cache = vec![None; nseg];
        self.act = vec![None; nseg];
    }

    /// Warm the backend's compilation caches for this graph (startup).
    /// A no-op (0) on backends that don't compile.
    pub fn warmup(&self) -> Result<usize> {
        self.backend.warmup(&self.arch, &self.ncls)
    }

    /// Weight-block residency per segment slot: the group id whose block
    /// is currently loaded, or `None` while the slot is cold. The shard
    /// scheduler publishes this to route frames to already-warm shards.
    pub fn resident(&self) -> &[Option<usize>] {
        &self.sim.resident
    }

    fn plan(&mut self, sample: u64, task: usize) -> (Vec<SegmentAction>, Cost) {
        let mut sim =
            ExecSim::new(&self.sim.device, &self.arch, &self.graph, &self.ncls);
        sim.restore(self.sim.resident.clone(), self.sim.act_cache.clone());
        let (plan, cost) = sim.plan_and_cost(sample, task);
        let (r, a) = sim.snapshot();
        self.sim.resident = r;
        self.sim.act_cache = a;
        (plan, cost)
    }

    /// Execute `task` on a batch-1 `input` sample. Returns (predicted
    /// class, simulated device cost).
    pub fn run_task(
        &mut self,
        sample: u64,
        task: usize,
        input: &Tensor,
    ) -> Result<(usize, Cost)> {
        assert_eq!(input.shape.first(), Some(&1), "serving path is batch-1");
        let (plan, flat_cost) = self.plan(sample, task);
        // flat residency: the plan's cost is the answer. Tiered: the
        // plan still decides *what executes* (identical predictions),
        // but load time comes from the tier's stall model instead.
        let mut cost = flat_cost;
        if self.tier.is_some() {
            cost = Cost::default();
            let seq: Vec<RoundStep> = plan
                .iter()
                .enumerate()
                .filter(|(_, a)| !matches!(a, SegmentAction::CachedActivation))
                .map(|(s, _)| self.round_step(s, task))
                .collect();
            let hint = self.backlog_hint;
            if let Some(t) = self.tier.as_mut() {
                t.begin_round(seq, hint);
            }
        }
        let mut x: Option<Tensor> = None;
        for (s, action) in plan.iter().enumerate() {
            let group = self.graph.group_of(s, task);
            match action {
                SegmentAction::CachedActivation => {
                    let cached = self.act[s]
                        .as_ref()
                        .filter(|(sm, g, _)| *sm == sample && *g == group)
                        .ok_or_else(|| anyhow!("plan says cached but buffer empty"))?;
                    self.layer_skips +=
                        self.graph.segment_layers(&self.arch, s).len() as u64;
                    x = Some(cached.2.clone());
                }
                SegmentAction::Execute | SegmentAction::LoadAndExecute => {
                    if self.tier.is_some() {
                        let st = self.round_step(s, task);
                        let elems: u64 = self
                            .graph
                            .segment_layers(&self.arch, s)
                            .map(|l| self.arch.layers[l].out_elems() as u64)
                            .sum();
                        let ec = self.sim.device.exec_cost(
                            self.graph.segment_macs(&self.arch, s),
                            elems,
                        );
                        if let Some(tier) = self.tier.as_mut() {
                            let touch =
                                tier.touch(st.block, st.bytes, st.sharers);
                            cost.add(self.sim.device.load_cost_stalled(
                                touch.charge_bytes,
                                touch.stall_s,
                            ));
                            tier.advance_exec(ec.exec_s);
                        }
                        cost.add(ec);
                    }
                    let mut cur = match x {
                        Some(t) => t,
                        None => input.clone(),
                    };
                    let weights = &self.store.blocks[s][group];
                    let mut wi = 0;
                    for l in self.graph.segment_layers(&self.arch, s) {
                        let is_logits = self.arch.layers[l].is_logits();
                        let ncls = is_logits.then_some(self.ncls[task]);
                        cur = self.backend.run_layer(
                            &self.arch,
                            l,
                            ncls,
                            &cur,
                            &weights[wi],
                            &weights[wi + 1],
                        )?;
                        wi += 2;
                        self.layer_execs += 1;
                    }
                    self.act[s] = Some((sample, group, cur.clone()));
                    x = Some(cur);
                }
            }
        }
        let logits = x.ok_or_else(|| anyhow!("no segments executed"))?;
        let pred = logits
            .data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        Ok((pred, cost))
    }

    /// Execute one full multitask round (all of `order`, honouring the
    /// `conditional` (prereq, dependent) gates) over a micro-batch of
    /// batch-1 frames in one backend forward per segment.
    ///
    /// Semantics match running [`Self::run_task`] per frame per task:
    /// the reference backend's batched kernels are bitwise identical
    /// row-for-row, so `predictions` equals the single-frame loop's
    /// output frame-for-frame. Activation reuse across tasks happens at
    /// batch granularity (one cached tensor per segment for the whole
    /// batch); per-sample activation caches are invalidated around the
    /// call. Weight residency carries over in both directions, and each
    /// cold block is loaded once per batch with the simulated load cost
    /// split over the frames that used it.
    pub fn run_round_batched(
        &mut self,
        ids: &[u64],
        inputs: &[&Tensor],
        order: &[usize],
        conditional: &[(usize, usize)],
    ) -> Result<BatchRound> {
        let m = ids.len();
        ensure!(m > 0, "run_round_batched: empty batch");
        ensure!(
            inputs.len() == m,
            "run_round_batched: {m} ids vs {} inputs",
            inputs.len()
        );
        for t in inputs {
            ensure!(t.shape.first() == Some(&1), "each batched frame must be batch-1");
        }
        let xbatch = Tensor::concat_batch(inputs);
        let nseg = self.graph.n_segments();
        let n_tasks = self.graph.n_tasks;
        // the per-sample caches describe one sample at a time and cannot
        // represent a batch: invalidate around the batched round (weight
        // residency, which is sample-independent, persists)
        for s in 0..nseg {
            self.sim.act_cache[s] = None;
            self.act[s] = None;
        }
        let mut bact: Vec<Option<BatchAct>> = (0..nseg).map(|_| None).collect();
        let mut preds: Vec<Vec<Option<usize>>> = vec![vec![None; n_tasks]; m];
        let mut costs = vec![Cost::default(); m];
        let mut tasks_skipped = 0usize;
        if self.tier.is_some() {
            // the whole round's block sequence is known up front (gated
            // tasks are speculatively included: an unused prefetch is
            // settled and balanced by the custody ledger) — issue
            // pipelined fast-tier loads before the first forward
            let mut seq = Vec::with_capacity(order.len() * nseg);
            for &t in order {
                for s in 0..nseg {
                    seq.push(self.round_step(s, t));
                }
            }
            let hint = self.backlog_hint;
            if let Some(tier) = self.tier.as_mut() {
                tier.begin_round(seq, hint);
            }
        }
        for &t in order {
            let active: Vec<usize> = (0..m)
                .filter(|&i| {
                    !conditional
                        .iter()
                        .any(|&(pre, dep)| dep == t && preds[i][pre] == Some(0))
                })
                .collect();
            tasks_skipped += m - active.len();
            if active.is_empty() {
                continue;
            }
            let act_ids: Vec<u64> = active.iter().map(|&i| ids[i]).collect();
            let mut x: Option<Tensor> = None;
            for s in 0..nseg {
                let group = self.graph.group_of(s, t);
                let nlayers =
                    self.graph.segment_layers(&self.arch, s).len() as u64;
                let hit = bact[s].as_ref().filter(|c| {
                    c.group == group
                        && act_ids.iter().all(|id| c.ids.contains(id))
                });
                if let Some(c) = hit {
                    x = Some(gather_rows(&c.out, &c.ids, &act_ids));
                    self.layer_skips += nlayers * active.len() as u64;
                    continue;
                }
                let mut cur = match x.take() {
                    Some(tensor) => tensor,
                    None => gather_rows(&xbatch, ids, &act_ids),
                };
                if self.tier.is_some() {
                    // tier path: every executed segment touches its
                    // block; the tier decides hit / in-flight / demand
                    // stall and what load energy is still unattributed
                    let st = self.round_step(s, t);
                    if let Some(tier) = self.tier.as_mut() {
                        let touch = tier.touch(st.block, st.bytes, st.sharers);
                        if touch.stall_s > 0.0 || touch.charge_bytes > 0 {
                            let lc = self
                                .sim
                                .device
                                .load_cost_stalled(
                                    touch.charge_bytes,
                                    touch.stall_s,
                                )
                                .scaled(1.0 / active.len() as f64);
                            for &i in &active {
                                costs[i].add(lc);
                            }
                        }
                    }
                    self.sim.resident[s] = Some(group);
                } else if self.sim.resident[s] != Some(group) {
                    let bytes =
                        self.graph.segment_bytes(&self.arch, s, t, &self.ncls);
                    let lc = self
                        .sim
                        .device
                        .load_cost(bytes)
                        .scaled(1.0 / active.len() as f64);
                    for &i in &active {
                        costs[i].add(lc);
                    }
                    self.sim.resident[s] = Some(group);
                }
                let elems: u64 = self
                    .graph
                    .segment_layers(&self.arch, s)
                    .map(|l| self.arch.layers[l].out_elems() as u64)
                    .sum();
                let ec = self
                    .sim
                    .device
                    .exec_cost(self.graph.segment_macs(&self.arch, s), elems);
                for &i in &active {
                    costs[i].add(ec);
                }
                if let Some(tier) = self.tier.as_mut() {
                    // the device model executes the segment once per
                    // active frame serially: that much compute overlaps
                    // any in-flight prefetches
                    tier.advance_exec(ec.exec_s * active.len() as f64);
                }
                let weights = &self.store.blocks[s][group];
                let mut wi = 0;
                for l in self.graph.segment_layers(&self.arch, s) {
                    let is_logits = self.arch.layers[l].is_logits();
                    let ncls = is_logits.then_some(self.ncls[t]);
                    cur = self.backend.run_layer(
                        &self.arch,
                        l,
                        ncls,
                        &cur,
                        &weights[wi],
                        &weights[wi + 1],
                    )?;
                    wi += 2;
                    self.layer_execs += active.len() as u64;
                }
                bact[s] = Some(BatchAct {
                    ids: act_ids.clone(),
                    group,
                    out: cur.clone(),
                });
                x = Some(cur);
            }
            let logits = x.ok_or_else(|| anyhow!("no segments executed"))?;
            let width = self.ncls[t];
            for (row, &i) in active.iter().enumerate() {
                let rl = &logits.data[row * width..(row + 1) * width];
                let pred = rl
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(j, _)| j)
                    .unwrap_or(0);
                preds[i][t] = Some(pred);
            }
        }
        Ok(BatchRound { predictions: preds, costs, tasks_skipped })
    }
}

/// Rows of `src` correspond to `ids` in order; return the rows named by
/// `want` (every id in `want` must be present in `ids`), preserving the
/// order of `want`.
fn gather_rows(src: &Tensor, ids: &[u64], want: &[u64]) -> Tensor {
    if ids == want {
        return src.clone();
    }
    let per: usize = src.shape[1..].iter().product();
    let mut data = Vec::with_capacity(want.len() * per);
    for w in want {
        // lint:allow(panic) — caller invariant: `want` is assembled by
        // filtering `ids`, so every wanted id is present; absence is a
        // batching bug worth dying loudly for
        let row = ids
            .iter()
            .position(|id| id == w)
            .expect("batched activation row present");
        data.extend_from_slice(&src.data[row * per..(row + 1) * per]);
    }
    let mut shape = src.shape.clone();
    shape[0] = want.len(); // lint:allow(panic) — Tensor rank >= 1 by construction
    Tensor::new(shape, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ReferenceBackend;
    use crate::taskgraph::Partition;
    use crate::util::rng::Pcg32;

    fn setup<B: Backend>(backend: B) -> BlockExecutor<B> {
        let arch = backend.arch("cnn5").unwrap();
        let graph = TaskGraph::new(
            3,
            vec![1, 3, 4],
            vec![
                Partition(vec![0, 0, 0]),
                Partition(vec![0, 0, 1]),
                Partition(vec![0, 1, 2]),
                Partition::singletons(3),
            ],
        )
        .unwrap();
        let ncls = vec![2, 2, 2];
        let mut rng = Pcg32::seed(11);
        let store = GraphWeights::init(&graph, &arch, &ncls, &mut rng);
        BlockExecutor::new(backend, Device::msp430(), arch, graph, ncls, store)
    }

    #[test]
    fn shared_prefix_executes_once_per_sample() {
        let mut ex = setup(ReferenceBackend::new());
        let x = Tensor::full(vec![1, 16, 16, 1], 0.3);
        let (_, c0) = ex.run_task(0, 0, &x).unwrap();
        let execs_after_first = ex.layer_execs;
        assert_eq!(execs_after_first, 5); // all five layers
        let (_, c1) = ex.run_task(0, 1, &x).unwrap();
        // task 1 shares segments 0,1 (layers 0,1,2) -> only 2 more layers
        assert_eq!(ex.layer_execs, execs_after_first + 2);
        assert_eq!(ex.layer_skips, 3);
        assert!(c1.time() < c0.time());
    }

    #[test]
    fn matches_whole_network_inference() {
        // blockwise execution must equal running the task's full param
        // list through the backend's whole-network eval
        let mut ex = setup(ReferenceBackend::new());
        let mut rng = Pcg32::seed(13);
        let data: Vec<f32> = (0..256).map(|_| rng.gauss()).collect();
        let x = Tensor::new(vec![1, 16, 16, 1], data);
        let (pred, _) = ex.run_task(0, 2, &x).unwrap();
        let params = ex.store.assemble(&ex.graph, &ex.arch, 2);
        let logits = ex
            .backend
            .eval_logits(&ex.arch, 2, &params, &x)
            .unwrap();
        let want = (logits.data[1] > logits.data[0]) as usize;
        assert_eq!(pred, want);
    }

    #[test]
    fn new_sample_recomputes() {
        let mut ex = setup(ReferenceBackend::new());
        let x = Tensor::full(vec![1, 16, 16, 1], 0.3);
        ex.run_task(0, 0, &x).unwrap();
        let execs = ex.layer_execs;
        ex.run_task(1, 0, &x).unwrap();
        assert_eq!(ex.layer_execs, execs + 5); // full path again
    }

    #[test]
    fn warmup_is_noop_on_reference_backend() {
        let ex = setup(ReferenceBackend::new());
        assert_eq!(ex.warmup().unwrap(), 0);
    }

    fn gauss_frames(n: usize, seed: u64) -> Vec<(u64, Tensor)> {
        let mut rng = Pcg32::seed(seed);
        (0..n as u64)
            .map(|i| {
                let data = (0..256).map(|_| rng.gauss()).collect();
                (i, Tensor::new(vec![1, 16, 16, 1], data))
            })
            .collect()
    }

    #[test]
    fn batched_round_matches_per_frame_predictions() {
        // batch size 5 exercises the 4+1 kernel blocks; predictions must
        // be identical to running every frame through run_task alone
        let frames = gauss_frames(5, 0xF00D);
        let order = [0usize, 1, 2];

        let mut single = setup(ReferenceBackend::new());
        let mut want: Vec<Vec<Option<usize>>> = Vec::new();
        for (id, x) in &frames {
            let mut preds = vec![None; 3];
            for &t in &order {
                let (p, _) = single.run_task(*id, t, x).unwrap();
                preds[t] = Some(p);
            }
            want.push(preds);
        }

        let mut batched = setup(ReferenceBackend::new());
        let ids: Vec<u64> = frames.iter().map(|(id, _)| *id).collect();
        let inputs: Vec<&Tensor> = frames.iter().map(|(_, x)| x).collect();
        let out = batched.run_round_batched(&ids, &inputs, &order, &[]).unwrap();
        assert_eq!(out.predictions, want);
        assert_eq!(out.tasks_skipped, 0);
        // shared segments executed once per batch: skips were recorded
        assert!(batched.layer_skips > 0);
        assert!(out.costs.iter().all(|c| c.time() > 0.0));
    }

    #[test]
    fn batched_round_honours_conditionals_per_frame() {
        let frames = gauss_frames(6, 0xCAFE);
        let order = [0usize, 1, 2];
        let conditional = [(0usize, 1usize), (0usize, 2usize)];

        let mut single = setup(ReferenceBackend::new());
        let mut want: Vec<Vec<Option<usize>>> = Vec::new();
        let mut want_skipped = 0usize;
        for (id, x) in &frames {
            let mut preds: Vec<Option<usize>> = vec![None; 3];
            for &t in &order {
                let gated = conditional
                    .iter()
                    .any(|&(pre, dep)| dep == t && preds[pre] == Some(0));
                if gated {
                    want_skipped += 1;
                    continue;
                }
                let (p, _) = single.run_task(*id, t, x).unwrap();
                preds[t] = Some(p);
            }
            want.push(preds);
        }

        let mut batched = setup(ReferenceBackend::new());
        let ids: Vec<u64> = frames.iter().map(|(id, _)| *id).collect();
        let inputs: Vec<&Tensor> = frames.iter().map(|(_, x)| x).collect();
        let out = batched
            .run_round_batched(&ids, &inputs, &order, &conditional)
            .unwrap();
        assert_eq!(out.predictions, want);
        assert_eq!(out.tasks_skipped, want_skipped);
    }

    #[test]
    fn batched_round_amortizes_loads_across_frames() {
        // the per-frame simulated load share of a batch of 4 must be a
        // quarter of a lone frame's (same cold start, same round)
        let frames = gauss_frames(4, 0xBEEF);
        let order = [0usize, 1, 2];
        let ids: Vec<u64> = frames.iter().map(|(id, _)| *id).collect();
        let inputs: Vec<&Tensor> = frames.iter().map(|(_, x)| x).collect();

        let mut lone = setup(ReferenceBackend::new());
        let lone_out = lone
            .run_round_batched(&ids[..1], &inputs[..1], &order, &[])
            .unwrap();
        let mut batched = setup(ReferenceBackend::new());
        let out = batched.run_round_batched(&ids, &inputs, &order, &[]).unwrap();
        for c in &out.costs {
            assert!(
                (c.load_s - lone_out.costs[0].load_s / 4.0).abs() < 1e-12,
                "load share {} vs lone {}",
                c.load_s,
                lone_out.costs[0].load_s
            );
        }
        // residency persisted: an immediate second batch never loads
        let out2 = batched.run_round_batched(&ids, &inputs, &order, &[]).unwrap();
        assert!(out2.costs.iter().all(|c| c.load_s == 0.0));
    }

    #[test]
    fn tiered_batched_predictions_match_flat_at_every_capacity() {
        let frames = gauss_frames(5, 0xA11CE);
        let order = [0usize, 1, 2];
        let conditional = [(0usize, 2usize)];
        let ids: Vec<u64> = frames.iter().map(|(id, _)| *id).collect();
        let inputs: Vec<&Tensor> = frames.iter().map(|(_, x)| x).collect();
        let mut flat = setup(ReferenceBackend::new());
        let want = flat
            .run_round_batched(&ids, &inputs, &order, &conditional)
            .unwrap();
        for cap in [0usize, 3_000, usize::MAX] {
            for prefetch in [false, true] {
                let mut ex = setup(ReferenceBackend::new());
                ex.enable_tier(crate::memory::tier::TierConfig::for_device(
                    &Device::msp430(),
                    cap,
                    prefetch,
                ));
                let got = ex
                    .run_round_batched(&ids, &inputs, &order, &conditional)
                    .unwrap();
                assert_eq!(
                    got.predictions, want.predictions,
                    "cap {cap} prefetch {prefetch} changed predictions"
                );
                assert_eq!(got.tasks_skipped, want.tasks_skipped);
                ex.tier_close(); // custody must balance at drain
            }
        }
    }

    #[test]
    fn tiered_run_task_matches_flat_and_balances() {
        let frames = gauss_frames(4, 0xD00F);
        let order = [0usize, 1, 2];
        let mut flat = setup(ReferenceBackend::new());
        let mut want = Vec::new();
        for (id, x) in &frames {
            for &t in &order {
                want.push(flat.run_task(*id, t, x).unwrap().0);
            }
        }
        for cap in [0usize, 2_000, usize::MAX] {
            let mut ex = setup(ReferenceBackend::new());
            ex.enable_tier(crate::memory::tier::TierConfig::for_device(
                &Device::msp430(),
                cap,
                true,
            ));
            let mut got = Vec::new();
            for (id, x) in &frames {
                for &t in &order {
                    got.push(ex.run_task(*id, t, x).unwrap().0);
                }
            }
            assert_eq!(got, want, "cap {cap} changed batch-1 predictions");
            ex.tier_close();
        }
    }

    #[test]
    fn prefetch_overlap_beats_demand_stall_in_batched_round() {
        let frames = gauss_frames(4, 0x5EED);
        let order = [0usize, 1, 2];
        let ids: Vec<u64> = frames.iter().map(|(id, _)| *id).collect();
        let inputs: Vec<&Tensor> = frames.iter().map(|(_, x)| x).collect();
        let run = |prefetch: bool| {
            let mut ex = setup(ReferenceBackend::new());
            ex.enable_tier(crate::memory::tier::TierConfig::for_device(
                &Device::msp430(),
                usize::MAX,
                prefetch,
            ));
            let out =
                ex.run_round_batched(&ids, &inputs, &order, &[]).unwrap();
            let stall: f64 = out.costs.iter().map(|c| c.load_s).sum();
            let counters = ex.tier_counters().unwrap();
            ex.tier_close();
            (stall, counters)
        };
        let (stall_off, off) = run(false);
        let (stall_on, on) = run(true);
        assert!(
            stall_on < stall_off,
            "prefetch stall {stall_on} !< demand stall {stall_off}"
        );
        assert!(on.prefetch_hits > 0);
        assert_eq!(on.misses, 0, "everything fits: prefetch covers all");
        assert_eq!(off.prefetch_hits, 0);
        // energy (bytes moved) identical: overlap hides time, not work
        assert_eq!(on.bytes_loaded, off.bytes_loaded);
    }

    /// PJRT variants — kept behind artifact detection.
    #[cfg(feature = "pjrt")]
    mod pjrt {
        use super::*;
        use crate::runtime::pjrt_test_engine as engine;

        #[test]
        fn shared_prefix_executes_once_per_sample_pjrt() {
            let Some(eng) = engine() else { return };
            let mut ex = setup(&eng);
            ex.warmup().unwrap();
            let x = Tensor::full(vec![1, 16, 16, 1], 0.3);
            ex.run_task(0, 0, &x).unwrap();
            let execs_after_first = ex.layer_execs;
            assert_eq!(execs_after_first, 5);
            ex.run_task(0, 1, &x).unwrap();
            assert_eq!(ex.layer_execs, execs_after_first + 2);
            assert_eq!(ex.layer_skips, 3);
        }
    }
}
