//! L3 coordinator: the live Antler system. `executor` runs task graphs
//! block-by-block on an execution backend with the §2.3 caching
//! semantics; `server` is the serving loop (sources → bounded queue →
//! ordered multitask execution with conditional skipping → metrics);
//! `shard` round-robins frames across a pool of `Send` executors;
//! `pipeline` wires offline preparation (affinity → graph → order →
//! trained weights) into a ready-to-serve executor.

pub mod executor;
pub mod pipeline;
pub mod server;
pub mod shard;

pub use executor::BlockExecutor;
pub use pipeline::{prepare, Prepared, PrepareConfig};
pub use server::{serve, Frame, FrameResult, ServePlan, ServeReport};
pub use shard::{serve_sharded, ShardReport};
