//! L3 coordinator: the live Antler system. `executor` runs task graphs
//! block-by-block on an execution backend with the §2.3 caching
//! semantics (single frames or cross-frame micro-batches); `server` is
//! the serving loop (sources → bounded queue → ordered multitask
//! execution with conditional skipping → metrics); `ingest` is the
//! multi-producer front-end (K producer threads pacing/admitting
//! independent frame sources with exact per-source drop accounting);
//! `shard` schedules frames across a pool of `Send` executors — a
//! shared-injector work-stealing scheduler with residency-aware
//! dispatch and adaptive cross-frame batching, plus the round-robin
//! baseline; `pipeline` wires offline preparation (affinity → graph →
//! order → trained weights) into a ready-to-serve executor; `registry`
//! is the versioned multi-tenant plan store with epoch-based hot-swap
//! (in-flight frames finish on the plan version they were admitted
//! under); `replan` is the background cost-drift replanner that
//! publishes new epochs when observed costs drift off the `Device`
//! model; `audit` is the debug-build frame-custody auditor backing the
//! conservation invariant `delivered + dropped == offered` at every
//! transfer point (CONCURRENCY.md).

pub mod audit;
pub mod executor;
pub mod ingest;
pub mod net;
pub mod pipeline;
pub mod registry;
pub mod replan;
pub mod server;
pub mod shard;
pub mod wire;

pub use executor::{BatchRound, BlockExecutor};
pub use ingest::{run_ingest, IngestReport, Source, SourceReport};
pub use net::{
    serve_net, serve_net_registry, ConnReport, NetOpts, NetReport,
};
pub use pipeline::{compile_tenant_plans, prepare, Prepared, PrepareConfig};
pub use registry::{EpochOutcome, EpochRow, PlanRegistry, PlanVersion};
pub use replan::{
    spawn_replanner, CostObs, DriftConfig, DriftModel, ReplanEvent,
    TenantSpec,
};
pub use server::{
    process_frame, process_frame_observed, run_executor, serve, Frame,
    FrameResult, ServePlan, ServeReport,
};
pub use shard::{
    serve_sharded, serve_sharded_opts, serve_sharded_registry,
    serve_sharded_registry_feed, serve_sharded_sources,
    serve_sharded_sources_registry, BatchPolicy, ShardOpts, ShardReport,
    WsDispatch,
};
pub use wire::QosClass;
