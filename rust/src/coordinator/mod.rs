//! L3 coordinator: the live Antler system. `executor` runs task graphs
//! block-by-block on an execution backend with the §2.3 caching
//! semantics (single frames or cross-frame micro-batches); `server` is
//! the serving loop (sources → bounded queue → ordered multitask
//! execution with conditional skipping → metrics); `ingest` is the
//! multi-producer front-end (K producer threads pacing/admitting
//! independent frame sources with exact per-source drop accounting);
//! `shard` schedules frames across a pool of `Send` executors — a
//! shared-injector work-stealing scheduler with residency-aware
//! dispatch and adaptive cross-frame batching, plus the round-robin
//! baseline; `pipeline` wires offline preparation (affinity → graph →
//! order → trained weights) into a ready-to-serve executor; `audit` is
//! the debug-build frame-custody auditor backing the conservation
//! invariant `delivered + dropped == offered` at every transfer point
//! (CONCURRENCY.md).

pub mod audit;
pub mod executor;
pub mod ingest;
pub mod net;
pub mod pipeline;
pub mod server;
pub mod shard;
pub mod wire;

pub use executor::{BatchRound, BlockExecutor};
pub use ingest::{run_ingest, IngestReport, Source, SourceReport};
pub use net::{serve_net, ConnReport, NetOpts, NetReport};
pub use pipeline::{prepare, Prepared, PrepareConfig};
pub use server::{
    process_frame, run_executor, serve, Frame, FrameResult, ServePlan,
    ServeReport,
};
pub use shard::{
    serve_sharded, serve_sharded_opts, serve_sharded_sources, BatchPolicy,
    ShardOpts, ShardReport,
};
pub use wire::QosClass;
