//! Versioned multi-tenant plan registry with epoch-based hot-swap.
//!
//! Each tenant owns a slot holding the *current* [`PlanVersion`] — an
//! immutable `(tenant, epoch, ServePlan)` triple behind an `Arc` — plus
//! the history of every version ever published. Admission pins a frame
//! to the version that was current at offer time by cloning the `Arc`
//! into the frame itself; a [`publish`](PlanRegistry::publish) swaps
//! the slot's current pointer under a short-lived mutex and bumps the
//! epoch. That is the whole hot-swap protocol: in-flight frames keep
//! executing the plan their pinned `Arc` points at, new frames pick up
//! the new epoch at the next `current()` read, and the old version is
//! freed when its last in-flight frame drops the pin — no drain, no
//! pause, no reader lock on the per-frame path beyond one mutex-guarded
//! pointer clone (RCU by refcount).
//!
//! Conservation across a swap is the load-bearing claim: a swap must
//! neither drop nor double-serve a frame. Every admission books
//! `note_admitted` on the pinned version *inside the steal queue's
//! accept path* (before the frame becomes poppable — so a fast worker
//! cannot retire a frame whose admission is unbooked), and every
//! admitted frame is retired on that same version as exactly one
//! [`EpochOutcome`]: completed, failed (its shard died mid-frame), or
//! drained (still queued at shutdown). `close_check` then requires
//! `admitted == completed + failed + drained` per version, summed over
//! live epochs — re-derived transition-by-transition in debug builds by
//! the [`PlanEpochLedger`](super::audit::PlanEpochLedger) auditor, and
//! model-checked under loom (`loom_epoch_swap_pins_and_balances`, the
//! 9th model — CONCURRENCY.md §Plan hot-swap).

use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::{lock_unpoisoned, Arc, Mutex};

#[cfg(debug_assertions)]
use super::audit::PlanEpochLedger;
use super::server::ServePlan;

/// How an admitted frame left its plan version. Every admission must
/// retire as exactly one of these — the epoch twin of the steal queue's
/// served/failed/drained custody split. A new retirement class must
/// break the build at every accounting site (analyzer rule A5), not be
/// silently absorbed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EpochOutcome {
    /// The frame's full multitask round finished on this plan.
    Completed,
    /// The frame's shard died mid-round; the frame is reported as a
    /// shard error, never as a result.
    Failed,
    /// The frame was still queued when serving shut down and was
    /// cleared by `drain_remaining` (counted as dropped upstream).
    Drained,
}

/// One immutable published plan: the unit frames pin at admission.
///
/// Counters are `Relaxed` on both sides: each is an independent monotone
/// tally (atomic RMWs never lose increments at any ordering), and every
/// cross-thread *read* happens after the serving scope's joins — the
/// synchronization barrier — so no counter carries a happens-before
/// edge for frame data (frames travel through the mutex-guarded steal
/// queue). Same contract as `ResidencyBoard` / `PrefetchSignal`.
pub struct PlanVersion {
    pub tenant: u32,
    /// Monotone per-tenant version number, starting at 0.
    pub epoch: u64,
    pub plan: ServePlan,
    admitted: AtomicUsize,
    completed: AtomicUsize,
    failed: AtomicUsize,
    drained: AtomicUsize,
    /// Debug-build custody ledger (`coordinator::audit`): re-derives
    /// the counter arithmetic transition-by-transition and panics on
    /// the first retirement no conserving execution could produce.
    /// Compiled out in release (the loom lane runs `--release`, so the
    /// model checks the protocol, not the auditor).
    #[cfg(debug_assertions)]
    audit: Mutex<PlanEpochLedger>,
}

impl std::fmt::Debug for PlanVersion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (a, c, fl, d) = self.counts();
        f.debug_struct("PlanVersion")
            .field("tenant", &self.tenant)
            .field("epoch", &self.epoch)
            .field("admitted", &a)
            .field("completed", &c)
            .field("failed", &fl)
            .field("drained", &d)
            .finish()
    }
}

impl PlanVersion {
    fn new(tenant: u32, epoch: u64, plan: ServePlan) -> PlanVersion {
        PlanVersion {
            tenant,
            epoch,
            plan,
            admitted: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            failed: AtomicUsize::new(0),
            drained: AtomicUsize::new(0),
            #[cfg(debug_assertions)]
            audit: Mutex::new(PlanEpochLedger::new()),
        }
    }

    /// Book one admission against this version. Called from inside the
    /// steal queue's accept path, under its lock, *before* the frame
    /// becomes poppable — so no worker can retire a frame whose
    /// admission is unbooked. Lock order is queue → ledger and nothing
    /// ever takes them in reverse, so no cycle.
    pub fn note_admitted(&self) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
        #[cfg(debug_assertions)]
        lock_unpoisoned(&self.audit).admit();
    }

    /// Retire one admitted frame. Exhaustive over [`EpochOutcome`]: a
    /// new retirement class must be accounted here (analyzer rule A5).
    pub fn note_outcome(&self, outcome: EpochOutcome) {
        match outcome {
            EpochOutcome::Completed => {
                self.completed.fetch_add(1, Ordering::Relaxed);
                #[cfg(debug_assertions)]
                lock_unpoisoned(&self.audit).complete();
            }
            EpochOutcome::Failed => {
                self.failed.fetch_add(1, Ordering::Relaxed);
                #[cfg(debug_assertions)]
                lock_unpoisoned(&self.audit).fail();
            }
            EpochOutcome::Drained => {
                self.drained.fetch_add(1, Ordering::Relaxed);
                #[cfg(debug_assertions)]
                lock_unpoisoned(&self.audit).drain();
            }
        }
    }

    /// `(admitted, completed, failed, drained)`.
    pub fn counts(&self) -> (usize, usize, usize, usize) {
        (
            self.admitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.drained.load(Ordering::Relaxed),
        )
    }

    /// Has every admitted frame been retired?
    pub fn balanced(&self) -> bool {
        let (a, c, f, d) = self.counts();
        a == c + f + d
    }

    /// Assert full retirement: `admitted == completed + failed +
    /// drained`. Runs in release builds too — a swap that leaks a frame
    /// must fail loudly, not ship; the check is O(1) and runs after the
    /// serving scope's joins, never per frame.
    pub fn close_check(&self) {
        let (a, c, f, d) = self.counts();
        assert_eq!(
            a,
            c + f + d,
            "plan version t{}e{} leaks frames: {a} admitted vs {c} completed \
             + {f} failed + {d} drained",
            self.tenant,
            self.epoch,
        );
        #[cfg(debug_assertions)]
        lock_unpoisoned(&self.audit).close_check(a, c, f, d);
    }
}

/// One tenant's slot: current version + full publish history.
struct TenantSlot {
    /// `history.last()` is always the current version. Guarded by one
    /// short-lived mutex: `current()` clones an `Arc` under it,
    /// `publish()` pushes under it — no guard ever crosses a blocking
    /// call (analyzer rule A4).
    history: Mutex<Vec<Arc<PlanVersion>>>,
}

/// The versioned multi-tenant plan registry.
///
/// Routing: tenant `t` maps to slot `t % n_tenants`, so an unknown
/// tenant id degrades to a deterministic slot instead of a panic — on
/// the single-tenant path every frame (tenant 0 or otherwise) lands on
/// the one plan, which is exactly the pre-registry behavior.
pub struct PlanRegistry {
    slots: Vec<TenantSlot>,
}

/// One row of the per-epoch accounting table (`ShardReport::epochs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EpochRow {
    pub tenant: u32,
    pub epoch: u64,
    pub admitted: usize,
    pub completed: usize,
    pub failed: usize,
    pub drained: usize,
    /// Is this the tenant's current (latest-published) version?
    pub live: bool,
}

impl PlanRegistry {
    /// A registry over `plans[i]` as tenant `i`'s epoch-0 plan.
    /// `plans` must be non-empty; an empty fleet has nothing to route.
    pub fn new(plans: Vec<ServePlan>) -> PlanRegistry {
        assert!(!plans.is_empty(), "registry needs at least one tenant plan");
        PlanRegistry {
            slots: plans
                .into_iter()
                .enumerate()
                .map(|(t, p)| TenantSlot {
                    history: Mutex::new(vec![Arc::new(PlanVersion::new(
                        t as u32, 0, p,
                    ))]),
                })
                .collect(),
        }
    }

    /// The single-tenant registry the legacy entry points wrap their
    /// one static plan in: every tenant id routes to it.
    pub fn single(plan: ServePlan) -> PlanRegistry {
        PlanRegistry::new(vec![plan])
    }

    pub fn n_tenants(&self) -> usize {
        self.slots.len()
    }

    fn slot(&self, tenant: u32) -> &TenantSlot {
        // non-empty by construction (`new` asserts), so the modulo is
        // always in range
        &self.slots[tenant as usize % self.slots.len()]
    }

    /// The tenant's current version — the one a frame offered *now*
    /// pins. One mutex-guarded `Arc` clone.
    pub fn current(&self, tenant: u32) -> Arc<PlanVersion> {
        let h = lock_unpoisoned(&self.slot(tenant).history);
        // the slot is created with its epoch-0 version and publish only
        // appends, so last() always exists; if that invariant ever
        // broke, dying here beats serving frames with no plan
        // lint:allow(panic)
        Arc::clone(h.last().expect("tenant slot lost its plan history"))
    }

    /// Publish `plan` as the tenant's next epoch and return that epoch.
    /// In-flight frames keep their pinned version; only frames offered
    /// after this call observe the new one.
    pub fn publish(&self, tenant: u32, plan: ServePlan) -> u64 {
        let slot = self.slot(tenant);
        let mut h = lock_unpoisoned(&slot.history);
        let epoch = h.last().map_or(0, |v| v.epoch + 1);
        let t = h.last().map_or(tenant, |v| v.tenant);
        h.push(Arc::new(PlanVersion::new(t, epoch, plan)));
        epoch
    }

    /// Every version ever published, all tenants, publish order within
    /// each tenant.
    pub fn versions(&self) -> Vec<Arc<PlanVersion>> {
        self.slots
            .iter()
            .flat_map(|s| lock_unpoisoned(&s.history).iter().cloned().collect::<Vec<_>>())
            .collect()
    }

    /// Per-epoch accounting rows for `ShardReport`.
    pub fn epoch_report(&self) -> Vec<EpochRow> {
        let mut rows = Vec::new();
        for s in &self.slots {
            let h = lock_unpoisoned(&s.history);
            let last = h.len().saturating_sub(1);
            for (i, v) in h.iter().enumerate() {
                let (admitted, completed, failed, drained) = v.counts();
                rows.push(EpochRow {
                    tenant: v.tenant,
                    epoch: v.epoch,
                    admitted,
                    completed,
                    failed,
                    drained,
                    live: i == last,
                });
            }
        }
        rows
    }

    /// Assert every version (live and retired) fully retired its
    /// admissions. Called after the serving scope's joins.
    pub fn close_check(&self) {
        for v in self.versions() {
            v.close_check();
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    fn plan(order: Vec<usize>) -> ServePlan {
        ServePlan::unconditional(order)
    }

    #[test]
    fn current_pins_the_version_at_read_time() {
        let reg = PlanRegistry::new(vec![plan(vec![0, 1]), plan(vec![1, 0])]);
        let v0 = reg.current(0);
        assert_eq!((v0.tenant, v0.epoch), (0, 0));
        assert_eq!(v0.plan.order, vec![0, 1]);
        let e = reg.publish(0, plan(vec![1, 0]));
        assert_eq!(e, 1);
        // the pinned Arc still reads the old plan; a fresh read sees the new
        assert_eq!(v0.plan.order, vec![0, 1]);
        let v1 = reg.current(0);
        assert_eq!(v1.epoch, 1);
        assert_eq!(v1.plan.order, vec![1, 0]);
        // tenant 1 is untouched by tenant 0's publish
        assert_eq!(reg.current(1).epoch, 0);
    }

    #[test]
    fn unknown_tenants_route_modulo_the_fleet() {
        let reg = PlanRegistry::new(vec![plan(vec![0]), plan(vec![1])]);
        assert_eq!(reg.current(2).tenant, 0);
        assert_eq!(reg.current(7).tenant, 1);
        let single = PlanRegistry::single(plan(vec![0, 1, 2]));
        for t in [0u32, 1, 99] {
            assert_eq!(single.current(t).plan.order, vec![0, 1, 2]);
        }
    }

    #[test]
    fn outcomes_retire_on_the_pinned_version_across_a_swap() {
        let reg = PlanRegistry::new(vec![plan(vec![0])]);
        let old = reg.current(0);
        old.note_admitted();
        old.note_admitted();
        reg.publish(0, plan(vec![0]));
        let new = reg.current(0);
        new.note_admitted();
        // in-flight frames finish on the version they were admitted under
        old.note_outcome(EpochOutcome::Completed);
        old.note_outcome(EpochOutcome::Drained);
        new.note_outcome(EpochOutcome::Completed);
        assert_eq!(old.counts(), (2, 1, 0, 1));
        assert_eq!(new.counts(), (1, 1, 0, 0));
        reg.close_check();
        let rows = reg.epoch_report();
        assert_eq!(rows.len(), 2);
        assert!(!rows[0].live && rows[1].live);
        assert_eq!(rows[0].admitted, 2);
        assert_eq!(rows[1].epoch, 1);
    }

    #[test]
    fn failed_outcome_is_its_own_bucket() {
        let reg = PlanRegistry::single(plan(vec![0]));
        let v = reg.current(0);
        v.note_admitted();
        v.note_outcome(EpochOutcome::Failed);
        assert_eq!(v.counts(), (1, 0, 1, 0));
        assert!(v.balanced());
        reg.close_check();
    }

    #[test]
    #[should_panic(expected = "leaks frames")]
    fn close_check_panics_on_unretired_admission() {
        let reg = PlanRegistry::single(plan(vec![0]));
        reg.current(0).note_admitted();
        reg.close_check();
    }
}

/// Exhaustive model check of the epoch-swap protocol (`./ci.sh --loom`,
/// 9th model): an admitter pinning + retiring frames races a publisher
/// swapping the tenant's plan. In every interleaving each frame retires
/// on the exact version that admitted it, every version balances, and
/// the epoch advances — a swap can neither drop nor double-serve.
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;
    use crate::sync::thread;

    fn model() -> loom::model::Builder {
        let mut b = loom::model::Builder::new();
        b.preemption_bound = Some(3);
        b
    }

    #[test]
    fn loom_epoch_swap_pins_and_balances() {
        model().check(|| {
            let reg = Arc::new(PlanRegistry::new(vec![
                ServePlan::unconditional(vec![0]),
            ]));
            let r_a = Arc::clone(&reg);
            let admitter = thread::spawn(move || {
                for _ in 0..2 {
                    // pin, admit, retire — the worker's life of a frame
                    let v = r_a.current(0);
                    v.note_admitted();
                    v.note_outcome(EpochOutcome::Completed);
                }
            });
            let r_p = Arc::clone(&reg);
            let publisher = thread::spawn(move || {
                r_p.publish(0, ServePlan::unconditional(vec![0]));
            });
            admitter.join().unwrap();
            publisher.join().unwrap();
            let versions = reg.versions();
            assert_eq!(versions.len(), 2, "publish must add a version");
            let total: usize = versions.iter().map(|v| v.counts().0).sum();
            assert_eq!(total, 2, "both frames admitted exactly once");
            for v in &versions {
                assert!(v.balanced(), "version t{}e{} unbalanced", v.tenant, v.epoch);
            }
            reg.close_check();
            assert_eq!(reg.current(0).epoch, 1, "swap must advance the epoch");
        });
    }
}
