//! The offline preparation pipeline (§5.3's "application development
//! tool", in rust): individually train task networks → profile affinity
//! at the branch points → enumerate + select the task graph → multitask
//! retrain the graph → solve the execution order → hand back a
//! ready-to-serve executor state. Generic over the execution
//! [`Backend`], so it runs end-to-end with or without PJRT artifacts.

use anyhow::Result;

use crate::affinity::{affinity_from_profiles, representation_profile, AffinityTensor};
use crate::device::Device;
use crate::memory::cost_matrix;
use crate::model::{ArchSpec, Tensor};
use crate::ordering::{solve_held_karp, solve_subset, OrderingProblem};
use crate::runtime::Backend;
use crate::taskgraph::select::{score_graph, select_tradeoff, GraphScore};
use crate::taskgraph::{enumerate, tenant_task_split, TaskGraph};
use crate::trainer::{self, GraphWeights};
use crate::util::rng::Pcg32;

use super::server::ServePlan;

/// Anything that can feed the pipeline: the dataset analogs (binary
/// one-vs-rest tasks) or the §7 deployment streams (multi-class tasks).
pub trait TaskSource {
    fn n_tasks(&self) -> usize;
    fn ncls(&self, task: usize) -> usize;
    /// A training batch of TRAIN_BATCH samples for `task`.
    fn train_batch(&self, task: usize, rng: &mut Pcg32) -> (Tensor, Vec<i32>);
    /// The full test set for `task`.
    fn test_set(&self, task: usize) -> (Tensor, Vec<i32>);
    /// `k` unlabeled samples for affinity profiling.
    fn profile_samples(&self, k: usize) -> Tensor;
}

impl TaskSource for crate::data::Dataset {
    fn n_tasks(&self) -> usize {
        self.spec.n_classes
    }
    fn ncls(&self, _task: usize) -> usize {
        2
    }
    fn train_batch(&self, task: usize, rng: &mut Pcg32) -> (Tensor, Vec<i32>) {
        let (train, _) = self.split();
        self.balanced_batch(task, &train, trainer::TRAIN_BATCH, rng)
    }
    fn test_set(&self, task: usize) -> (Tensor, Vec<i32>) {
        let (_, test) = self.split();
        self.gather(&test, task)
    }
    fn profile_samples(&self, k: usize) -> Tensor {
        self.x.slice_batch(0, k.min(self.len()))
    }
}

impl TaskSource for crate::data::deployment::DeploymentData {
    fn n_tasks(&self) -> usize {
        self.spec.n_tasks()
    }
    fn ncls(&self, task: usize) -> usize {
        self.spec.tasks[task].ncls
    }
    fn train_batch(&self, task: usize, rng: &mut Pcg32) -> (Tensor, Vec<i32>) {
        let (train, _) = self.split();
        self.batch(task, &train, trainer::TRAIN_BATCH, rng)
    }
    fn test_set(&self, task: usize) -> (Tensor, Vec<i32>) {
        let (_, test) = self.split();
        self.gather(task, &test)
    }
    fn profile_samples(&self, k: usize) -> Tensor {
        self.x.slice_batch(0, k.min(self.len()))
    }
}

#[derive(Debug, Clone)]
pub struct PrepareConfig {
    /// SGD steps for each individually trained network.
    pub steps_individual: usize,
    /// SGD steps for the multitask retraining of the selected graph.
    pub steps_retrain: usize,
    pub lr: f32,
    /// Branch points D (Table: BP = 3 by default, §5.3).
    pub branch_points: usize,
    /// Profiling samples K for affinity.
    pub profile_k: usize,
    /// Cap on enumerated graphs (exhaustive ≤ this, else clustered).
    pub max_graphs: usize,
    pub seed: u64,
    pub device: Device,
}

impl Default for PrepareConfig {
    fn default() -> PrepareConfig {
        PrepareConfig {
            steps_individual: 150,
            steps_retrain: 200,
            lr: 0.05,
            branch_points: 3,
            profile_k: 24,
            max_graphs: 600,
            seed: 0xA1,
            device: Device::msp430(),
        }
    }
}

/// Everything the serving side needs, plus the intermediate artifacts the
/// benchmarks report on.
pub struct Prepared {
    pub arch: ArchSpec,
    pub ncls: Vec<usize>,
    pub affinity: AffinityTensor,
    pub scores: Vec<GraphScore>,
    pub selected: usize,
    pub graph: TaskGraph,
    pub order: Vec<usize>,
    pub store: GraphWeights,
    /// Individually trained per-task parameter lists (Vanilla baseline).
    pub task_params: Vec<Vec<Tensor>>,
    /// Per-task accuracy of the Vanilla nets.
    pub vanilla_acc: Vec<f64>,
    /// Per-task accuracy of the retrained task graph.
    pub antler_acc: Vec<f64>,
}

/// Run the full §5.3 pipeline.
pub fn prepare<B: Backend + ?Sized, S: TaskSource>(
    backend: &B,
    arch_name: &str,
    source: &S,
    cfg: &PrepareConfig,
) -> Result<Prepared> {
    let arch = backend.arch(arch_name)?;
    let n = source.n_tasks();
    let ncls: Vec<usize> = (0..n).map(|t| source.ncls(t)).collect();
    let mut rng = Pcg32::seed(cfg.seed);

    // 1. individual training (also the Vanilla baseline)
    let mut task_params = Vec::with_capacity(n);
    let mut vanilla_acc = Vec::with_capacity(n);
    for t in 0..n {
        let (params, _losses) = trainer::train_individual(
            backend,
            &arch,
            ncls[t],
            cfg.steps_individual,
            cfg.lr,
            &mut rng,
            |r| source.train_batch(t, r),
        )?;
        let (xt, yt) = source.test_set(t);
        vanilla_acc
            .push(trainer::evaluate(backend, &arch, ncls[t], &params, &xt, &yt)?);
        task_params.push(params);
    }

    // 2. affinity profiling at the branch points
    let bounds = TaskGraph::default_bounds(arch.n_layers(), cfg.branch_points);
    let affinity = profile_affinity(backend, &arch, &bounds, &task_params, source, cfg)?;

    // 3. enumerate + score + select
    let graphs = if n <= 6 {
        enumerate::enumerate_all(n, &bounds, Some(cfg.max_graphs))
    } else {
        enumerate::clustered(&affinity, &bounds, cfg.max_graphs)
    };
    let scores: Vec<GraphScore> = graphs
        .iter()
        .map(|g| score_graph(g, &affinity, &arch, &ncls, &cfg.device))
        .collect();
    let selected = select_tradeoff(&scores);
    let graph = scores[selected].graph.clone();

    // 4. multitask retraining of the selected graph, seeded from the
    //    individually trained nets
    let mut store = GraphWeights::from_task_params(&graph, &arch, &task_params);
    let _losses = trainer::train_graph(
        backend,
        &arch,
        &graph,
        &ncls,
        &mut store,
        cfg.steps_retrain,
        cfg.lr * 0.5,
        &mut rng,
        |task, r| source.train_batch(task, r),
    )?;
    let mut antler_acc = Vec::with_capacity(n);
    for t in 0..n {
        let params = store.assemble(&graph, &arch, t);
        let (xt, yt) = source.test_set(t);
        antler_acc
            .push(trainer::evaluate(backend, &arch, ncls[t], &params, &xt, &yt)?);
    }

    // 5. optimal order for the selected graph
    let order = scores[selected].order.clone();

    Ok(Prepared {
        arch,
        ncls,
        affinity,
        scores,
        selected,
        graph,
        order,
        store,
        task_params,
        vanilla_acc,
        antler_acc,
    })
}

/// §3.1 profiling: run each task's trained network over K samples up to
/// the last branch point, capture activations at every branch point, and
/// assemble the affinity tensor.
pub fn profile_affinity<B: Backend + ?Sized, S: TaskSource>(
    backend: &B,
    arch: &ArchSpec,
    bounds: &[usize],
    task_params: &[Vec<Tensor>],
    source: &S,
    cfg: &PrepareConfig,
) -> Result<AffinityTensor> {
    let k = cfg.profile_k;
    let x0 = source.profile_samples(k);
    // PJRT layer artifacts are lowered at batch 32; pad K up to 32 so the
    // same flow works on every backend
    let batch = 32usize;
    let x0 = if x0.shape[0] < batch {
        let pad = x0.slice_batch(0, batch - x0.shape[0]);
        Tensor::concat_batch(&[&x0, &pad])
    } else {
        x0.slice_batch(0, batch)
    };
    let last = *bounds.last().unwrap();
    let mut profiles: Vec<Vec<Vec<f64>>> = Vec::with_capacity(task_params.len());
    for params in task_params {
        let mut x = x0.clone();
        let mut per_bp = Vec::with_capacity(bounds.len());
        for l in 0..last {
            x = backend.run_layer(
                arch,
                l,
                None,
                &x,
                &params[2 * l],
                &params[2 * l + 1],
            )?;
            if bounds.contains(&(l + 1)) {
                per_bp.push(representation_profile(&x.slice_batch(0, k.min(batch))));
            }
        }
        profiles.push(per_bp);
    }
    Ok(affinity_from_profiles(&profiles))
}

/// Build an ordering problem for a prepared deployment with §7's
/// constraints (presence precedence / conditional).
pub fn deployment_order(
    prepared: &Prepared,
    device: &Device,
    precedence: Vec<(usize, usize)>,
    conditional: Vec<(usize, usize, f64)>,
) -> Result<Vec<usize>> {
    let c = cost_matrix(device, &prepared.arch, &prepared.graph, &prepared.ncls, false);
    let p = OrderingProblem::from_matrix(c)
        .with_precedence(precedence)
        .with_conditional(conditional);
    Ok(solve_held_karp(&p)
        .map(|s| s.order)
        .unwrap_or_else(|| (0..prepared.ncls.len()).collect()))
}

/// Re-entrant per-tenant compile: split the prepared deployment's task
/// set across `n_tenants` ([`tenant_task_split`] — round-robin, surplus
/// tenants wrap to the full set), then push each subset through the
/// same Held–Karp ordering `deployment_order` uses, restricted to the
/// subset's rows and columns of the switching-cost matrix
/// ([`solve_subset`]). Constraints that name a task outside a tenant's
/// subset are vacuous for that tenant and drop out; an infeasible
/// subset falls back to its ascending identity order, mirroring
/// `deployment_order`'s fallback. Tenant `t`'s plan is `plans[t]` —
/// ready to seed a `PlanRegistry` at epoch 0, or to be re-derived live
/// by the cost-drift replanner (`coordinator::replan`).
pub fn compile_tenant_plans(
    prepared: &Prepared,
    device: &Device,
    n_tenants: usize,
    precedence: &[(usize, usize)],
    conditional: &[(usize, usize, f64)],
) -> Vec<ServePlan> {
    let cost =
        cost_matrix(device, &prepared.arch, &prepared.graph, &prepared.ncls, false);
    tenant_task_split(prepared.ncls.len(), n_tenants)
        .into_iter()
        .map(|tasks| {
            let order = solve_subset(&cost, &tasks, precedence, conditional)
                .map(|s| s.order)
                .unwrap_or_else(|| tasks.clone());
            let conditional: Vec<(usize, usize)> = conditional
                .iter()
                .filter(|&&(a, b, _)| {
                    tasks.contains(&a) && tasks.contains(&b)
                })
                .map(|&(a, b, _)| (a, b))
                .collect();
            ServePlan { order, conditional }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset_by_name;
    use crate::runtime::ReferenceBackend;

    #[test]
    fn pipeline_end_to_end_on_imu_tasks() {
        let be = ReferenceBackend::new();
        let ds = dataset_by_name("hhar-s").unwrap().generate(&[128], 360);
        let cfg = PrepareConfig {
            steps_individual: 40,
            steps_retrain: 60,
            max_graphs: 150,
            ..Default::default()
        };
        let prep = prepare(&be, "dnn4", &ds, &cfg).unwrap();
        assert_eq!(prep.ncls, vec![2; 6]);
        assert!(!prep.scores.is_empty());
        assert!(prep.selected < prep.scores.len());
        // orders are permutations
        let mut o = prep.order.clone();
        o.sort_unstable();
        assert_eq!(o, (0..6).collect::<Vec<_>>());
        // accuracy sanity: both systems beat chance on average
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(mean(&prep.vanilla_acc) > 0.6, "{:?}", prep.vanilla_acc);
        assert!(mean(&prep.antler_acc) > 0.6, "{:?}", prep.antler_acc);
        // the selected graph must actually share something
        assert!(prep.graph.model_bytes(&prep.arch, &prep.ncls)
            <= 6 * prep.arch.total_params(2) * 4);
        // affinity is a D x 6 x 6 tensor
        assert_eq!(prep.affinity.n, 6);
        assert_eq!(prep.affinity.d, prep.graph.d());

        // per-tenant compile: two tenants partition the 6 tasks and each
        // tenant's order is a permutation of exactly its subset
        let plans = compile_tenant_plans(&prep, &cfg.device, 2, &[], &[]);
        assert_eq!(plans.len(), 2);
        for (t, plan) in plans.iter().enumerate() {
            let mut got = plan.order.clone();
            got.sort_unstable();
            let want: Vec<usize> = (0..6).filter(|i| i % 2 == t).collect();
            assert_eq!(got, want, "tenant {t} order is not its subset");
        }
        // one tenant == the whole deployment: the subset solve over
        // everything must reproduce deployment_order bit for bit
        let single = compile_tenant_plans(&prep, &cfg.device, 1, &[], &[]);
        let full = deployment_order(&prep, &cfg.device, vec![], vec![]).unwrap();
        assert_eq!(single[0].order, full);
        assert!(single[0].conditional.is_empty());
    }

    /// PJRT variant — kept behind artifact detection.
    #[cfg(feature = "pjrt")]
    mod pjrt {
        use super::*;
        use crate::runtime::pjrt_test_engine;

        #[test]
        fn pipeline_end_to_end_on_imu_tasks_pjrt() {
            let Some(eng) = pjrt_test_engine() else { return };
            let ds = dataset_by_name("hhar-s").unwrap().generate(&[128], 360);
            let cfg = PrepareConfig {
                steps_individual: 40,
                steps_retrain: 60,
                max_graphs: 150,
                ..Default::default()
            };
            let prep = prepare(&eng, "dnn4", &ds, &cfg).unwrap();
            assert_eq!(prep.ncls, vec![2; 6]);
            let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
            assert!(mean(&prep.antler_acc) > 0.6, "{:?}", prep.antler_acc);
        }
    }
}
