//! Cost-drift replanner: the background loop that closes the gap
//! between the `Device` cost model the plans were compiled from and the
//! costs the shards actually observe. Shards stream per-task simulated
//! service times ([`CostObs`]) over a channel; the [`DriftModel`]
//! accumulates an EWMA per (tenant, task) and compares the *shape* of
//! observed costs against the shape the tenant's cost matrix predicts.
//! When the worst per-task relative drift exceeds
//! [`DriftConfig::threshold`] with every task at
//! [`DriftConfig::min_samples`], the tenant's cost-matrix columns are
//! rescaled by the observed/predicted ratio, the ordering pipeline is
//! re-run off the hot path (`ordering::solve_subset` — the same
//! Held–Karp the offline compile uses), and the new plan is published
//! to the [`PlanRegistry`] as a new epoch. In-flight frames are
//! untouched: the epoch-based hot-swap (`coordinator::registry`) lets
//! them finish on the plan they were admitted under.
//!
//! The drift arithmetic is deliberately a handful of pure f64
//! operations — `tools/verify_replanner.py` is a line-for-line port
//! that replays the same traces without cargo (same contract as
//! `verify_tier_model.py` / `verify_analyzer.py`).
//!
//! Why shapes, not absolute times: observations are *simulated* device
//! seconds (`Cost::time()` from the executor), so they are deterministic
//! — but a task's observed per-frame cost includes whatever trunk blocks
//! its round position makes it pay, while the matrix predicts pairwise
//! switching costs. Normalizing both sides to mean 1.0 compares the
//! relative expensiveness of tasks, which is exactly what reordering can
//! exploit; a uniform slowdown (same shape, bigger numbers) correctly
//! triggers nothing, because no reorder can help it.

use crate::ordering::solve_subset;
use crate::sync::mpsc::{channel, Sender};
use crate::sync::{thread, Arc};

use super::registry::PlanRegistry;
use super::server::ServePlan;

/// One per-task service-time observation from a shard: `secs` is
/// simulated device seconds for one execution of `task` on a frame of
/// `tenant` (the single-frame serving path reports these; batched
/// rounds amortize block loads across frames and are skipped).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostObs {
    pub tenant: u32,
    pub task: usize,
    pub secs: f64,
}

/// Drift-trigger knobs. The defaults are conservative: half again off
/// the predicted shape, sustained over 32 samples per task.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftConfig {
    /// Max per-task relative drift of the normalized observed shape vs
    /// the normalized predicted shape that triggers a replan.
    pub threshold: f64,
    /// Observations required for EVERY task of a tenant before its
    /// drift is trusted.
    pub min_samples: usize,
    /// EWMA smoothing factor for observed costs (1.0 = last sample).
    pub alpha: f64,
}

impl Default for DriftConfig {
    fn default() -> DriftConfig {
        DriftConfig { threshold: 0.5, min_samples: 32, alpha: 0.2 }
    }
}

/// A tenant's compile context, carried by the replanner so the ordering
/// pipeline can be re-run off the hot path without touching the
/// `Prepared` artifacts.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub tenant: u32,
    /// The tenant's task subset, original task ids.
    pub tasks: Vec<usize>,
    /// Full n×n switching-cost matrix from the `Device` model
    /// (`memory::cost_matrix`) — the replanner rescales a copy's
    /// columns as drift is confirmed.
    pub cost: Vec<Vec<f64>>,
    pub precedence: Vec<(usize, usize)>,
    pub conditional: Vec<(usize, usize, f64)>,
}

/// One published replan, in publication order.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplanEvent {
    pub tenant: u32,
    /// The epoch the new plan was published as.
    pub epoch: u64,
    /// The max per-task relative drift that triggered it.
    pub max_drift: f64,
}

/// Per-tenant accumulator state.
#[derive(Debug)]
struct TenantState {
    spec: TenantSpec,
    /// task id -> position in `spec.tasks`, usize::MAX = not ours.
    local: Vec<usize>,
    /// Predicted per-task cost: mean over the subset's other tasks of
    /// the matrix column into this task (cost of switching INTO it).
    predicted: Vec<f64>,
    /// EWMA of observed per-task cost, per subset position.
    ewma: Vec<Option<f64>>,
    samples: Vec<usize>,
}

impl TenantState {
    fn new(spec: TenantSpec, n_tasks: usize) -> TenantState {
        let mut local = vec![usize::MAX; n_tasks];
        for (i, &t) in spec.tasks.iter().enumerate() {
            if t < n_tasks {
                local[t] = i;
            }
        }
        let k = spec.tasks.len();
        let predicted = predicted_from_matrix(&spec.cost, &spec.tasks);
        TenantState {
            spec,
            local,
            predicted,
            ewma: vec![None; k],
            samples: vec![0; k],
        }
    }

    /// Reset the accumulator after a publish: the rescaled matrix IS
    /// the model now, so drift restarts from zero against it — without
    /// this, persistent drift would republish every sample forever.
    fn reset(&mut self) {
        self.predicted = predicted_from_matrix(&self.spec.cost, &self.spec.tasks);
        for e in self.ewma.iter_mut() {
            *e = None;
        }
        for s in self.samples.iter_mut() {
            *s = 0;
        }
    }
}

/// predicted[i] = mean over j≠i of cost[tasks[j]][tasks[i]] — the
/// average modeled cost of switching into task i from elsewhere in the
/// subset. Singleton subsets predict 0 (and can never trigger: there is
/// nothing to reorder).
fn predicted_from_matrix(cost: &[Vec<f64>], tasks: &[usize]) -> Vec<f64> {
    let k = tasks.len();
    tasks
        .iter()
        .map(|&into| {
            if k < 2 {
                return 0.0;
            }
            let sum: f64 = tasks
                .iter()
                .filter(|&&from| from != into)
                .map(|&from| cost[from][into])
                .sum();
            sum / (k - 1) as f64
        })
        .collect()
}

/// Normalize a cost vector to mean 1.0 (shape). All-zero stays all-zero.
fn shape(v: &[f64]) -> Vec<f64> {
    let mean = v.iter().sum::<f64>() / v.len().max(1) as f64;
    if mean <= 0.0 {
        return v.to_vec();
    }
    v.iter().map(|&x| x / mean).collect()
}

/// The drift detector + replan compiler, pure and synchronous:
/// [`DriftModel::observe`] folds one observation in and returns the new
/// plan when that observation tips a tenant over the threshold.
/// `spawn_replanner` wraps it in a thread; the tests and the Python
/// port drive it directly.
#[derive(Debug)]
pub struct DriftModel {
    cfg: DriftConfig,
    tenants: Vec<TenantState>,
}

impl DriftModel {
    pub fn new(specs: Vec<TenantSpec>, cfg: DriftConfig) -> DriftModel {
        let n_tasks = specs.iter().map(|s| s.cost.len()).max().unwrap_or(0);
        DriftModel {
            cfg,
            tenants: specs
                .into_iter()
                .map(|s| TenantState::new(s, n_tasks))
                .collect(),
        }
    }

    /// Fold one observation in. Returns `Some((tenant, plan, max_drift))`
    /// when this observation confirms drift for its tenant: the tenant's
    /// matrix columns have been rescaled, the subset re-ordered, and the
    /// accumulator reset — the caller's only job is to publish.
    pub fn observe(
        &mut self,
        obs: CostObs,
    ) -> Option<(u32, ServePlan, f64)> {
        let a = self.cfg.alpha;
        let ti = self
            .tenants
            .iter()
            .position(|t| t.spec.tenant == obs.tenant)?;
        let st = &mut self.tenants[ti];
        let pos = *st.local.get(obs.task)?;
        if pos == usize::MAX {
            return None;
        }
        st.ewma[pos] = Some(match st.ewma[pos] {
            None => obs.secs,
            Some(e) => (1.0 - a) * e + a * obs.secs,
        });
        st.samples[pos] += 1;
        self.check(ti)
    }

    /// The drift-trigger arithmetic — ported line for line by
    /// `tools/verify_replanner.py`; keep the two in lockstep.
    fn check(&mut self, ti: usize) -> Option<(u32, ServePlan, f64)> {
        let cfg = self.cfg;
        let st = &mut self.tenants[ti];
        let k = st.spec.tasks.len();
        if k < 2 {
            return None;
        }
        if st.samples.iter().any(|&s| s < cfg.min_samples) {
            return None;
        }
        let observed: Vec<f64> =
            st.ewma.iter().map(|e| e.unwrap_or(0.0)).collect();
        let p_hat = shape(&st.predicted);
        let o_hat = shape(&observed);
        let mut max_drift = 0.0f64;
        for i in 0..k {
            let denom = p_hat[i].max(1e-12);
            let d = (o_hat[i] - p_hat[i]).abs() / denom;
            if d > max_drift {
                max_drift = d;
            }
        }
        if max_drift <= cfg.threshold {
            return None;
        }
        // confirmed: rescale the matrix columns by observed/predicted
        // shape ratio — column j is the cost of switching INTO task j,
        // which is what the per-task observation measures
        for i in 0..k {
            let m = o_hat[i] / p_hat[i].max(1e-12);
            let col = st.spec.tasks[i];
            for row in st.spec.cost.iter_mut() {
                if col < row.len() {
                    row[col] *= m;
                }
            }
        }
        let order = solve_subset(
            &st.spec.cost,
            &st.spec.tasks,
            &st.spec.precedence,
            &st.spec.conditional,
        )
        .map(|s| s.order)
        .unwrap_or_else(|| st.spec.tasks.clone());
        let conditional: Vec<(usize, usize)> = st
            .spec
            .conditional
            .iter()
            .filter(|&&(x, y, _)| {
                st.spec.tasks.contains(&x) && st.spec.tasks.contains(&y)
            })
            .map(|&(x, y, _)| (x, y))
            .collect();
        let tenant = st.spec.tenant;
        st.reset();
        Some((tenant, ServePlan { order, conditional }, max_drift))
    }
}

/// Spawn the background replanner: returns the observation sender
/// (clone it into every shard worker) and a handle yielding the
/// published [`ReplanEvent`]s. The thread exits when the last sender is
/// dropped — `serve_registry_core` drops the workers' clones as they
/// finish, so `handle.join()` after the serve returns is drain-free.
pub fn spawn_replanner(
    registry: Arc<PlanRegistry>,
    specs: Vec<TenantSpec>,
    cfg: DriftConfig,
) -> (Sender<CostObs>, thread::JoinHandle<Vec<ReplanEvent>>) {
    let (tx, rx) = channel::<CostObs>();
    let handle = thread::spawn(move || {
        let mut model = DriftModel::new(specs, cfg);
        let mut events = Vec::new();
        while let Ok(obs) = rx.recv() {
            if let Some((tenant, plan, max_drift)) = model.observe(obs) {
                let epoch = registry.publish(tenant, plan);
                events.push(ReplanEvent { tenant, epoch, max_drift });
            }
        }
        events
    });
    (tx, handle)
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    /// 3 tasks, strongly asymmetric columns: switching into task 2 is
    /// modeled 4x the cost of switching into task 0.
    fn spec(tenant: u32) -> TenantSpec {
        TenantSpec {
            tenant,
            tasks: vec![0, 1, 2],
            cost: vec![
                vec![0.0, 2.0, 4.0],
                vec![1.0, 0.0, 4.0],
                vec![1.0, 2.0, 0.0],
            ],
            precedence: vec![],
            conditional: vec![],
        }
    }

    fn cfg() -> DriftConfig {
        // alpha 1.0: the EWMA is the last sample — deterministic tests
        DriftConfig { threshold: 0.5, min_samples: 2, alpha: 1.0 }
    }

    fn feed(
        model: &mut DriftModel,
        tenant: u32,
        costs: &[f64],
        rounds: usize,
    ) -> Option<(u32, ServePlan, f64)> {
        let mut fired = None;
        for _ in 0..rounds {
            for (task, &secs) in costs.iter().enumerate() {
                if let Some(hit) =
                    model.observe(CostObs { tenant, task, secs })
                {
                    fired = Some(hit);
                }
            }
        }
        fired
    }

    #[test]
    fn matching_shape_never_triggers() {
        let mut m = DriftModel::new(vec![spec(0)], cfg());
        // observations proportional to the predicted column means
        // (1.0, 2.0, 4.0): same shape, scaled 3x — a uniform slowdown
        // that reordering cannot help must not trigger
        assert!(feed(&mut m, 0, &[3.0, 6.0, 12.0], 8).is_none());
    }

    #[test]
    fn quiet_below_min_samples() {
        let mut m = DriftModel::new(
            vec![spec(0)],
            DriftConfig { min_samples: 50, ..cfg() },
        );
        // wildly drifted, but not enough evidence yet
        assert!(feed(&mut m, 0, &[9.0, 0.1, 0.1], 20).is_none());
    }

    #[test]
    fn inverted_costs_trigger_and_resolve_to_a_new_order() {
        let mut m = DriftModel::new(vec![spec(0)], cfg());
        // the model says task 2 is the expensive switch; reality says
        // task 0 is — shape fully inverted
        let (tenant, plan, max_drift) = feed(&mut m, 0, &[4.0, 2.0, 1.0], 4)
            .expect("inverted shape must trigger");
        assert_eq!(tenant, 0);
        assert!(max_drift > 0.5, "drift {max_drift}");
        let mut got = plan.order.clone();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2], "plan must stay a permutation");
        // after the publish the rescaled matrix IS the model: the same
        // observations must now be on-shape and quiet
        assert!(
            feed(&mut m, 0, &[4.0, 2.0, 1.0], 8).is_none(),
            "replanner must not republish without fresh drift"
        );
    }

    #[test]
    fn observations_route_by_tenant_and_foreign_tasks_are_ignored() {
        let two = TenantSpec { tenant: 1, tasks: vec![0, 1], ..spec(1) };
        let mut m = DriftModel::new(vec![spec(0), two], cfg());
        // tenant 7 is unknown; task 9 is nobody's — both are no-ops
        assert!(m.observe(CostObs { tenant: 7, task: 0, secs: 9.0 }).is_none());
        assert!(m.observe(CostObs { tenant: 0, task: 9, secs: 9.0 }).is_none());
        // tenant 1 never owns task 2: its observation is dropped, so
        // tenant 1 cannot reach min_samples on a foreign task
        assert!(m.observe(CostObs { tenant: 1, task: 2, secs: 9.0 }).is_none());
    }

    #[test]
    fn singleton_tenants_never_replan() {
        let one = TenantSpec { tasks: vec![1], ..spec(0) };
        let mut m = DriftModel::new(vec![one], cfg());
        for _ in 0..20 {
            assert!(m
                .observe(CostObs { tenant: 0, task: 1, secs: 99.0 })
                .is_none());
        }
    }

    #[test]
    fn spawned_replanner_publishes_epochs_to_the_registry() {
        let registry = Arc::new(PlanRegistry::new(vec![
            ServePlan::unconditional(vec![0, 1, 2]),
        ]));
        let (tx, handle) =
            spawn_replanner(Arc::clone(&registry), vec![spec(0)], cfg());
        for _ in 0..4 {
            for (task, secs) in [(0, 4.0), (1, 2.0), (2, 1.0)] {
                tx.send(CostObs { tenant: 0, task, secs }).unwrap();
            }
        }
        drop(tx); // last sender gone: the replanner drains and reports
        let events = handle.join().expect("replanner thread panicked");
        assert_eq!(events.len(), 1, "one confirmed drift, one publish");
        assert_eq!(events[0].tenant, 0);
        assert_eq!(events[0].epoch, 1);
        assert!(events[0].max_drift > 0.5);
        let current = registry.current(0);
        assert_eq!(current.epoch, 1);
        let mut got = current.plan.order.clone();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2]);
    }
}
