//! Multi-producer ingest tier: K producer threads feeding a shared sink
//! (the work-stealing scheduler's bounded injector in production, any
//! `Fn(Frame) -> bool` in tests) from a set of independent frame
//! sources.
//!
//! A [`Source`] models a real sampling front-end: frames arrive on a
//! schedule (`interval`), cost CPU to admit (`prep` — the decode/copy a
//! real driver does), and go stale (`slack`) when the producer falls
//! behind the schedule — a sensor does not deliver ancient frames, it
//! drops them and keeps up. The pool assigns sources to producers
//! round-robin; each producer rotates fairly among its sources that are
//! currently due (so a flood source cannot starve a paced sibling into
//! staleness) and sleeps to the earliest schedule otherwise — one
//! thread paces many slow sources and K threads split sources one
//! thread cannot hold (the ingest-bound regime `benches/runtime_hotpath`
//! measures: K=4 keeps every schedule where K=1 drops stale frames).
//!
//! Accounting is per source and exact: every offered frame is delivered,
//! dropped stale, or dropped by sink backpressure — nothing else — so
//! `delivered + dropped == offered` holds per source and in aggregate
//! (asserted at the shutdown barrier). The barrier itself is
//! `std::thread::scope`: [`run_ingest`] returns only after every
//! producer has joined and handed back its source reports, so a report
//! can never under-count an in-flight frame.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::model::Tensor;
use crate::sync::thread;

use super::audit::SourceLedger;
use super::server::Frame;
use super::wire::QosClass;

/// One frame source behind the ingest tier.
#[derive(Debug)]
pub struct Source {
    /// Name used in per-source accounting ("mic0", "cam1", ...).
    pub name: String,
    /// The frames this source will offer, in order.
    pub frames: Vec<(u64, Tensor)>,
    /// Real-time schedule: frame `i` is due at pool start + `i * interval`.
    /// `None` = flood (every frame due immediately).
    pub interval: Option<Duration>,
    /// Staleness budget: a frame whose producer reaches it more than
    /// `slack` past its due time is dropped at ingest (a sampling
    /// front-end sheds overrun frames instead of delivering them late).
    /// `None` = deliver no matter how late. Ignored without a schedule
    /// (`interval`): a flood source has nothing to fall behind.
    pub slack: Option<Duration>,
    /// Per-frame admission cost (the decode/copy model), burned on the
    /// producer thread before hand-off. This is what makes a fast source
    /// "faster than one producer thread".
    pub prep: Option<Duration>,
    /// Admission class stamped on every frame this source offers
    /// (`coordinator::wire`). Defaults to [`QosClass::Realtime`] — the
    /// class the shedding rule always admits — so in-process synthetic
    /// sources behave exactly as before the network front-end existed;
    /// class-aware sinks (`WsDispatch::offer_classed`) shed lower
    /// classes first under backpressure.
    pub qos: QosClass,
    /// Tenant stamped on every frame this source offers. A registry
    /// sink routes the frame to this tenant's current plan version;
    /// the default 0 keeps single-tenant callers on their old path.
    pub tenant: u32,
}

impl Source {
    /// An unpaced source: every frame due immediately, never stale.
    pub fn flood(name: &str, frames: Vec<(u64, Tensor)>) -> Source {
        Source {
            name: name.to_string(),
            frames,
            interval: None,
            slack: None,
            prep: None,
            qos: QosClass::Realtime,
            tenant: 0,
        }
    }

    /// A paced source: one frame due every `interval`, never stale.
    pub fn paced(
        name: &str,
        frames: Vec<(u64, Tensor)>,
        interval: Duration,
    ) -> Source {
        Source { interval: Some(interval), ..Source::flood(name, frames) }
    }

    /// Same source, different admission class.
    pub fn with_qos(self, qos: QosClass) -> Source {
        Source { qos, ..self }
    }

    /// Same source, owned by a different tenant.
    pub fn with_tenant(self, tenant: u32) -> Source {
        Source { tenant, ..self }
    }
}

/// Split a flat frame list into `k` flood [`Source`]s by *position*
/// round-robin — the ONE assignment path between a CLI frame list and
/// the producer pool. `run_ingest` then assigns source `i` to producer
/// `i % k` with the same positional rule, so the two layers can never
/// disagree. `k` is clamped to the frame count and empty splits are
/// dropped, so clamping (or `k > frames`) can never produce a source no
/// producer owns: previously `main.rs` split by frame *id* modulo the
/// unclamped producer count, a second assignment rule that could strand
/// a source when the two disagreed.
pub fn split_round_robin(
    frames: Vec<(u64, Tensor)>,
    k: usize,
    prefix: &str,
) -> Vec<Source> {
    let k = k.max(1).min(frames.len().max(1));
    let mut splits: Vec<Vec<(u64, Tensor)>> =
        (0..k).map(|_| Vec::new()).collect();
    for (i, f) in frames.into_iter().enumerate() {
        splits[i % k].push(f);
    }
    splits
        .into_iter()
        .enumerate()
        .filter(|(_, fs)| !fs.is_empty())
        .map(|(i, fs)| Source::flood(&format!("{prefix}{i}"), fs))
        .collect()
}

/// Per-source accounting after the pool drains.
#[derive(Debug, Clone)]
pub struct SourceReport {
    pub name: String,
    /// Frames the source held when ingest started.
    pub offered: usize,
    /// Frames handed to the sink and accepted.
    pub delivered: usize,
    /// Frames shed at ingest because the producer fell behind the
    /// source's schedule by more than its slack.
    pub dropped_stale: usize,
    /// Frames the sink rejected (downstream queue full).
    pub dropped_backpressure: usize,
}

impl SourceReport {
    pub fn dropped(&self) -> usize {
        self.dropped_stale + self.dropped_backpressure
    }
}

/// Aggregate result of one ingest run.
#[derive(Debug, Clone)]
pub struct IngestReport {
    /// Producer threads actually used (clamped to the source count).
    pub producers: usize,
    /// Per-source accounting, in the order the sources were given.
    pub sources: Vec<SourceReport>,
}

impl IngestReport {
    pub fn offered(&self) -> usize {
        self.sources.iter().map(|s| s.offered).sum()
    }

    pub fn delivered(&self) -> usize {
        self.sources.iter().map(|s| s.delivered).sum()
    }

    pub fn dropped(&self) -> usize {
        self.sources.iter().map(|s| s.dropped()).sum()
    }

    pub fn dropped_stale(&self) -> usize {
        self.sources.iter().map(|s| s.dropped_stale).sum()
    }

    pub fn dropped_backpressure(&self) -> usize {
        self.sources.iter().map(|s| s.dropped_backpressure).sum()
    }
}

/// One producer's view of one source while the pool runs.
struct Cursor {
    /// Original index in the caller's source list (reports are returned
    /// in that order).
    src_i: usize,
    name: String,
    interval: Option<Duration>,
    slack: Option<Duration>,
    prep: Option<Duration>,
    qos: QosClass,
    tenant: u32,
    frames: VecDeque<(u64, Tensor)>,
    offered: usize,
    sent: usize,
    delivered: usize,
    stale: usize,
    backpressure: usize,
    /// Debug-build custody ledger (`coordinator::audit`): every offered
    /// frame must end as exactly one of delivered / stale /
    /// backpressure. Zero-sized no-op in release.
    audit: SourceLedger,
}

impl Cursor {
    fn new(src_i: usize, src: Source) -> Cursor {
        let offered = src.frames.len();
        Cursor {
            src_i,
            name: src.name,
            interval: src.interval,
            slack: src.slack,
            prep: src.prep,
            qos: src.qos,
            tenant: src.tenant,
            frames: src.frames.into(),
            offered,
            sent: 0,
            delivered: 0,
            stale: 0,
            backpressure: 0,
            audit: SourceLedger::new(offered),
        }
    }

    /// When the source's next frame is due. Flood sources are always due.
    fn due(&self, start: Instant) -> Instant {
        match self.interval {
            Some(iv) => start + iv * self.sent as u32,
            None => start,
        }
    }

    fn into_report(self) -> (usize, SourceReport) {
        // the ledger agrees with the counters it shadowed, and no frame
        // is still unaccounted (debug builds; free in release)
        self.audit.reconcile(self.delivered, self.stale, self.backpressure);
        (
            self.src_i,
            SourceReport {
                name: self.name,
                offered: self.offered,
                delivered: self.delivered,
                dropped_stale: self.stale,
                dropped_backpressure: self.backpressure,
            },
        )
    }
}

/// Occupy this thread for `d` — the synthetic decode/copy cost that
/// makes a single producer fall behind several schedules.
///
/// Short costs spin: at the sub-millisecond scale the paced-source
/// timing tests (and real sensor pacing) live at, an OS sleep's wakeup
/// jitter would swamp the cost being modeled. Longer costs used to spin
/// too — pinning a core at 100% doing nothing for multi-millisecond
/// `prep` values — so above [`SPIN_TAIL`] the wait now sleeps to within
/// `SPIN_TAIL` of the target and spins only the tail: the producer is
/// still occupied (unavailable to its other sources) for the full `d`,
/// with spin-accurate completion, without burning the core for the bulk
/// of a long wait.
const SPIN_TAIL: Duration = Duration::from_micros(500);

fn busy_wait(d: Duration) {
    let t = Instant::now();
    if d > SPIN_TAIL {
        // under loom this sleep is a yield (no clock there); the spin
        // tail below still runs the full duration on real builds
        thread::sleep(d - SPIN_TAIL);
    }
    while t.elapsed() < d {
        std::hint::spin_loop();
    }
}

/// One producer thread's loop: rotate fairly among the owned sources
/// that are due (sleeping to the earliest schedule when none are) and
/// pump frames into the sink until every owned source is exhausted.
fn produce<S>(
    mut curs: Vec<Cursor>,
    start: Instant,
    sink: &S,
) -> Vec<(usize, SourceReport)>
where
    S: Fn(Frame) -> bool,
{
    if curs.is_empty() {
        return Vec::new();
    }
    let mut rot = 0usize;
    let m = curs.len();
    loop {
        // pick among the owned sources fairly: rotate over sources whose
        // next frame is already due (a flood source is due forever, and a
        // strict earliest-due pick would let it starve a paced sibling
        // into staleness); only when nothing is due yet, sleep until the
        // earliest-due source. Per-source FIFO is preserved either way —
        // frames always leave a source front-first.
        let now = Instant::now();
        let due_now = (0..m)
            .map(|off| (rot + off) % m)
            .find(|&i| {
                !curs[i].frames.is_empty() && curs[i].due(start) <= now
            });
        let ci = match due_now {
            Some(i) => i,
            None => {
                let next = curs
                    .iter()
                    .enumerate()
                    .filter(|(_, c)| !c.frames.is_empty())
                    .min_by_key(|(_, c)| c.due(start))
                    .map(|(i, _)| i);
                let Some(i) = next else { break };
                i
            }
        };
        rot = (ci + 1) % m;
        let c = &mut curs[ci];
        let due = c.due(start);
        if due > now {
            thread::sleep(due - now);
        }
        // staleness is decided on arrival at the frame, before paying the
        // admission cost: a front-end that has fallen behind sheds cheaply
        // to catch back up to the schedule. Only scheduled sources can go
        // stale — a flood source has no schedule to fall behind, so its
        // `slack` (if any) is ignored rather than shedding every frame
        // past pool start + slack.
        let late = now.saturating_duration_since(due);
        // both picks above filter for non-empty, so the pop always
        // yields; if that invariant ever broke, re-picking is strictly
        // safer than panicking the producer mid-stream
        let Some((id, input)) = c.frames.pop_front() else { continue };
        c.sent += 1;
        let stale = c.interval.is_some()
            && c.slack.is_some_and(|slack| late > slack);
        if stale {
            c.stale += 1;
            c.audit.stale();
        } else {
            if let Some(p) = c.prep {
                busy_wait(p);
            }
            // propagate the staleness budget downstream as an absolute
            // deadline (`due + slack` — the instant this frame would
            // have been shed here): plain sinks ignore it, class-aware
            // sinks (`offer_classed`) shed at it instead of queueing a
            // frame the contract already condemned. Flood sources carry
            // no schedule and therefore no deadline.
            let deadline = match (c.interval, c.slack) {
                (Some(_), Some(slack)) => Some(due + slack),
                _ => None,
            };
            if sink(
                Frame::with_qos(id, input, c.qos, deadline)
                    .with_tenant(c.tenant),
            ) {
                c.delivered += 1;
                c.audit.deliver();
            } else {
                c.backpressure += 1;
                c.audit.backpressure();
            }
        }
    }
    curs.into_iter().map(Cursor::into_report).collect()
}

/// Run `producers` threads over `sources` (assigned round-robin),
/// delivering every non-stale frame to `sink`. `sink` returns whether
/// the frame was accepted downstream; a rejection is counted against the
/// frame's source as backpressure. Returns only after every producer has
/// joined (the graceful-shutdown barrier), with exact per-source
/// accounting.
pub fn run_ingest<S>(
    sources: Vec<Source>,
    producers: usize,
    sink: &S,
) -> IngestReport
where
    S: Fn(Frame) -> bool + Sync,
{
    let k = producers.max(1).min(sources.len().max(1));
    let mut owned: Vec<Vec<Cursor>> = (0..k).map(|_| Vec::new()).collect();
    for (i, src) in sources.into_iter().enumerate() {
        owned[i % k].push(Cursor::new(i, src));
    }
    let start = Instant::now();
    let mut tagged: Vec<(usize, SourceReport)> = thread::scope(|scope| {
        let handles: Vec<_> = owned
            .into_iter()
            .map(|curs| scope.spawn(move || produce(curs, start, sink)))
            .collect();
        // the barrier: every producer reports before anyone reads. A
        // panicked producer re-raises on the caller rather than being
        // swallowed into a bogus "all delivered" report
        handles
            .into_iter()
            .flat_map(|h| match h.join() {
                Ok(reports) => reports,
                Err(payload) => std::panic::resume_unwind(payload),
            })
            .collect()
    });
    tagged.sort_by_key(|&(i, _)| i);
    let sources: Vec<SourceReport> =
        tagged.into_iter().map(|(_, r)| r).collect();
    // the conservation contract is enforced in release builds too — an
    // accounting regression must fail loudly, not ship in the serving
    // path; the check is O(sources) and free next to the joins above
    for s in &sources {
        assert_eq!(
            s.delivered + s.dropped(),
            s.offered,
            "ingest source {} leaks frames",
            s.name
        );
    }
    IngestReport { producers: k, sources }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::sync::atomic::{AtomicUsize, Ordering};
    use crate::sync::{lock_unpoisoned, Mutex};

    fn frames(base: u64, n: usize) -> Vec<(u64, Tensor)> {
        (0..n as u64)
            .map(|i| (base + i, Tensor::full(vec![1, 2, 2, 1], 0.5)))
            .collect()
    }

    #[test]
    fn all_frames_delivered_in_per_source_order() {
        let sources = vec![
            Source::flood("a", frames(0, 7)),
            Source::flood("b", frames(100, 4)),
            Source::flood("c", frames(200, 9)),
        ];
        let seen = Mutex::new(Vec::<u64>::new());
        let report = run_ingest(sources, 2, &|f: Frame| {
            lock_unpoisoned(&seen).push(f.id);
            true
        });
        assert_eq!(report.producers, 2);
        assert_eq!(report.offered(), 20);
        assert_eq!(report.delivered(), 20);
        assert_eq!(report.dropped(), 0);
        for (s, (base, n)) in
            report.sources.iter().zip([(0u64, 7), (100, 4), (200, 9)])
        {
            assert_eq!(s.offered, n);
            assert_eq!(s.delivered, n);
            // per-source FIFO order survives the merge and the threads
            let seen = lock_unpoisoned(&seen);
            let got: Vec<u64> = seen
                .iter()
                .copied()
                .filter(|id| (base..base + 100).contains(id))
                .collect();
            assert_eq!(got, (base..base + n as u64).collect::<Vec<_>>());
        }
    }

    #[test]
    fn rejecting_sink_counts_backpressure_per_source() {
        let sources = vec![
            Source::flood("a", frames(0, 5)),
            Source::flood("b", frames(100, 3)),
        ];
        let report = run_ingest(sources, 2, &|_| false);
        assert_eq!(report.delivered(), 0);
        assert_eq!(report.dropped_backpressure(), 8);
        assert_eq!(report.dropped_stale(), 0);
        for s in &report.sources {
            assert_eq!(s.delivered + s.dropped(), s.offered);
        }
    }

    #[test]
    fn flaky_sink_conserves_exactly() {
        // the sink rejects every other frame; conservation stays exact
        let sources = vec![
            Source::flood("a", frames(0, 11)),
            Source::flood("b", frames(100, 6)),
        ];
        let flip = AtomicUsize::new(0);
        let report = run_ingest(sources, 3, &|_| {
            flip.fetch_add(1, Ordering::Relaxed) % 2 == 0
        });
        assert_eq!(report.delivered() + report.dropped(), 17);
        assert!(report.delivered() > 0);
        assert!(report.dropped_backpressure() > 0);
        for s in &report.sources {
            assert_eq!(s.delivered + s.dropped(), s.offered);
        }
    }

    #[test]
    fn overrun_schedule_sheds_stale_frames() {
        // a zero-slack schedule the producer is behind from the first
        // instant: (almost) every frame is shed as stale, and the shed
        // frames never reach the sink — but they are still accounted
        let src = Source {
            interval: Some(Duration::from_nanos(1)),
            slack: Some(Duration::ZERO),
            ..Source::flood("hot", frames(0, 16))
        };
        let seen = AtomicUsize::new(0);
        let report = run_ingest(vec![src], 1, &|_| {
            seen.fetch_add(1, Ordering::Relaxed);
            true
        });
        let s = &report.sources[0];
        assert_eq!(s.delivered + s.dropped(), 16);
        // the very first frame can land exactly on its due instant; all
        // later ones are strictly late on a zero-slack nanosecond grid
        assert!(s.dropped_stale >= 15, "only {} stale", s.dropped_stale);
        assert_eq!(s.delivered, seen.load(Ordering::Relaxed));
    }

    #[test]
    fn slack_without_schedule_is_ignored() {
        // a flood source has no schedule to fall behind: a (misguided)
        // slack on it must not shed frames that are merely later than
        // pool start + slack
        let src = Source {
            slack: Some(Duration::ZERO),
            prep: Some(Duration::from_micros(50)),
            ..Source::flood("flood-with-slack", frames(0, 50))
        };
        let report = run_ingest(vec![src], 1, &|_| true);
        assert_eq!(report.delivered(), 50);
        assert_eq!(report.dropped_stale(), 0);
    }

    #[test]
    fn no_slack_delivers_no_matter_how_late() {
        // same overrun schedule, but slack = None: lateness never sheds
        let src = Source {
            interval: Some(Duration::from_nanos(1)),
            ..Source::flood("late-ok", frames(0, 10))
        };
        let report = run_ingest(vec![src], 1, &|_| true);
        assert_eq!(report.delivered(), 10);
        assert_eq!(report.dropped(), 0);
    }

    #[test]
    fn flood_source_does_not_starve_paced_sibling() {
        // one producer owns both a large flood source (always due, ~60 ms
        // of admission work) and a paced source whose frames go stale
        // 8 ms past their 2 ms schedule. A strict earliest-due merge
        // would drain the flood first and shed every paced frame; the
        // rotating pick must interleave them so (almost) none go stale.
        let flood = Source {
            prep: Some(Duration::from_micros(300)),
            ..Source::flood("bulk", frames(1000, 200))
        };
        let paced = Source {
            interval: Some(Duration::from_millis(2)),
            slack: Some(Duration::from_millis(8)),
            ..Source::flood("sensor", frames(0, 20))
        };
        let report = run_ingest(vec![flood, paced], 1, &|_| true);
        let bulk = &report.sources[0];
        let sensor = &report.sources[1];
        assert_eq!(bulk.delivered, 200);
        assert_eq!(sensor.delivered + sensor.dropped(), 20);
        // generous bound for scheduling noise; total starvation (the old
        // earliest-due rule) would shed all 20
        assert!(
            sensor.dropped_stale <= 5,
            "paced source starved: {} of 20 stale",
            sensor.dropped_stale
        );
    }

    #[test]
    fn producer_count_clamps_to_sources() {
        let report =
            run_ingest(vec![Source::flood("only", frames(0, 3))], 8, &|_| true);
        assert_eq!(report.producers, 1);
        assert_eq!(report.delivered(), 3);
    }

    #[test]
    fn busy_wait_hybrid_occupies_full_duration() {
        // below the spin tail: pure spin, exact as ever. Above it: the
        // sleep+spin hybrid must still run the FULL duration (the
        // producer stays occupied), never return early, and not overrun
        // wildly — the paced timing tests above depend on that.
        for d in [Duration::from_micros(200), Duration::from_millis(3)] {
            let t = Instant::now();
            busy_wait(d);
            let took = t.elapsed();
            assert!(took >= d, "busy_wait returned early: {took:?} < {d:?}");
            assert!(
                took < d + Duration::from_millis(40),
                "busy_wait overran: {took:?} for {d:?}"
            );
        }
    }

    #[test]
    fn split_round_robin_is_positional_and_strands_nothing() {
        // position-based deal: frame ids play no role in assignment
        // (ids here are deliberately NOT 0..n, the old id-modulo rule
        // would scatter them differently)
        let fs: Vec<(u64, Tensor)> = [7u64, 7, 9, 1000, 3, 5]
            .iter()
            .map(|&id| (id, Tensor::full(vec![1, 1, 1, 1], 0.0)))
            .collect();
        let srcs = split_round_robin(fs.clone(), 2, "cli");
        assert_eq!(srcs.len(), 2);
        assert_eq!(
            srcs[0].frames.iter().map(|f| f.0).collect::<Vec<_>>(),
            vec![7, 9, 3]
        );
        assert_eq!(
            srcs[1].frames.iter().map(|f| f.0).collect::<Vec<_>>(),
            vec![7, 1000, 5]
        );
        // k beyond the frame count clamps — no empty source is ever
        // produced for a producer to be stranded with (or without)
        let srcs = split_round_robin(fs.clone(), 64, "cli");
        assert_eq!(srcs.len(), 6);
        assert!(srcs.iter().all(|s| s.frames.len() == 1));
        // and the whole pipeline conserves: every frame lands exactly once
        let total: usize = srcs.iter().map(|s| s.frames.len()).sum();
        assert_eq!(total, 6);
        assert!(split_round_robin(Vec::new(), 4, "cli").is_empty());
    }

    #[test]
    fn scheduled_slack_propagates_as_frame_deadline() {
        // a paced source with slack stamps each delivered frame with the
        // absolute instant it would have been shed at ingest (due +
        // slack); flood sources carry no deadline, and every in-process
        // source defaults to the always-admitted realtime class
        let paced = Source {
            interval: Some(Duration::from_micros(100)),
            slack: Some(Duration::from_millis(50)),
            ..Source::flood("paced", frames(0, 3))
        };
        let seen = Mutex::new(Vec::<(Option<Instant>, QosClass)>::new());
        let t0 = Instant::now();
        run_ingest(vec![paced], 1, &|f: Frame| {
            lock_unpoisoned(&seen).push((f.deadline, f.qos));
            true
        });
        let seen = lock_unpoisoned(&seen);
        assert_eq!(seen.len(), 3);
        for (i, (deadline, qos)) in seen.iter().enumerate() {
            assert_eq!(*qos, QosClass::Realtime);
            let d = deadline.unwrap_or_else(|| {
                panic!("paced frame {i} lost its deadline")
            });
            // due_i + slack is ≥ pool start + slack; generous upper bound
            assert!(d >= t0 + Duration::from_millis(50));
            assert!(d <= t0 + Duration::from_secs(5));
        }
        let flood = Source::flood("flood", frames(0, 2));
        let bare = Mutex::new(Vec::<Option<Instant>>::new());
        run_ingest(vec![flood], 1, &|f: Frame| {
            lock_unpoisoned(&bare).push(f.deadline);
            true
        });
        assert!(lock_unpoisoned(&bare).iter().all(Option::is_none));
    }
}

/// Exhaustive model check of the ingest shutdown barrier (`./ci.sh
/// --loom`). loom models only `'static` spawns, so this test drives the
/// REAL `produce()` loop from plain loom threads instead of going
/// through `run_ingest`'s `thread::scope` (which stays std — see
/// `crate::sync` docs); the protocol under test — K producers racing a
/// shared admitting sink, reports read only after every join — is
/// identical, and the conservation contract is re-asserted after the
/// barrier exactly as `run_ingest` asserts it.
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;
    use crate::sync::{lock_unpoisoned, Arc, Mutex};

    #[test]
    fn loom_ingest_barrier_conserves_across_producers() {
        let mut b = loom::model::Builder::new();
        b.preemption_bound = Some(3);
        b.check(|| {
            // a sink with room for exactly one frame: the two producers
            // race for it, the loser must book backpressure — in every
            // interleaving delivered totals 1 and nothing leaks
            let admitted = Arc::new(Mutex::new(0usize));
            let a = Arc::clone(&admitted);
            let sink = Arc::new(move |_f: Frame| {
                let mut g = lock_unpoisoned(&a);
                if *g < 1 {
                    *g += 1;
                    true
                } else {
                    false
                }
            });
            let start = Instant::now();
            let handles: Vec<_> = (0..2)
                .map(|p| {
                    let sink = Arc::clone(&sink);
                    let curs = vec![Cursor::new(
                        p,
                        Source::flood(
                            &format!("s{p}"),
                            vec![(p as u64, Tensor::full(vec![1, 1, 1, 1], 0.0))],
                        ),
                    )];
                    thread::spawn(move || produce(curs, start, &*sink))
                })
                .collect();
            // the barrier: reports exist only after both joins
            let reports: Vec<SourceReport> = handles
                .into_iter()
                .flat_map(|h| h.join().unwrap())
                .map(|(_, r)| r)
                .collect();
            let mut delivered = 0;
            for r in &reports {
                assert_eq!(
                    r.delivered + r.dropped(),
                    r.offered,
                    "source {} leaks frames",
                    r.name
                );
                delivered += r.delivered;
            }
            assert_eq!(delivered, 1, "sink admitted exactly one frame");
            assert_eq!(*lock_unpoisoned(&admitted), 1);
        });
    }
}
