//! PCG32 deterministic RNG (O'Neill 2014). All randomness in the system —
//! dataset synthesis, weight init fallback, GA, property tests — flows
//! through this so every experiment is reproducible from a seed.

#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const MUL: u64 = 6364136223846793005;

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut r = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        r.next_u32();
        r.state = r.state.wrapping_add(seed);
        r.next_u32();
        r
    }

    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MUL).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        let bound = bound as u32;
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as usize
    }

    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn gauss(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len())]
    }

    /// A fresh, decorrelated child generator (for per-thread use).
    pub fn split(&mut self) -> Pcg32 {
        Pcg32::new(self.next_u64(), self.next_u64() | 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seed(42);
        let mut b = Pcg32::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::seed(1);
        let mut b = Pcg32::seed(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seed(7);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::seed(9);
        let mut seen = [false; 7];
        for _ in 0..500 {
            let x = r.below(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Pcg32::seed(11);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.1, "var {}", var);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seed(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
