//! Minimal JSON codec — enough for `artifacts/manifest.json` and for
//! exporting benchmark results. Supports the full JSON value grammar
//! (objects, arrays, strings with escapes, numbers, bools, null).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
    /// `[1,2,3]` -> `vec![1,2,3]` for shape lists in the manifest.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<_>>>()
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }
    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }
    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }
    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

// --------------------------------------------------------------- writer ---

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{}", b),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{}", n)
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}", v)?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{}", v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{}", c)?,
        }
    }
    write!(f, "\"")
}

/// Convenience constructors for building result exports.
pub fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_usize(), Some(2));
        assert!(j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().is_null());
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":[{"batch":1,"inputs":[[1,16,16,1],[3,3,1,8],[8]],"name":"x"}],"version":1}"#;
        let j = Json::parse(src).unwrap();
        let printed = j.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), j);
    }

    #[test]
    fn shape_vec() {
        let j = Json::parse("[1,16,16,1]").unwrap();
        assert_eq!(j.as_usize_vec().unwrap(), vec![1, 16, 16, 1]);
    }

    #[test]
    fn escaped_output() {
        let j = Json::Str("a\"b\\c\n".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\n""#);
    }
}
