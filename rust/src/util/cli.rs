//! Tiny CLI argument parser: `antler <subcommand> [--key value] [--flag]`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut out = Args::default();
        let argv: Vec<String> = iter.into_iter().collect();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = argv("bench fig9 --device msp430 --steps 200 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["fig9"]);
        assert_eq!(a.get("device"), Some("msp430"));
        assert_eq!(a.usize("steps", 0), 200);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn eq_style_options() {
        let a = argv("serve --rate=25 --seed=7");
        assert_eq!(a.usize("rate", 0), 25);
        assert_eq!(a.u64("seed", 0), 7);
    }

    #[test]
    fn defaults() {
        let a = argv("train");
        assert_eq!(a.usize("steps", 300), 300);
        assert_eq!(a.get_or("arch", "cnn5"), "cnn5");
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn trailing_flag() {
        let a = argv("x --fast");
        assert!(a.flag("fast"));
    }
}
