//! Tiny CLI argument parser: `antler <subcommand> [--key value] [--flag]`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut out = Args::default();
        let argv: Vec<String> = iter.into_iter().collect();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Strict numeric accessor: absent → default, present-but-malformed
    /// → an error naming the flag. The lenient [`Args::usize`] silently
    /// swallowed typos into the default (`--batch 1O` served with
    /// batch 1 and nobody noticed); config-shaped flags go through this
    /// instead so a typo is a loud exit, not a silent misconfiguration.
    pub fn usize_strict(
        &self,
        key: &str,
        default: usize,
    ) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                format!("--{key} wants an unsigned integer, got {v:?}")
            }),
        }
    }
}

/// `--batch B|auto`: `Ok(None)` selects adaptive sizing, `Ok(Some(b))`
/// a fixed batch. Malformed values are an error naming the flag —
/// `"1O".parse().unwrap_or(1)` used to demote a typo'd batch to 1
/// silently.
pub fn parse_batch_arg(s: &str) -> Result<Option<usize>, String> {
    if s == "auto" {
        return Ok(None);
    }
    s.parse().map(Some).map_err(|_| {
        format!("--batch wants a frame count or 'auto', got {s:?}")
    })
}

/// `--precedence a>b,c>d`: every pair must parse. The old
/// `filter_map(.. parse().ok()?)` silently DROPPED malformed pairs —
/// a typo'd constraint vanished and the solver happily returned an
/// order violating what the user asked for.
pub fn parse_precedence(spec: &str) -> Result<Vec<(usize, usize)>, String> {
    spec.split(',')
        .map(|pair| {
            let (a, b) = pair.split_once('>').ok_or_else(|| {
                format!("--precedence pair {pair:?} wants the form a>b")
            })?;
            let a = a.parse().map_err(|_| {
                format!("--precedence node {a:?} is not a task index")
            })?;
            let b = b.parse().map_err(|_| {
                format!("--precedence node {b:?} is not a task index")
            })?;
            Ok((a, b))
        })
        .collect()
}

/// `--qos on|off` (and `--prefetch`-style switches): strict two-state
/// parse, error names the flag.
pub fn parse_switch(key: &str, s: &str) -> Result<bool, String> {
    match s {
        "on" => Ok(true),
        "off" => Ok(false),
        other => Err(format!("--{key} wants on|off, got {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = argv("bench fig9 --device msp430 --steps 200 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["fig9"]);
        assert_eq!(a.get("device"), Some("msp430"));
        assert_eq!(a.usize("steps", 0), 200);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn eq_style_options() {
        let a = argv("serve --rate=25 --seed=7");
        assert_eq!(a.usize("rate", 0), 25);
        assert_eq!(a.u64("seed", 0), 7);
    }

    #[test]
    fn defaults() {
        let a = argv("train");
        assert_eq!(a.usize("steps", 300), 300);
        assert_eq!(a.get_or("arch", "cnn5"), "cnn5");
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn trailing_flag() {
        let a = argv("x --fast");
        assert!(a.flag("fast"));
    }

    #[test]
    fn malformed_batch_errors_naming_the_flag() {
        // the bug: "1O" (letter O) used to become batch=1 silently
        let err = parse_batch_arg("1O").unwrap_err();
        assert!(err.contains("--batch"), "error must name the flag: {err}");
        assert_eq!(parse_batch_arg("auto"), Ok(None));
        assert_eq!(parse_batch_arg("8"), Ok(Some(8)));
        assert!(parse_batch_arg("-3").is_err());
        assert!(parse_batch_arg("").is_err());
    }

    #[test]
    fn malformed_precedence_errors_naming_the_flag() {
        // the bug: a malformed pair was silently dropped from the
        // constraint set instead of rejected
        for bad in ["1>2,3-4", "a>2", "1>2,", ">", "1>b"] {
            let err = parse_precedence(bad).unwrap_err();
            assert!(
                err.contains("--precedence"),
                "error must name the flag for {bad:?}: {err}"
            );
        }
        assert_eq!(parse_precedence("1>2,0>3"), Ok(vec![(1, 2), (0, 3)]));
    }

    #[test]
    fn malformed_numeric_flags_error_naming_the_flag() {
        let a = argv("serve --shards 2x --frames 10");
        let err = a.usize_strict("shards", 1).unwrap_err();
        assert!(err.contains("--shards"), "error must name the flag: {err}");
        assert_eq!(a.usize_strict("frames", 100), Ok(10));
        // absent flag keeps its default
        assert_eq!(a.usize_strict("queue-depth", 64), Ok(64));
    }

    #[test]
    fn malformed_switch_errors_naming_the_flag() {
        let err = parse_switch("qos", "maybe").unwrap_err();
        assert!(err.contains("--qos"), "error must name the flag: {err}");
        assert_eq!(parse_switch("qos", "on"), Ok(true));
        assert_eq!(parse_switch("qos", "off"), Ok(false));
    }
}
