//! Hand-rolled substrates. The offline crate mirror for this environment
//! carries only `xla` + its transitive deps, so the usual serde / rand /
//! clap / criterion stack is re-implemented here at the size this project
//! needs (see DESIGN.md, Substitutions).

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
