//! Statistics helpers: the correlation coefficients the paper's affinity
//! analysis is built on (§3.1: inverse Pearson over sample pairs, Spearman
//! over task-pair profiles), plus summary stats for the bench harness.

/// Pearson correlation coefficient of two equal-length vectors.
/// Returns 0.0 when either vector has zero variance (degenerate case).
pub fn pearson(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    if a.is_empty() {
        return 0.0;
    }
    let ma = a.iter().map(|&x| x as f64).sum::<f64>() / n;
    let mb = b.iter().map(|&x| x as f64).sum::<f64>() / n;
    let (mut cov, mut va, mut vb) = (0.0f64, 0.0f64, 0.0f64);
    for i in 0..a.len() {
        let da = a[i] as f64 - ma;
        let db = b[i] as f64 - mb;
        cov += da * db;
        va += da * da;
        vb += db * db;
    }
    if va <= 0.0 || vb <= 0.0 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// Fractional ranks with ties averaged (the standard Spearman convention).
pub fn ranks(v: &[f64]) -> Vec<f64> {
    let n = v.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| v[i].partial_cmp(&v[j]).unwrap_or(std::cmp::Ordering::Equal));
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && v[idx[j + 1]] == v[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

/// Spearman's rank correlation coefficient.
pub fn spearman(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let ra: Vec<f32> = ranks(a).iter().map(|&x| x as f32).collect();
    let rb: Vec<f32> = ranks(b).iter().map(|&x| x as f32).collect();
    pearson(&ra, &rb)
}

pub fn mean(v: &[f64]) -> f64 {
    if v.is_empty() {
        0.0
    } else {
        v.iter().sum::<f64>() / v.len() as f64
    }
}

pub fn stddev(v: &[f64]) -> f64 {
    if v.len() < 2 {
        return 0.0;
    }
    let m = mean(v);
    (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (v.len() - 1) as f64).sqrt()
}

/// Percentile by linear interpolation on a sorted copy; p in [0, 100].
pub fn percentile(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let mut s = v.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let pos = (p / 100.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (s[hi] - s[lo]) * (pos - lo as f64)
    }
}

/// Min-max normalization to [0, 1]; constant vectors map to 0.5.
pub fn normalize(v: &[f64]) -> Vec<f64> {
    let lo = v.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !(hi > lo) {
        return vec![0.5; v.len()];
    }
    v.iter().map(|x| (x - lo) / (hi - lo)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_perfect() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [2.0f32, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        let c = [8.0f32, 6.0, 4.0, 2.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_degenerate() {
        assert_eq!(pearson(&[1.0; 4], &[1.0, 2.0, 3.0, 4.0]), 0.0);
        assert_eq!(pearson(&[], &[]), 0.0);
    }

    #[test]
    fn ranks_with_ties() {
        assert_eq!(ranks(&[10.0, 20.0, 20.0, 30.0]), vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn spearman_monotone_invariance() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [1.0, 8.0, 27.0, 64.0, 125.0]; // a^3: nonlinear but monotone
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_known_value() {
        // classic example: ranks differ by one swap
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [1.0, 2.0, 4.0, 3.0];
        // d = [0,0,1,1]; rho = 1 - 6*2/(4*15) = 0.8
        assert!((spearman(&a, &b) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn percentile_interp() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn normalize_bounds() {
        let v = normalize(&[2.0, 4.0, 6.0]);
        assert_eq!(v, vec![0.0, 0.5, 1.0]);
        assert_eq!(normalize(&[3.0, 3.0]), vec![0.5, 0.5]);
    }
}
