//! Task affinity (§3.1): how similar two tasks' learned representations
//! are at each branch point.
//!
//! Step 1 — per task, at each branch point, profile K samples: the K×K
//! matrix of pairwise *dissimilarities* (inverse Pearson) between the
//! samples' activation vectors, flattened (upper triangle) into a
//! representation profile.
//!
//! Step 2 — for every task pair and branch point, Spearman's rank
//! correlation between the two profiles gives the affinity score
//! S[ρ][i][j], a D×n×n tensor.

use crate::model::Tensor;
use crate::util::rng::Pcg32;
use crate::util::stats;

/// Affinity scores S[ρ][i][j] ∈ [-1, 1]; symmetric in (i, j), diag = 1.
#[derive(Debug, Clone)]
pub struct AffinityTensor {
    pub d: usize,
    pub n: usize,
    s: Vec<f64>,
}

impl AffinityTensor {
    pub fn new(d: usize, n: usize) -> AffinityTensor {
        let mut t = AffinityTensor { d, n, s: vec![0.0; d * n * n] };
        for rho in 0..d {
            for i in 0..n {
                *t.at_mut(rho, i, i) = 1.0;
            }
        }
        t
    }

    pub fn at(&self, rho: usize, i: usize, j: usize) -> f64 {
        self.s[(rho * self.n + i) * self.n + j]
    }

    pub fn at_mut(&mut self, rho: usize, i: usize, j: usize) -> &mut f64 {
        &mut self.s[(rho * self.n + i) * self.n + j]
    }

    pub fn set_sym(&mut self, rho: usize, i: usize, j: usize, v: f64) {
        *self.at_mut(rho, i, j) = v;
        *self.at_mut(rho, j, i) = v;
    }

    /// Dissimilarity 1 - S, clamped to [0, 2].
    pub fn dissimilarity(&self, rho: usize, i: usize, j: usize) -> f64 {
        (1.0 - self.at(rho, i, j)).clamp(0.0, 2.0)
    }
}

/// Step 1: representation profile of one task at one branch point.
/// `acts` holds the task's activation tensor for K profiling samples at
/// that branch point, shape [K, features...]. Output: flattened upper
/// triangle (i<j) of the K×K inverse-Pearson dissimilarity matrix.
pub fn representation_profile(acts: &Tensor) -> Vec<f64> {
    let k = acts.shape[0];
    let feat: usize = acts.shape[1..].iter().product();
    let row = |i: usize| &acts.data[i * feat..(i + 1) * feat];
    let mut out = Vec::with_capacity(k * (k - 1) / 2);
    for i in 0..k {
        for j in (i + 1)..k {
            out.push(1.0 - stats::pearson(row(i), row(j)));
        }
    }
    out
}

/// Step 2: assemble the affinity tensor from per-task, per-branch-point
/// profiles. `profiles[task][rho]` is the output of
/// [`representation_profile`].
pub fn affinity_from_profiles(profiles: &[Vec<Vec<f64>>]) -> AffinityTensor {
    let n = profiles.len();
    assert!(n > 0);
    let d = profiles[0].len();
    let mut t = AffinityTensor::new(d, n);
    for rho in 0..d {
        for i in 0..n {
            for j in (i + 1)..n {
                let s = stats::spearman(&profiles[i][rho], &profiles[j][rho]);
                t.set_sym(rho, i, j, s);
            }
        }
    }
    t
}

/// Synthetic affinity for algorithm-level experiments and tests: tasks get
/// latent unit vectors; affinity at branch point ρ is their cosine pushed
/// toward 1 for early branch points (early layers encode shared basic
/// patterns — §2.2) and toward the raw cosine for late ones.
pub fn synthetic_affinity(n: usize, d: usize, rng: &mut Pcg32) -> AffinityTensor {
    let dim = 8;
    let latents: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            let v: Vec<f64> = (0..dim).map(|_| rng.gauss() as f64).collect();
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            v.into_iter().map(|x| x / norm).collect()
        })
        .collect();
    let mut t = AffinityTensor::new(d, n);
    for rho in 0..d {
        // depth factor: 0 at the first branch point, 1 at the last
        let depth = if d == 1 { 1.0 } else { rho as f64 / (d - 1) as f64 };
        for i in 0..n {
            for j in (i + 1)..n {
                let cos: f64 =
                    latents[i].iter().zip(&latents[j]).map(|(a, b)| a * b).sum();
                // early layers: high affinity for everyone; later: task-specific
                let s = (1.0 - depth) * (0.75 + 0.25 * cos) + depth * cos;
                t.set_sym(rho, i, j, s.clamp(-1.0, 1.0));
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor(shape: Vec<usize>, f: impl Fn(usize) -> f32) -> Tensor {
        let n = shape.iter().product();
        Tensor::new(shape, (0..n).map(f).collect())
    }

    #[test]
    fn profile_length_is_upper_triangle() {
        let acts = tensor(vec![5, 7], |i| (i as f32).sin());
        assert_eq!(representation_profile(&acts).len(), 10);
    }

    #[test]
    fn identical_tasks_have_affinity_one() {
        let acts = tensor(vec![4, 6], |i| (i * i % 17) as f32);
        let p = representation_profile(&acts);
        let t = affinity_from_profiles(&[vec![p.clone()], vec![p]]);
        assert!((t.at(0, 0, 1) - 1.0).abs() < 1e-9);
        assert!((t.at(0, 1, 0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn diagonal_is_one_and_symmetric() {
        let mut rng = Pcg32::seed(3);
        let t = synthetic_affinity(6, 3, &mut rng);
        for rho in 0..3 {
            for i in 0..6 {
                assert!((t.at(rho, i, i) - 1.0).abs() < 1e-12);
                for j in 0..6 {
                    assert_eq!(t.at(rho, i, j), t.at(rho, j, i));
                }
            }
        }
    }

    #[test]
    fn early_branch_points_show_higher_affinity() {
        let mut rng = Pcg32::seed(5);
        let t = synthetic_affinity(8, 3, &mut rng);
        let avg = |rho: usize| {
            let mut s = 0.0;
            let mut c = 0;
            for i in 0..8 {
                for j in (i + 1)..8 {
                    s += t.at(rho, i, j);
                    c += 1;
                }
            }
            s / c as f64
        };
        assert!(avg(0) > avg(2), "early {} late {}", avg(0), avg(2));
    }

    #[test]
    fn dissimilarity_clamped() {
        let mut t = AffinityTensor::new(1, 2);
        t.set_sym(0, 0, 1, -1.0);
        assert_eq!(t.dissimilarity(0, 0, 1), 2.0);
        t.set_sym(0, 0, 1, 1.0);
        assert_eq!(t.dissimilarity(0, 0, 1), 0.0);
    }

    #[test]
    fn opposite_profiles_low_affinity() {
        // profiles that rank sample pairs in opposite order -> spearman -1
        let p1 = vec![vec![1.0, 2.0, 3.0, 4.0]];
        let p2 = vec![vec![4.0, 3.0, 2.0, 1.0]];
        let t = affinity_from_profiles(&[p1, p2]);
        assert!((t.at(0, 0, 1) + 1.0).abs() < 1e-9);
    }
}
