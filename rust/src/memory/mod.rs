//! Memory-hierarchy execution simulator (§2.3): the block-granular
//! runtime semantics of Antler on a memory-constrained device.
//!
//! RAM is statically allocated as one slot per segment of the common
//! architecture plus one activation buffer per branch point. Executing a
//! task walks its root→leaf path: a segment whose *output activation* is
//! cached for the current sample is skipped entirely; otherwise its weight
//! block is loaded from external memory unless already resident, then the
//! segment executes. The same state machine drives both the cost
//! simulator here (figures 9–11/15) and the real PJRT executor
//! (`coordinator::executor`), so the cost model and the live system share
//! their notion of "what work happens".

use crate::device::{Cost, Device};
use crate::model::ArchSpec;
use crate::taskgraph::TaskGraph;

pub mod tier;

/// Runtime residency/cache state for one device+graph instance.
#[derive(Debug, Clone)]
pub struct ExecSim<'a> {
    pub device: &'a Device,
    pub arch: &'a ArchSpec,
    pub graph: &'a TaskGraph,
    pub ncls: &'a [usize],
    /// Weight block resident in each segment slot: group id of that
    /// segment's partition, or None when the slot is cold.
    resident: Vec<Option<usize>>,
    /// Activation cached at each segment output: (sample id, group id).
    act_cache: Vec<Option<(u64, usize)>>,
    /// When true, all weights are RAM-resident (in-memory baselines:
    /// NWV / YONO) and loads never happen.
    pub all_resident: bool,
}

/// What happened for one segment of one task execution — the real
/// executor consumes this plan to decide which PJRT calls to make.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegmentAction {
    /// Output activation cache hit: nothing to do.
    CachedActivation,
    /// Weights resident, execute only.
    Execute,
    /// Load weights then execute.
    LoadAndExecute,
}

impl<'a> ExecSim<'a> {
    pub fn new(
        device: &'a Device,
        arch: &'a ArchSpec,
        graph: &'a TaskGraph,
        ncls: &'a [usize],
    ) -> ExecSim<'a> {
        assert_eq!(ncls.len(), graph.n_tasks);
        ExecSim {
            device,
            arch,
            graph,
            ncls,
            resident: vec![None; graph.n_segments()],
            act_cache: vec![None; graph.n_segments()],
            all_resident: false,
        }
    }

    pub fn reset(&mut self) {
        self.resident = vec![None; self.graph.n_segments()];
        self.act_cache = vec![None; self.graph.n_segments()];
    }

    fn segment_elems(&self, s: usize) -> u64 {
        self.graph
            .segment_layers(self.arch, s)
            .map(|l| self.arch.layers[l].out_elems() as u64)
            .sum()
    }

    /// Snapshot of (resident blocks, activation cache) — lets the live
    /// executor persist state across its own lifetime.
    pub fn snapshot(&self) -> (Vec<Option<usize>>, Vec<Option<(u64, usize)>>) {
        (self.resident.clone(), self.act_cache.clone())
    }

    /// Restore a snapshot taken from an identically-shaped sim.
    pub fn restore(
        &mut self,
        resident: Vec<Option<usize>>,
        act_cache: Vec<Option<(u64, usize)>>,
    ) {
        assert_eq!(resident.len(), self.graph.n_segments());
        assert_eq!(act_cache.len(), self.graph.n_segments());
        self.resident = resident;
        self.act_cache = act_cache;
    }

    /// Plan + cost in one step (what the live executor consumes).
    pub fn plan_and_cost(&mut self, sample: u64, task: usize) -> (Vec<SegmentAction>, Cost) {
        let snap = self.snapshot();
        let plan = self.plan_task(sample, task);
        self.restore(snap.0, snap.1);
        let cost = self.run_task(sample, task);
        (plan, cost)
    }

    /// Plan the segment actions for executing `task` on `sample`,
    /// updating residency/cache state, and return the per-segment actions.
    pub fn plan_task(&mut self, sample: u64, task: usize) -> Vec<SegmentAction> {
        let mut plan = Vec::with_capacity(self.graph.n_segments());
        for s in 0..self.graph.n_segments() {
            let group = self.graph.group_of(s, task);
            if self.act_cache[s] == Some((sample, group)) {
                plan.push(SegmentAction::CachedActivation);
                continue;
            }
            let action = if self.all_resident || self.resident[s] == Some(group) {
                SegmentAction::Execute
            } else {
                SegmentAction::LoadAndExecute
            };
            self.resident[s] = Some(group);
            self.act_cache[s] = Some((sample, group));
            plan.push(action);
        }
        plan
    }

    /// Cost of executing `task` on `sample` given current state.
    pub fn run_task(&mut self, sample: u64, task: usize) -> Cost {
        let plan = self.plan_task(sample, task);
        let mut cost = Cost::default();
        for (s, action) in plan.iter().enumerate() {
            match action {
                SegmentAction::CachedActivation => {}
                SegmentAction::Execute => {
                    cost.add(self.device.exec_cost(
                        self.graph.segment_macs(self.arch, s),
                        self.segment_elems(s),
                    ));
                }
                SegmentAction::LoadAndExecute => {
                    cost.add(self.device.load_cost(self.graph.segment_bytes(
                        self.arch,
                        s,
                        task,
                        self.ncls,
                    )));
                    cost.add(self.device.exec_cost(
                        self.graph.segment_macs(self.arch, s),
                        self.segment_elems(s),
                    ));
                }
            }
        }
        cost
    }

    /// Cost of one full round: all tasks, in `order`, on one sample.
    pub fn run_round(&mut self, sample: u64, order: &[usize]) -> Cost {
        let mut cost = Cost::default();
        for &t in order {
            cost.add(self.run_task(sample, t));
        }
        cost
    }

    /// Steady-state per-round cost: run `rounds` rounds on distinct
    /// samples (activation caches invalidate across samples, weight
    /// residency persists) and average, excluding the cold first round.
    pub fn steady_round_cost(&mut self, order: &[usize], rounds: usize) -> Cost {
        self.reset();
        let _cold = self.run_round(0, order);
        let mut acc = Cost::default();
        let rounds = rounds.max(1);
        for r in 1..=rounds {
            acc.add(self.run_round(r as u64, order));
        }
        acc.scaled(1.0 / rounds as f64)
    }
}

/// The paper's switching cost matrix (Eq. 3): `c[i][j]` is the extra cost
/// of running τ_j right after τ_i on the same sample — exactly the
/// non-shared suffix of τ_j's path (shared prefix is both weight-resident
/// and activation-cached).
pub fn cost_matrix(
    device: &Device,
    arch: &ArchSpec,
    graph: &TaskGraph,
    ncls: &[usize],
    energy: bool,
) -> Vec<Vec<f64>> {
    let n = graph.n_tasks;
    let mut c = vec![vec![0.0; n]; n];
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            let prefix = graph.shared_prefix(i, j);
            let mut cost = Cost::default();
            for s in prefix..graph.n_segments() {
                cost.add(device.load_cost(graph.segment_bytes(arch, s, j, ncls)));
                let elems: u64 = graph
                    .segment_layers(arch, s)
                    .map(|l| arch.layers[l].out_elems() as u64)
                    .sum();
                cost.add(device.exec_cost(graph.segment_macs(arch, s), elems));
            }
            c[i][j] = if energy { cost.energy() } else { cost.time() };
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskgraph::partition::Partition;

    const TINY: &str = r#"{
      "version": 1,
      "archs": {"cnn5": {"input": [16,16,1], "ncls": [2],
        "layers": [
          {"kind":"conv_pool","cfg":{"kh":3,"kw":3,"cin":1,"cout":8},"in":[16,16,1],"out":[8,8,8],"macs_per_sample":18432},
          {"kind":"conv_pool","cfg":{"kh":3,"kw":3,"cin":8,"cout":16},"in":[8,8,8],"out":[4,4,16],"macs_per_sample":73728},
          {"kind":"dense","cfg":{"din":256,"dout":64},"in":[4,4,16],"out":[64],"macs_per_sample":16384},
          {"kind":"dense","cfg":{"din":64,"dout":32},"in":[64],"out":[32],"macs_per_sample":2048},
          {"kind":"logits","cfg":{"din":32,"dout":0},"in":[32],"out":[2],"macs_per_sample":64}
        ]}},
      "entries": []
    }"#;

    fn arch() -> ArchSpec {
        crate::model::manifest::Manifest::from_json(
            std::path::PathBuf::from("/tmp"),
            &crate::util::json::Json::parse(TINY).unwrap(),
        )
        .unwrap()
        .arch("cnn5")
        .unwrap()
        .clone()
    }

    fn graph3() -> TaskGraph {
        // tasks 0,1 share two segments; task 2 splits after segment 0
        TaskGraph::new(
            3,
            vec![1, 3, 4],
            vec![
                Partition(vec![0, 0, 0]),
                Partition(vec![0, 0, 1]),
                Partition(vec![0, 1, 2]),
                Partition::singletons(3),
            ],
        )
        .unwrap()
    }

    #[test]
    fn second_task_skips_shared_prefix() {
        let dev = Device::msp430();
        let arch = arch();
        let g = graph3();
        let ncls = vec![2; 3];
        let mut sim = ExecSim::new(&dev, &arch, &g, &ncls);
        let _ = sim.run_task(0, 0);
        let plan = sim.plan_task(0, 1);
        // segments 0,1 shared with task 0 -> cached activations
        assert_eq!(plan[0], SegmentAction::CachedActivation);
        assert_eq!(plan[1], SegmentAction::CachedActivation);
        assert_eq!(plan[2], SegmentAction::LoadAndExecute);
        assert_eq!(plan[3], SegmentAction::LoadAndExecute);
    }

    #[test]
    fn rerunning_same_task_same_sample_is_free() {
        let dev = Device::msp430();
        let arch = arch();
        let g = graph3();
        let ncls = vec![2; 3];
        let mut sim = ExecSim::new(&dev, &arch, &g, &ncls);
        let _ = sim.run_task(7, 2);
        let again = sim.run_task(7, 2);
        assert_eq!(again.time(), 0.0);
    }

    #[test]
    fn new_sample_invalidates_activations_but_not_weights() {
        let dev = Device::msp430();
        let arch = arch();
        let g = graph3();
        let ncls = vec![2; 3];
        let mut sim = ExecSim::new(&dev, &arch, &g, &ncls);
        let _ = sim.run_task(0, 0);
        let plan = sim.plan_task(1, 0); // same task, new sample
        assert!(plan.iter().all(|&a| a == SegmentAction::Execute));
    }

    #[test]
    fn all_resident_mode_never_loads() {
        let dev = Device::stm32h747();
        let arch = arch();
        let g = TaskGraph::disjoint(3, vec![1, 3, 4]);
        let ncls = vec![2; 3];
        let mut sim = ExecSim::new(&dev, &arch, &g, &ncls);
        sim.all_resident = true;
        let c = sim.run_round(0, &[0, 1, 2]);
        assert_eq!(c.load_s, 0.0);
        assert!(c.exec_s > 0.0);
    }

    #[test]
    fn shared_graph_round_cheaper_than_disjoint() {
        let dev = Device::msp430();
        let arch = arch();
        let ncls = vec![2; 3];
        let shared = TaskGraph::shared(3, vec![1, 3, 4]);
        let disjoint = TaskGraph::disjoint(3, vec![1, 3, 4]);
        let mut s1 = ExecSim::new(&dev, &arch, &shared, &ncls);
        let mut s2 = ExecSim::new(&dev, &arch, &disjoint, &ncls);
        let c1 = s1.steady_round_cost(&[0, 1, 2], 4);
        let c2 = s2.steady_round_cost(&[0, 1, 2], 4);
        assert!(c1.time() < c2.time());
        assert!(c1.energy() < c2.energy());
    }

    #[test]
    fn cost_matrix_reflects_shared_prefix() {
        let dev = Device::msp430();
        let arch = arch();
        let g = graph3();
        let ncls = vec![2; 3];
        let c = cost_matrix(&dev, &arch, &g, &ncls, false);
        // switching 0->1 (share 2 segments) cheaper than 0->2 (share 1)
        assert!(c[0][1] < c[0][2], "{} vs {}", c[0][1], c[0][2]);
        assert_eq!(c[0][0], 0.0);
        // symmetric here (equal class counts)
        assert!((c[1][2] - c[2][1]).abs() < 1e-12);
    }

    #[test]
    fn cost_matrix_matches_simulator_increments() {
        // c[i][j] must equal the simulator's cost of j right after i
        let dev = Device::msp430();
        let arch = arch();
        let g = graph3();
        let ncls = vec![2; 3];
        let c = cost_matrix(&dev, &arch, &g, &ncls, false);
        for i in 0..3 {
            for j in 0..3 {
                if i == j {
                    continue;
                }
                let mut sim = ExecSim::new(&dev, &arch, &g, &ncls);
                sim.reset();
                let _ = sim.run_task(0, i);
                let got = sim.run_task(0, j).time();
                assert!(
                    (got - c[i][j]).abs() < 1e-12,
                    "i={} j={} sim={} matrix={}",
                    i,
                    j,
                    got,
                    c[i][j]
                );
            }
        }
    }

    #[test]
    fn steady_state_fully_shared_graph_never_reloads() {
        let dev = Device::msp430();
        let arch = arch();
        let g = TaskGraph::shared(3, vec![1, 3, 4]);
        let ncls = vec![2; 3];
        let mut sim = ExecSim::new(&dev, &arch, &g, &ncls);
        let steady = sim.steady_round_cost(&[0, 1, 2], 3);
        // only the private heads swap, and each head slot cycles through
        // all three tasks every round -> head loads remain, but the shared
        // trunk (everything except the head) is never reloaded
        let head_bytes = g.segment_bytes(&arch, 3, 0, &ncls);
        let expect_load = 3.0 * dev.load_time(head_bytes);
        assert!((steady.load_s - expect_load).abs() < 1e-12);
    }

    #[test]
    fn steady_state_disjoint_reloads_everything_but_last() {
        let dev = Device::msp430();
        let arch = arch();
        let g = TaskGraph::disjoint(3, vec![1, 3, 4]);
        let ncls = vec![2; 3];
        let mut sim = ExecSim::new(&dev, &arch, &g, &ncls);
        let steady = sim.steady_round_cost(&[0, 1, 2], 4);
        // each round all three tasks must reload their whole network
        // (slots held by the previous task) — the Vanilla pathology
        let net_bytes: usize =
            (0..4).map(|s| g.segment_bytes(&arch, s, 0, &ncls)).sum();
        let expect = 3.0 * dev.load_time(net_bytes);
        assert!((steady.load_s - expect).abs() < 1e-9,
                "{} vs {}", steady.load_s, expect);
    }
}
