//! Two-tier weight memory: a bounded fast tier (SRAM-class) over the
//! slow external tier (FRAM/eFlash), priced through the same
//! [`Device`](crate::device::Device) byte-rate model as the flat
//! residency simulation in [`super::ExecSim`].
//!
//! The tier is a *cost and accounting* model layered under the block
//! executor: weights are always fetched from the canonical
//! `GraphWeights` store, so enabling the tier can never change a
//! prediction — only where load time lands (demand stall vs overlapped
//! prefetch) and which blocks get evicted. The parity property test in
//! `tests/props.rs` pins that invariant at every capacity.
//!
//! Model, per shard (single simulated DMA engine, one clock):
//!   * `prefetch_round` pipelines loads for the round's block sequence
//!     in execution order: each load starts when the DMA engine frees
//!     up (`ready_at = max(now, dma_free) + bytes/read_bps`), so later
//!     segments' loads overlap earlier segments' compute.
//!   * `touch` charges the *visible* stall: zero for a settled
//!     prefetched block, `ready_at - now` for one still in flight, and
//!     the full serialized load for a demand miss.
//!   * `advance_exec` moves the clock through compute, settling
//!     in-flight loads that complete under it.
//!
//! Eviction follows the DTR-style `evict_single`/`allocate_buffer`
//! loop (SNIPPETS.md §1): evict the lowest-scored victim until the
//! incoming block fits, and if nothing is evictable, *stream* the block
//! through without inserting it — capacity 0 degenerates to pure
//! streaming and an adversarial thrash pattern can never livelock. The
//! affinity policy scores victims by
//! `(upcoming uses this round, sharers in the task graph, last touch)`
//! lexicographically — blocks shared by many pending tasks are sticky —
//! while [`EvictPolicy::Lru`] keeps only the recency term as the
//! measured baseline.
//!
//! Custody is audited by [`TierLedger`](crate::coordinator::audit):
//! every load issued is eventually completed or cancelled, and
//! insertions minus evictions always equals the resident count. Under
//! `debug_assertions` any single-step corruption of those transitions
//! panics (see the 200-seed walk in `coordinator/audit.rs`).

use std::collections::BTreeMap;

use crate::coordinator::audit::TierLedger;
use crate::device::Device;

/// A weight block in the fast tier: one (segment, group) pair, the unit
/// `GraphWeights` stores and `ExecSim` tracks residency for.
pub type BlockId = (usize, usize);

/// Victim-selection policy for the eviction loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictPolicy {
    /// Score by (upcoming uses, task-graph sharers, recency) — the
    /// affinity-aware default.
    Affinity,
    /// Plain least-recently-used — the baseline the unit suite beats.
    Lru,
}

/// Fast-tier configuration, carried from the CLI / `ShardOpts` into
/// each shard's executor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierConfig {
    /// Fast-tier capacity in bytes. `usize::MAX` means unbounded (the
    /// tier still tracks residency and prefetch, but never evicts);
    /// `0` degenerates to streaming every block on every touch.
    pub fast_bytes: usize,
    /// Issue pipelined fast-tier loads for the round's upcoming blocks
    /// before their forward starts.
    pub prefetch: bool,
    pub policy: EvictPolicy,
    /// Slow-tier read bandwidth, bytes/second — `Device::ext_read_bps`.
    pub read_bps: f64,
}

impl TierConfig {
    pub fn new(fast_bytes: usize, prefetch: bool, read_bps: f64) -> TierConfig {
        TierConfig {
            fast_bytes,
            prefetch,
            policy: EvictPolicy::Affinity,
            read_bps,
        }
    }

    /// Configuration priced from a device model's external-read rate.
    pub fn for_device(device: &Device, fast_bytes: usize, prefetch: bool) -> TierConfig {
        TierConfig::new(fast_bytes, prefetch, device.ext_read_bps)
    }
}

/// One step of a round's block sequence, in execution order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RoundStep {
    pub block: BlockId,
    pub bytes: usize,
    /// Tasks sharing this block in the task graph (the affinity reuse
    /// signal: `|{t : group_of(segment, t) == group}|`).
    pub sharers: usize,
}

/// Observable tier statistics, aggregated into `ShardReport`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TierCounters {
    /// Touches served from the fast tier (includes `prefetch_hits`).
    pub hits: u64,
    /// Touches that demand-loaded from the slow tier.
    pub misses: u64,
    /// First touches of a block that a prefetch brought in.
    pub prefetch_hits: u64,
    /// Blocks removed from the fast tier to make room.
    pub evictions: u64,
    /// Prefetch loads issued.
    pub prefetch_issued: u64,
    /// Prefetch loads evicted before first use.
    pub prefetch_cancelled: u64,
    /// Visible load-stall seconds (simulated device time the forward
    /// waited on the slow tier).
    pub stall_s: f64,
    /// Total bytes moved from the slow tier (prefetch + demand).
    pub bytes_loaded: u64,
}

impl TierCounters {
    pub fn merge(&mut self, o: &TierCounters) {
        self.hits += o.hits;
        self.misses += o.misses;
        self.prefetch_hits += o.prefetch_hits;
        self.evictions += o.evictions;
        self.prefetch_issued += o.prefetch_issued;
        self.prefetch_cancelled += o.prefetch_cancelled;
        self.stall_s += o.stall_s;
        self.bytes_loaded += o.bytes_loaded;
    }
}

/// What one touch cost: the visible stall and the bytes whose load
/// energy this touch should be charged for (full block size on the
/// first touch after a load, zero on warm hits).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Touch {
    pub stall_s: f64,
    pub charge_bytes: usize,
}

#[derive(Debug, Clone)]
struct Entry {
    bytes: usize,
    /// Simulated time the block's data is fully in the fast tier.
    ready_at: f64,
    /// Tick of the most recent touch (0 = never touched).
    last_touch: u64,
    /// Brought in by prefetch (vs a demand miss).
    prefetched: bool,
    /// Load completion observed (ledger `complete` recorded).
    settled: bool,
    /// Load energy already attributed to a frame.
    charged: bool,
    sharers: usize,
}

/// The per-shard fast-tier state machine. Single-threaded by design:
/// each shard owns one tier inside its executor, so stall accounting is
/// deterministic. Cross-shard coordination (residency boards, prefetch
/// hints) stays in `coordinator/shard.rs` behind the `crate::sync`
/// facade.
#[derive(Debug)]
pub struct WeightTier {
    pub cfg: TierConfig,
    /// BTreeMap for deterministic iteration order — victim selection
    /// must not depend on hash seeds.
    resident: BTreeMap<BlockId, Entry>,
    used: usize,
    /// Touch clock for recency scoring.
    tick: u64,
    /// Simulated device time, seconds. Monotone across rounds.
    now: f64,
    /// Simulated time the single DMA engine frees up.
    dma_free: f64,
    /// Current round's block sequence in execution order.
    seq: Vec<RoundStep>,
    /// Next unconsumed position in `seq`.
    cursor: usize,
    /// Frames already visible behind this round (injector backlog +
    /// prefetch-signal hints): > 0 keeps this round's blocks sticky.
    backlog_hint: usize,
    pub counters: TierCounters,
    ledger: TierLedger,
}

impl WeightTier {
    pub fn new(cfg: TierConfig) -> WeightTier {
        WeightTier {
            cfg,
            resident: BTreeMap::new(),
            used: 0,
            tick: 0,
            now: 0.0,
            dma_free: 0.0,
            seq: Vec::new(),
            cursor: 0,
            backlog_hint: 0,
            counters: TierCounters::default(),
            ledger: TierLedger::new(),
        }
    }

    /// Bytes currently resident in the fast tier.
    pub fn used_bytes(&self) -> usize {
        self.used
    }

    /// Simulated clock, seconds.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Begin a round: install the block sequence the upcoming forward
    /// will touch (execution order, duplicates meaningful) and the
    /// backlog hint (frames already visible behind this round). Issues
    /// pipelined prefetches when enabled.
    pub fn begin_round(&mut self, seq: Vec<RoundStep>, backlog_hint: usize) {
        self.seq = seq;
        self.cursor = 0;
        self.backlog_hint = backlog_hint;
        if self.cfg.prefetch {
            self.prefetch_round();
        }
        self.reconcile();
    }

    /// Uses of `b` at or after the cursor; with visible backlog, this
    /// round's sequence is assumed to repeat once more.
    fn upcoming_uses(&self, b: BlockId) -> usize {
        let ahead = self.seq[self.cursor.min(self.seq.len())..]
            .iter()
            .filter(|s| s.block == b)
            .count();
        let next_round = if self.backlog_hint > 0 {
            self.seq.iter().filter(|s| s.block == b).count()
        } else {
            0
        };
        ahead + next_round
    }

    /// Pick the eviction victim among resident blocks, or `None` if the
    /// tier is empty. `Affinity` minimizes
    /// `(upcoming_uses, sharers, last_touch)` lexicographically; `Lru`
    /// minimizes `last_touch` alone.
    fn victim(&self, require_unneeded: bool) -> Option<BlockId> {
        self.resident
            .iter()
            .filter_map(|(&b, e)| {
                let upcoming = self.upcoming_uses(b);
                if require_unneeded && upcoming > 0 {
                    return None;
                }
                let key = match self.cfg.policy {
                    EvictPolicy::Affinity => (upcoming, e.sharers, e.last_touch),
                    EvictPolicy::Lru => (0, 0, e.last_touch),
                };
                Some((key, b))
            })
            .min_by_key(|&(key, b)| (key, b))
            .map(|(_, b)| b)
    }

    fn evict(&mut self, b: BlockId) {
        if let Some(e) = self.resident.remove(&b) {
            self.used -= e.bytes;
            self.counters.evictions += 1;
            if e.settled {
                self.ledger.evict();
            } else {
                // an in-flight load is torn down before completing
                self.ledger.cancel();
                if e.prefetched {
                    self.counters.prefetch_cancelled += 1;
                }
            }
        }
    }

    /// DTR-style allocate loop: evict victims until `bytes` fits.
    /// Returns false (stream-through, nothing evicted beyond what
    /// already happened) when the block can never fit or no victim is
    /// available — termination is structural: every iteration removes
    /// one entry, and an empty tier ends the loop.
    fn make_room(&mut self, bytes: usize, require_unneeded: bool) -> bool {
        if bytes > self.cfg.fast_bytes {
            return false;
        }
        while self.used + bytes > self.cfg.fast_bytes {
            match self.victim(require_unneeded) {
                Some(v) => self.evict(v),
                None => return false,
            }
        }
        true
    }

    /// Issue pipelined fast-tier loads for the round's not-yet-resident
    /// blocks, in execution order. Only blocks that fit after evicting
    /// *unneeded* residents are prefetched — a prefetch never evicts a
    /// block this round still uses, so it cannot thrash the round it
    /// serves.
    fn prefetch_round(&mut self) {
        let steps: Vec<RoundStep> = self.seq.clone();
        let mut seen: Vec<BlockId> = Vec::new();
        for st in steps {
            if seen.contains(&st.block) || self.resident.contains_key(&st.block) {
                continue;
            }
            seen.push(st.block);
            if !self.make_room(st.bytes, true) {
                continue; // will demand-load or stream at touch time
            }
            let start = if self.now > self.dma_free { self.now } else { self.dma_free };
            let ready = start + st.bytes as f64 / self.cfg.read_bps;
            self.dma_free = ready;
            self.ledger.issue(true);
            self.counters.prefetch_issued += 1;
            self.counters.bytes_loaded += st.bytes as u64;
            self.resident.insert(
                st.block,
                Entry {
                    bytes: st.bytes,
                    ready_at: ready,
                    last_touch: 0,
                    prefetched: true,
                    settled: false,
                    charged: false,
                    sharers: st.sharers,
                },
            );
            self.used += st.bytes;
        }
    }

    /// Advance the simulated clock through `secs` of compute, settling
    /// in-flight loads that complete under it.
    pub fn advance_exec(&mut self, secs: f64) {
        self.now += secs;
        let now = self.now;
        for e in self.resident.values_mut() {
            if !e.settled && e.ready_at <= now {
                e.settled = true;
                self.ledger.complete();
            }
        }
    }

    /// The forward needs `block` now. Returns the visible stall and the
    /// bytes to charge load energy for. Advances the round cursor past
    /// this use.
    pub fn touch(&mut self, block: BlockId, bytes: usize, sharers: usize) -> Touch {
        self.tick += 1;
        // consume this use from the round sequence (first occurrence at
        // or after the cursor; conditional-skipped earlier uses are
        // passed over by the forward search)
        if let Some(off) = self.seq[self.cursor.min(self.seq.len())..]
            .iter()
            .position(|s| s.block == block)
        {
            self.cursor = self.cursor + off + 1;
        }
        let mut out = Touch::default();
        if let Some(e) = self.resident.get_mut(&block) {
            // fast-tier hit — possibly still in flight
            if e.ready_at > self.now {
                out.stall_s = e.ready_at - self.now;
                self.now = e.ready_at;
            }
            if !e.settled {
                e.settled = true;
                self.ledger.complete();
            }
            if e.prefetched && e.last_touch == 0 {
                self.counters.prefetch_hits += 1;
            }
            if !e.charged {
                out.charge_bytes = e.bytes;
                e.charged = true;
            }
            e.last_touch = self.tick;
            self.counters.hits += 1;
            self.counters.stall_s += out.stall_s;
            self.reconcile();
            return out;
        }
        // demand miss: serialized load behind whatever the DMA engine is
        // already moving
        self.counters.misses += 1;
        let start = if self.now > self.dma_free { self.now } else { self.dma_free };
        let done = start + bytes as f64 / self.cfg.read_bps;
        out.stall_s = done - self.now;
        self.now = done;
        self.dma_free = done;
        out.charge_bytes = bytes;
        self.counters.stall_s += out.stall_s;
        self.counters.bytes_loaded += bytes as u64;
        let cached = self.make_room(bytes, false);
        self.ledger.issue(cached);
        self.ledger.complete();
        if cached {
            self.resident.insert(
                block,
                Entry {
                    bytes,
                    ready_at: done,
                    last_touch: self.tick,
                    prefetched: false,
                    settled: true,
                    charged: true,
                    sharers,
                },
            );
            self.used += bytes;
        }
        self.reconcile();
        out
    }

    /// Residency view for the dispatch board: per segment, the settled
    /// resident group most recently touched (`None` while cold). This
    /// is what `ResidencyBoard::publish` consumes, so residency-aware
    /// dispatch works unchanged over tier state.
    pub fn segment_view(&self, nseg: usize) -> Vec<Option<usize>> {
        let mut view: Vec<Option<(u64, usize)>> = vec![None; nseg];
        for (&(s, g), e) in &self.resident {
            if !e.settled || s >= nseg {
                continue;
            }
            match view[s] {
                Some((t, _)) if t >= e.last_touch => {}
                _ => view[s] = Some((e.last_touch, g)),
            }
        }
        view.into_iter().map(|v| v.map(|(_, g)| g)).collect()
    }

    /// Debug-only custody check: insertions − evictions must equal the
    /// resident count, and issued − completed − cancelled the in-flight
    /// count. Compiled out in release builds.
    fn reconcile(&self) {
        let in_flight = self.resident.values().filter(|e| !e.settled).count();
        self.ledger.reconcile(self.resident.len(), in_flight);
    }

    /// End-of-life check: every load issued was completed or cancelled.
    /// Call when a shard drains; panics (debug) on custody violations.
    pub fn close_check(&mut self) {
        // settle any in-flight prefetches the forward never waited on
        let remaining = self.dma_free;
        if remaining > self.now {
            self.advance_exec(remaining - self.now);
        }
        self.reconcile();
        self.ledger.close_check();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BPS: f64 = 1_000_000.0; // 1 MB/s: 1 byte = 1 µs, easy arithmetic

    fn tier(fast_bytes: usize, prefetch: bool, policy: EvictPolicy) -> WeightTier {
        WeightTier::new(TierConfig { fast_bytes, prefetch, policy, read_bps: BPS })
    }

    fn step(seg: usize, grp: usize, bytes: usize, sharers: usize) -> RoundStep {
        RoundStep { block: (seg, grp), bytes, sharers }
    }

    /// Run a round's touches with `exec_s` of compute between segments;
    /// returns misses observed for the round.
    fn run_seq(t: &mut WeightTier, seq: &[RoundStep], backlog: usize, exec_s: f64) -> u64 {
        let before = t.counters.misses;
        t.begin_round(seq.to_vec(), backlog);
        for st in seq {
            t.touch(st.block, st.bytes, st.sharers);
            t.advance_exec(exec_s);
        }
        t.counters.misses - before
    }

    /// Hand-built case where the affinity score provably beats LRU on
    /// load count. Capacity 2, unit blocks. Sequence A B C A: at C's
    /// miss, A has an upcoming use and 3 sharers while B is dead weight
    /// — affinity evicts B and A's re-touch hits; LRU evicts A (oldest)
    /// and re-loads it.
    #[test]
    fn affinity_beats_lru_on_load_count() {
        let a = step(0, 0, 1, 3);
        let b = step(1, 0, 1, 1);
        let c = step(2, 0, 1, 1);
        let seq = [a, b, c, a];

        let mut aff = tier(2, false, EvictPolicy::Affinity);
        let aff_misses = run_seq(&mut aff, &seq, 0, 0.0);

        let mut lru = tier(2, false, EvictPolicy::Lru);
        let lru_misses = run_seq(&mut lru, &seq, 0, 0.0);

        assert_eq!(aff_misses, 3, "affinity: A,B,C cold; A again hits");
        assert_eq!(lru_misses, 4, "lru evicts A at C, re-loads it");
        assert!(aff.counters.stall_s < lru.counters.stall_s);
        aff.close_check();
        lru.close_check();
    }

    /// Sharers break the tie when upcoming uses are equal: with no
    /// lookahead left, the block shared by more tasks survives.
    #[test]
    fn sharers_tiebreak_keeps_shared_block() {
        let shared = step(0, 0, 1, 4);
        let private = step(1, 0, 1, 1);
        let newcomer = step(2, 0, 1, 1);
        let mut t = tier(2, false, EvictPolicy::Affinity);
        // seq ends after the newcomer: neither resident block has
        // upcoming uses, so sharers decide (touch order makes `shared`
        // the LRU victim — affinity must override recency here)
        run_seq(&mut t, &[shared, private, newcomer], 0, 0.0);
        assert!(
            t.segment_view(3)[0].is_some(),
            "shared block survived eviction"
        );
        assert!(t.segment_view(3)[1].is_none(), "private block evicted");
        t.close_check();
    }

    /// Capacity 0: every touch is a miss, nothing is ever inserted, the
    /// ledger still balances (stream-throughs are issued + completed).
    #[test]
    fn capacity_zero_streams_everything() {
        let mut t = tier(0, true, EvictPolicy::Affinity);
        let seq = [step(0, 0, 10, 1), step(1, 0, 10, 1), step(0, 0, 10, 1)];
        let misses = run_seq(&mut t, &seq, 1, 0.0);
        assert_eq!(misses, 3);
        assert_eq!(t.counters.hits, 0);
        assert_eq!(t.used_bytes(), 0);
        assert_eq!(t.counters.prefetch_issued, 0, "nothing fits, nothing issued");
        // full serialized stall: 3 blocks × 10 bytes at 1 µs/byte
        assert!((t.counters.stall_s - 30e-6).abs() < 1e-12);
        t.close_check();
    }

    /// Adversarial thrash: capacity 1 with two alternating unit blocks.
    /// Every touch after the first pair evicts the other block; the
    /// eviction loop must terminate every time (no livelock) and the
    /// custody ledger must balance at close.
    #[test]
    fn thrash_terminates_and_balances() {
        let a = step(0, 0, 1, 1);
        let b = step(0, 1, 1, 1);
        let mut t = tier(1, true, EvictPolicy::Affinity);
        let seq: Vec<RoundStep> = (0..50).flat_map(|_| [a, b]).collect();
        run_seq(&mut t, &seq, 1, 0.0);
        assert_eq!(t.counters.hits + t.counters.misses, 100);
        assert!(t.counters.evictions <= t.counters.misses + t.counters.prefetch_issued);
        assert!(t.used_bytes() <= 1);
        t.close_check();
    }

    /// Prefetch overlap: with compute between touches, pipelined
    /// prefetch hides later blocks' load time behind earlier blocks'
    /// exec; prefetch-off pays every load as a serial stall.
    #[test]
    fn prefetch_hides_stall_behind_compute() {
        let seq = [step(0, 0, 100, 1), step(1, 0, 100, 1), step(2, 0, 100, 1)];
        let exec_s = 200e-6; // 2× one block's load time per segment

        let mut off = tier(usize::MAX, false, EvictPolicy::Affinity);
        run_seq(&mut off, &seq, 0, exec_s);
        let mut on = tier(usize::MAX, true, EvictPolicy::Affinity);
        run_seq(&mut on, &seq, 0, exec_s);

        // off: 3 full demand stalls (300 µs). on: block 0 stalls its own
        // load (100 µs); blocks 1,2 finish under the preceding exec.
        assert!((off.counters.stall_s - 300e-6).abs() < 1e-12);
        assert!((on.counters.stall_s - 100e-6).abs() < 1e-12);
        assert_eq!(on.counters.prefetch_hits, 3);
        assert_eq!(on.counters.misses, 0);
        off.close_check();
        on.close_check();
    }

    /// Unbounded capacity: a second identical round is all hits, no
    /// loads, zero stall — residency persists across rounds.
    #[test]
    fn unbounded_second_round_all_hits() {
        let seq = [step(0, 0, 10, 2), step(1, 0, 20, 1), step(2, 0, 30, 1)];
        let mut t = tier(usize::MAX, false, EvictPolicy::Affinity);
        let first = run_seq(&mut t, &seq, 0, 1e-3);
        let stall_after_first = t.counters.stall_s;
        let second = run_seq(&mut t, &seq, 0, 1e-3);
        assert_eq!(first, 3);
        assert_eq!(second, 0);
        assert_eq!(t.counters.stall_s, stall_after_first);
        assert_eq!(t.counters.bytes_loaded, 60);
        t.close_check();
    }

    /// Backlog hint pins this round's blocks: with visible frames
    /// behind the round, a foreign block streams through instead of
    /// evicting blocks the next round will reuse.
    #[test]
    fn backlog_hint_makes_round_blocks_sticky() {
        let a = step(0, 0, 1, 2);
        let b = step(1, 0, 1, 2);
        let mut t = tier(2, false, EvictPolicy::Affinity);
        run_seq(&mut t, &[a, b], 3, 0.0); // backlog visible
        // a foreign one-off block arrives mid-round; both residents
        // still have upcoming (next-round) uses, but demand eviction
        // may still pick one — the *prefetch* path is what must not
        // thrash. Here we check the cheap invariant: after re-running
        // the same round, its blocks hit.
        let misses = run_seq(&mut t, &[a, b], 0, 0.0);
        assert_eq!(misses, 0, "sticky blocks survive into the next round");
        t.close_check();
    }

    /// segment_view exposes the most recently touched settled group per
    /// segment and never a still-in-flight prefetch.
    #[test]
    fn segment_view_tracks_settled_recency() {
        let mut t = tier(usize::MAX, true, EvictPolicy::Affinity);
        let g0 = step(0, 0, 100, 1);
        let g1 = step(0, 1, 100, 1);
        t.begin_round(vec![g0, g1], 0);
        // prefetches issued but nothing settled yet: view is cold
        assert_eq!(t.segment_view(1), vec![None]);
        t.touch(g0.block, g0.bytes, g0.sharers); // stalls until ready
        assert_eq!(t.segment_view(1), vec![Some(0)]);
        t.touch(g1.block, g1.bytes, g1.sharers);
        assert_eq!(t.segment_view(1), vec![Some(1)], "recency wins");
        t.close_check();
    }

    /// A plan hot-swap changes the round sequence mid-serve. Residency
    /// is keyed by block, not by plan, so blocks shared between the old
    /// and the new plan stay warm across the swap and the custody
    /// ledger balances at close. The registry swap path
    /// (`coordinator::registry`) relies on exactly this: residency and
    /// prefetch hints survive a swap — a stale preference costs warmth,
    /// never correctness.
    #[test]
    fn residency_survives_a_plan_swap() {
        let a = step(0, 0, 10, 2);
        let b = step(1, 0, 10, 1);
        let c = step(2, 0, 10, 1);
        let mut t = tier(usize::MAX, true, EvictPolicy::Affinity);
        // epoch 0's plan: rounds touch A B C
        run_seq(&mut t, &[a, b, c], 1, 1e-3);
        let loaded = t.counters.bytes_loaded;
        // hot-swap: the new epoch's plan reorders to C A and drops B;
        // the prefetch set changes, but old residents still hit
        let misses = run_seq(&mut t, &[c, a], 0, 1e-3);
        assert_eq!(misses, 0, "blocks stay warm across the swap");
        assert_eq!(t.counters.bytes_loaded, loaded, "no reloads after swap");
        t.close_check();
    }

    /// Prefetches the forward never touched are settled and balanced at
    /// close (issued == completed + cancelled) — the custody invariant
    /// the audit ledger enforces.
    #[test]
    fn untouched_prefetch_balances_at_close() {
        let mut t = tier(usize::MAX, true, EvictPolicy::Affinity);
        t.begin_round(vec![step(0, 0, 10, 1), step(1, 0, 10, 1)], 0);
        // round aborts: only the first block is ever touched
        t.touch((0, 0), 10, 1);
        t.close_check(); // must not panic: in-flight prefetch settles
        assert_eq!(t.counters.prefetch_issued, 2);
        assert_eq!(t.counters.prefetch_hits, 1);
    }
}
