//! PJRT backend: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client via
//! the `xla` crate. This is the only place python-produced bits enter the
//! system; after `Engine::load`, the process is self-contained.
//!
//! HLO *text* is the interchange format (xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos; the text parser reassigns instruction
//! ids) — see /opt/xla-example/README.md.
//!
//! `PjRtClient` is `Rc`-based (!Send), so an `Engine` is pinned to one
//! thread; the serving coordinator owns it on a dedicated executor thread
//! — which also mirrors the single-core MCU execution model being
//! simulated. Sharded serving uses the `Send` reference backend instead.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use super::Backend;
use crate::model::{manifest::Manifest, ArchSpec, Tensor};

/// Inputs accepted by [`Engine::run`].
pub enum Arg<'a> {
    F32(&'a Tensor),
    I32(&'a [i32]),
    ScalarF32(f32),
}

pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Executions performed (for the perf counters).
    pub exec_count: std::cell::Cell<u64>,
}

impl Engine {
    /// Create a CPU PJRT client and read the artifact manifest.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            exec_count: std::cell::Cell::new(0),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (once) and cache the named artifact.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(Rc::clone(e));
        }
        let path = self.manifest.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let exe = Rc::new(exe);
        self.exes.borrow_mut().insert(name.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Eagerly compile every artifact matching `filter` (startup warm-up).
    pub fn precompile(&self, filter: impl Fn(&str) -> bool) -> Result<usize> {
        let names: Vec<String> = self
            .manifest
            .entries
            .keys()
            .filter(|n| filter(n))
            .cloned()
            .collect();
        for n in &names {
            self.executable(n)?;
        }
        Ok(names.len())
    }

    pub fn compiled_count(&self) -> usize {
        self.exes.borrow().len()
    }

    /// Execute an artifact. Output shapes come from the manifest entry.
    /// (Perf note: `entry` is borrowed, not cloned — this is the serving
    /// hot path; see EXPERIMENTS.md §Perf.)
    pub fn run(&self, name: &str, args: &[Arg]) -> Result<Vec<Tensor>> {
        let entry = self.manifest.entry(name)?;
        if args.len() != entry.inputs.len() {
            bail!(
                "{name}: expected {} args, got {}",
                entry.inputs.len(),
                args.len()
            );
        }
        let exe = self.executable(name)?;
        let mut literals = Vec::with_capacity(args.len());
        for (i, a) in args.iter().enumerate() {
            let want = &entry.inputs[i];
            literals.push(to_literal(a, want).with_context(|| {
                format!("{name}: arg {i} (expected shape {want:?})")
            })?);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        self.exec_count.set(self.exec_count.get() + 1);
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name} result: {e:?}"))?;
        if tuple.len() != entry.outputs.len() {
            bail!(
                "{name}: manifest says {} outputs, got {}",
                entry.outputs.len(),
                tuple.len()
            );
        }
        tuple
            .into_iter()
            .zip(&entry.outputs)
            .map(|(lit, shape)| {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("{name}: output not f32: {e:?}"))?;
                Ok(Tensor::new(shape.clone(), data))
            })
            .collect()
    }
}

impl Backend for Engine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn arch(&self, name: &str) -> Result<ArchSpec> {
        self.manifest.arch(name).map(|a| a.clone())
    }

    fn arch_names(&self) -> Vec<String> {
        self.manifest.archs.keys().cloned().collect()
    }

    fn run_layer(
        &self,
        arch: &ArchSpec,
        layer: usize,
        ncls: Option<usize>,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
    ) -> Result<Tensor> {
        let batch = x.shape[0];
        let name = self.manifest.layer_artifact(&arch.name, layer, ncls, batch);
        let mut out = self.run(&name, &[Arg::F32(x), Arg::F32(w), Arg::F32(b)])?;
        Ok(out.remove(0))
    }

    fn train_step(
        &self,
        arch: &ArchSpec,
        ncls: usize,
        params: &mut Vec<Tensor>,
        x: &Tensor,
        y: &[i32],
        lr: f32,
    ) -> Result<f32> {
        let name = self.manifest.train_artifact(&arch.name, ncls);
        let mut args: Vec<Arg> = Vec::with_capacity(3 + params.len());
        args.push(Arg::F32(x));
        args.push(Arg::I32(y));
        args.push(Arg::ScalarF32(lr));
        for p in params.iter() {
            args.push(Arg::F32(p));
        }
        let mut out = self.run(&name, &args)?;
        if out.len() != params.len() + 1 {
            bail!("train artifact returned {} outputs", out.len());
        }
        let loss = out[0].data[0];
        for (i, p) in params.iter_mut().enumerate() {
            *p = std::mem::replace(&mut out[i + 1], Tensor::zeros(vec![0]));
        }
        Ok(loss)
    }

    fn eval_logits(
        &self,
        arch: &ArchSpec,
        ncls: usize,
        params: &[Tensor],
        x: &Tensor,
    ) -> Result<Tensor> {
        let name = self.manifest.eval_artifact(&arch.name, ncls);
        let mut args: Vec<Arg> = Vec::with_capacity(1 + params.len());
        args.push(Arg::F32(x));
        for p in params {
            args.push(Arg::F32(p));
        }
        let mut out = self.run(&name, &args)?;
        Ok(out.remove(0))
    }

    /// Pre-compile every batch-1 layer artifact the (arch, class counts)
    /// pair needs for serving.
    fn warmup(&self, arch: &ArchSpec, ncls: &[usize]) -> Result<usize> {
        let mut n = 0;
        for l in 0..arch.n_layers() {
            let is_logits = arch.layers[l].is_logits();
            if is_logits {
                let mut seen = std::collections::BTreeSet::new();
                for &c in ncls {
                    if seen.insert(c) {
                        let name =
                            self.manifest.layer_artifact(&arch.name, l, Some(c), 1);
                        self.executable(&name)?;
                        n += 1;
                    }
                }
            } else {
                let name = self.manifest.layer_artifact(&arch.name, l, None, 1);
                self.executable(&name)?;
                n += 1;
            }
        }
        Ok(n)
    }
}

fn to_literal(arg: &Arg, want_shape: &[usize]) -> Result<xla::Literal> {
    match arg {
        Arg::F32(t) => {
            if t.shape != want_shape {
                bail!("shape mismatch: have {:?}", t.shape);
            }
            // single-copy construction (vec1+reshape copies twice)
            let bytes = unsafe {
                std::slice::from_raw_parts(
                    t.data.as_ptr() as *const u8,
                    t.data.len() * 4,
                )
            };
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &t.shape,
                bytes,
            )
            .map_err(|e| anyhow!("literal: {e:?}"))
        }
        Arg::I32(v) => {
            if want_shape != [v.len()] {
                bail!("i32 arg length {} vs shape {:?}", v.len(), want_shape);
            }
            Ok(xla::Literal::vec1(v))
        }
        Arg::ScalarF32(x) => {
            if !want_shape.is_empty() {
                bail!("scalar arg vs shape {:?}", want_shape);
            }
            Ok(xla::Literal::scalar(*x))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::pjrt_test_engine as engine;

    #[test]
    fn arg_shape_validation() {
        let t = Tensor::zeros(vec![2, 3]);
        assert!(to_literal(&Arg::F32(&t), &[2, 3]).is_ok());
        assert!(to_literal(&Arg::F32(&t), &[3, 2]).is_err());
        assert!(to_literal(&Arg::I32(&[1, 2]), &[2]).is_ok());
        assert!(to_literal(&Arg::I32(&[1, 2]), &[3]).is_err());
        assert!(to_literal(&Arg::ScalarF32(0.5), &[]).is_ok());
        assert!(to_literal(&Arg::ScalarF32(0.5), &[1]).is_err());
    }

    #[test]
    fn engine_runs_a_layer_artifact() {
        let Some(eng) = engine() else { return };
        let arch = eng.arch("cnn5").unwrap();
        let x = Tensor::full(vec![1, 16, 16, 1], 0.5);
        let w = Tensor::full(vec![3, 3, 1, 8], 0.1);
        let b = Tensor::zeros(vec![8]);
        let y = eng.run_layer(&arch, 0, None, &x, &w, &b).unwrap();
        assert_eq!(y.shape, vec![1, 8, 8, 8]);
        // conv(0.5, 0.1 kernel) interior = 9*0.5*0.1 = 0.45; pooled max > 0
        assert!(y.data.iter().all(|&v| v > 0.0));
        assert!(y.data.iter().any(|&v| (v - 0.45).abs() < 1e-5));
    }

    #[test]
    fn executable_cache_hits() {
        let Some(eng) = engine() else { return };
        let _ = eng.executable("layer_cnn5_0_b1").unwrap();
        let before = eng.compiled_count();
        let _ = eng.executable("layer_cnn5_0_b1").unwrap();
        assert_eq!(eng.compiled_count(), before);
    }

    #[test]
    fn run_rejects_wrong_arity() {
        let Some(eng) = engine() else { return };
        let x = Tensor::zeros(vec![1, 16, 16, 1]);
        assert!(eng.run("layer_cnn5_0_b1", &[Arg::F32(&x)]).is_err());
    }
}
