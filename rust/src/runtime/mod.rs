//! Multi-backend runtime. A [`Backend`] executes the three block-program
//! shapes the coordinator needs — single layers (the serving hot path),
//! one SGD training step, and a whole-network batch eval — behind one
//! trait, so every layer above (executor, server, trainer, pipeline,
//! benches) is backend-agnostic.
//!
//! Two implementations:
//!  * [`ReferenceBackend`] — a pure-Rust interpreter of the block
//!    programs (conv2d / dense / maxpool / softmax, mirroring
//!    `python/compile/kernels/ref.py`), always available, `Send + Sync`,
//!    so the full stack is testable and shardable with no artifacts.
//!  * [`Engine`] (feature `pjrt`) — the AOT-compiled HLO artifacts from
//!    `python/compile/aot.py` executed on the CPU PJRT client. `Rc`-based
//!    and pinned to one thread, which also mirrors the single-core MCU
//!    execution model being simulated.
//!
//! Selection: `ANTLER_BACKEND=reference|pjrt` (or the `--backend` CLI
//! flag, which sets the env var). Unset → PJRT when the feature is on
//! and artifacts exist, reference otherwise.

pub mod reference;

#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use reference::ReferenceBackend;

#[cfg(feature = "pjrt")]
pub use pjrt::{Arg, Engine};

use anyhow::Result;

use crate::model::{ArchSpec, Tensor};

/// Environment variable naming the backend to use (`reference` | `pjrt`).
pub const BACKEND_ENV: &str = "ANTLER_BACKEND";

/// An execution backend for the Antler block programs. All methods take
/// `&self`; implementations use interior mutability for caches/counters.
pub trait Backend {
    /// Short identifier (`"reference"` / `"pjrt"`).
    fn name(&self) -> &'static str;

    /// Look up an architecture this backend can execute.
    fn arch(&self, name: &str) -> Result<ArchSpec>;

    /// Names of every architecture this backend can execute.
    fn arch_names(&self) -> Vec<String>;

    /// Run one layer: `y = layer_l(x, w, b)`. `ncls` is `Some` only for
    /// the logits layer (its output width is chosen per task). The batch
    /// dimension is `x.shape[0]`.
    ///
    /// Contract for batch-N inputs: every output row must equal the
    /// result of running that row alone (the reference backend makes
    /// this bitwise-exact; PJRT agrees to the parity-test tolerance).
    /// The cross-frame batching serving path (`coordinator::shard`)
    /// relies on it to keep batched predictions frame-for-frame
    /// identical to the single-executor loop.
    fn run_layer(
        &self,
        arch: &ArchSpec,
        layer: usize,
        ncls: Option<usize>,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
    ) -> Result<Tensor>;

    /// One SGD step of softmax cross-entropy over the whole network.
    /// `params` is the flat `[w0, b0, w1, b1, ...]` list, updated in
    /// place; returns the pre-update batch loss.
    fn train_step(
        &self,
        arch: &ArchSpec,
        ncls: usize,
        params: &mut Vec<Tensor>,
        x: &Tensor,
        y: &[i32],
        lr: f32,
    ) -> Result<f32>;

    /// Whole-network batch forward → logits `(batch, ncls)`.
    fn eval_logits(
        &self,
        arch: &ArchSpec,
        ncls: usize,
        params: &[Tensor],
        x: &Tensor,
    ) -> Result<Tensor>;

    /// Warm any compilation caches needed to serve `arch` with these
    /// per-task class counts; returns the number of entries warmed.
    /// No-op for backends that don't compile.
    fn warmup(&self, arch: &ArchSpec, ncls: &[usize]) -> Result<usize> {
        let _ = (arch, ncls);
        Ok(0)
    }
}

macro_rules! forward_backend_impl {
    () => {
        fn name(&self) -> &'static str {
            (**self).name()
        }
        fn arch(&self, name: &str) -> Result<ArchSpec> {
            (**self).arch(name)
        }
        fn arch_names(&self) -> Vec<String> {
            (**self).arch_names()
        }
        fn run_layer(
            &self,
            arch: &ArchSpec,
            layer: usize,
            ncls: Option<usize>,
            x: &Tensor,
            w: &Tensor,
            b: &Tensor,
        ) -> Result<Tensor> {
            (**self).run_layer(arch, layer, ncls, x, w, b)
        }
        fn train_step(
            &self,
            arch: &ArchSpec,
            ncls: usize,
            params: &mut Vec<Tensor>,
            x: &Tensor,
            y: &[i32],
            lr: f32,
        ) -> Result<f32> {
            (**self).train_step(arch, ncls, params, x, y, lr)
        }
        fn eval_logits(
            &self,
            arch: &ArchSpec,
            ncls: usize,
            params: &[Tensor],
            x: &Tensor,
        ) -> Result<Tensor> {
            (**self).eval_logits(arch, ncls, params, x)
        }
        fn warmup(&self, arch: &ArchSpec, ncls: &[usize]) -> Result<usize> {
            (**self).warmup(arch, ncls)
        }
    };
}

impl<'a, B: Backend + ?Sized> Backend for &'a B {
    forward_backend_impl!();
}

impl<B: Backend + ?Sized> Backend for Box<B> {
    forward_backend_impl!();
}

impl<B: Backend + ?Sized> Backend for std::rc::Rc<B> {
    forward_backend_impl!();
}

impl<B: Backend + ?Sized> Backend for crate::sync::Arc<B> {
    forward_backend_impl!();
}

/// True when the PJRT engine can actually load: built with `--features
/// pjrt` AND the AOT artifacts exist on disk.
#[cfg(feature = "pjrt")]
pub fn pjrt_available() -> bool {
    crate::model::manifest::default_artifacts_dir()
        .join("manifest.json")
        .exists()
}

/// True when the PJRT engine can actually load: built with `--features
/// pjrt` AND the AOT artifacts exist on disk.
#[cfg(not(feature = "pjrt"))]
pub fn pjrt_available() -> bool {
    false
}

#[cfg(feature = "pjrt")]
fn pjrt_backend() -> Result<Box<dyn Backend>> {
    let dir = crate::model::manifest::default_artifacts_dir();
    Ok(Box::new(Engine::load(&dir)?))
}

/// Artifact-gated engine for PJRT test variants: `Some` only when the
/// AOT artifacts exist on disk. The single source of truth for artifact
/// detection in tests — keep skip conditions from drifting apart.
#[cfg(feature = "pjrt")]
pub fn pjrt_test_engine() -> Option<Engine> {
    pjrt_available().then(|| {
        Engine::load(&crate::model::manifest::default_artifacts_dir())
            .expect("artifacts exist but the engine failed to load")
    })
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend() -> Result<Box<dyn Backend>> {
    anyhow::bail!(
        "the pjrt backend requires building with `--features pjrt` \
         (and `python -m compile.aot` artifacts)"
    )
}

/// Construct the backend named by `ANTLER_BACKEND`, defaulting to PJRT
/// when available and the pure-Rust reference backend otherwise.
pub fn backend_from_env() -> Result<Box<dyn Backend>> {
    match std::env::var(BACKEND_ENV).ok().as_deref() {
        Some("reference") | Some("ref") => Ok(Box::new(ReferenceBackend::new())),
        Some("pjrt") => pjrt_backend(),
        Some(other) => anyhow::bail!(
            "unknown {BACKEND_ENV}={other:?} (expected \"reference\" or \"pjrt\")"
        ),
        None => {
            if pjrt_available() {
                pjrt_backend()
            } else {
                Ok(Box::new(ReferenceBackend::new()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_backend_is_always_constructible() {
        let be = ReferenceBackend::new();
        assert_eq!(be.name(), "reference");
        assert!(be.arch("cnn5").is_ok());
        assert!(be.arch_names().contains(&"dnn4".to_string()));
    }

    #[test]
    fn trait_objects_and_smart_pointers_forward() {
        let boxed: Box<dyn Backend> = Box::new(ReferenceBackend::new());
        assert_eq!(boxed.name(), "reference");
        // &dyn Backend is itself a Backend (the executor stores it by value)
        fn takes_backend<B: Backend>(b: B) -> &'static str {
            b.name()
        }
        assert_eq!(takes_backend(boxed.as_ref()), "reference");
        let rc = std::rc::Rc::new(ReferenceBackend::new());
        assert_eq!(takes_backend(rc), "reference");
        let arc = crate::sync::Arc::new(ReferenceBackend::new());
        assert_eq!(takes_backend(arc), "reference");
    }
}
