//! Pure-Rust reference backend: interprets the block programs directly —
//! conv2d (same-padded, stride 1, NHWC/HWIO), dense, 2×2 maxpool,
//! leaky-ReLU and softmax cross-entropy — mirroring
//! `python/compile/kernels/ref.py` to f32 tolerance. It needs no AOT
//! artifacts, is `Send + Sync` (plain data + atomic counters), and
//! implements the full [`Backend`] contract including training: the
//! backward pass is hand-derived for the three layer kinds, so the
//! trainer, pipeline and serving tests all run on any machine.
//!
//! This is the correctness oracle for the PJRT engine (tests/parity.rs)
//! and the workhorse of the sharded executor pool
//! (`coordinator::shard`), which wants one `Send` executor per thread.
//!
//! All forward kernels are batch-N: the leading dimension of `x` is the
//! batch, and rows are computed in sample blocks (4/2/1) whose
//! per-sample accumulation order matches a batch-1 call exactly, so a
//! batched forward is bitwise identical row-for-row to running each
//! sample alone. Cross-frame micro-batching in the shard scheduler
//! (`coordinator::shard`, `--batch`) builds on that guarantee.

use std::collections::BTreeMap;

use crate::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, bail, Result};

use super::Backend;
use crate::model::{archs::builtin_archs, ArchSpec, LayerKind, LayerSpec, Tensor};

/// Slope of the leaky ReLU — must match `kernels/ref.py::LEAKY_SLOPE`.
pub const LEAKY_SLOPE: f32 = 0.01;

#[inline]
fn leaky(v: f32) -> f32 {
    if v > 0.0 {
        v
    } else {
        LEAKY_SLOPE * v
    }
}

#[inline]
fn leaky_grad(z: f32) -> f32 {
    if z > 0.0 {
        1.0
    } else {
        LEAKY_SLOPE
    }
}

pub struct ReferenceBackend {
    archs: BTreeMap<String, ArchSpec>,
    /// Layer executions performed (perf counter, mirrors Engine::exec_count).
    layer_execs: AtomicU64,
}

impl Default for ReferenceBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl ReferenceBackend {
    pub fn new() -> ReferenceBackend {
        ReferenceBackend {
            archs: builtin_archs(),
            layer_execs: AtomicU64::new(0),
        }
    }

    pub fn layer_exec_count(&self) -> u64 {
        self.layer_execs.load(Ordering::Relaxed)
    }

    /// Mean softmax cross-entropy of `params` on a labelled batch —
    /// exposed for gradient checking in tests.
    pub fn loss(
        &self,
        arch: &ArchSpec,
        ncls: usize,
        params: &[Tensor],
        x: &Tensor,
        y: &[i32],
    ) -> Result<f32> {
        let logits = self.eval_logits(arch, ncls, params, x)?;
        let (loss, _) = ce_loss_and_grad(&logits, y, ncls)?;
        Ok(loss)
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn arch(&self, name: &str) -> Result<ArchSpec> {
        self.archs
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("unknown arch {name:?}"))
    }

    fn arch_names(&self) -> Vec<String> {
        self.archs.keys().cloned().collect()
    }

    fn run_layer(
        &self,
        arch: &ArchSpec,
        layer: usize,
        ncls: Option<usize>,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
    ) -> Result<Tensor> {
        let spec = arch
            .layers
            .get(layer)
            .ok_or_else(|| anyhow!("{}: no layer {layer}", arch.name))?;
        if spec.kind == LayerKind::Logits {
            if let Some(c) = ncls {
                if w.shape.len() != 2 || w.shape[1] != c {
                    bail!(
                        "{} layer {layer}: logits weights {:?} vs ncls {c}",
                        arch.name,
                        w.shape
                    );
                }
            }
        }
        self.layer_execs.fetch_add(1, Ordering::Relaxed);
        layer_forward(spec, x, w, b)
    }

    fn train_step(
        &self,
        arch: &ArchSpec,
        ncls: usize,
        params: &mut Vec<Tensor>,
        x: &Tensor,
        y: &[i32],
        lr: f32,
    ) -> Result<f32> {
        let nl = arch.n_layers();
        if params.len() != 2 * nl {
            bail!("expected {} params, got {}", 2 * nl, params.len());
        }
        let bsz = x.shape[0];
        if y.len() != bsz {
            bail!("batch {bsz} vs {} labels", y.len());
        }

        // ---- forward, caching what the backward pass needs
        let mut inputs: Vec<Tensor> = Vec::with_capacity(nl); // activation entering layer l
        let mut caches: Vec<LayerCache> = Vec::with_capacity(nl);
        let mut cur = x.clone();
        for (l, spec) in arch.layers.iter().enumerate() {
            let w = &params[2 * l];
            let b = &params[2 * l + 1];
            let (out, cache) = layer_forward_cached(spec, &cur, w, b)?;
            inputs.push(std::mem::replace(&mut cur, out));
            caches.push(cache);
        }
        let logits = cur;
        let (loss, mut grad) = ce_loss_and_grad(&logits, y, ncls)?;

        // ---- backward + SGD update, last layer first
        for l in (0..nl).rev() {
            let spec = &arch.layers[l];
            let w = &params[2 * l];
            let (dw, db, dx) =
                layer_backward(spec, &inputs[l], w, &caches[l], &grad)?;
            apply_sgd(&mut params[2 * l], &dw, lr);
            apply_sgd(&mut params[2 * l + 1], &db, lr);
            grad = dx;
        }
        Ok(loss)
    }

    fn eval_logits(
        &self,
        arch: &ArchSpec,
        ncls: usize,
        params: &[Tensor],
        x: &Tensor,
    ) -> Result<Tensor> {
        let nl = arch.n_layers();
        if params.len() != 2 * nl {
            bail!("expected {} params, got {}", 2 * nl, params.len());
        }
        let mut cur = x.clone();
        for (l, spec) in arch.layers.iter().enumerate() {
            if spec.kind == LayerKind::Logits && params[2 * l].shape[1] != ncls {
                bail!(
                    "logits weights {:?} vs ncls {ncls}",
                    params[2 * l].shape
                );
            }
            cur = layer_forward(spec, &cur, &params[2 * l], &params[2 * l + 1])?;
        }
        Ok(cur)
    }
}

// ------------------------------------------------------------------ layers

/// What the backward pass needs beyond the layer input.
enum LayerCache {
    /// Pre-activation conv output `z` and the flat argmax index (into the
    /// pre-pool tensor) of every pooled element.
    ConvPool { z: Tensor, pool_idx: Vec<usize> },
    /// Pre-activation dense output `z` (the logits layer reuses this
    /// without a nonlinearity).
    Dense { z: Tensor },
}

fn layer_forward(spec: &LayerSpec, x: &Tensor, w: &Tensor, b: &Tensor) -> Result<Tensor> {
    match spec.kind {
        LayerKind::ConvPool => {
            let mut z = conv2d_raw(x, w, b)?;
            for v in z.data.iter_mut() {
                *v = leaky(*v);
            }
            let (p, _) = maxpool2x2(&z);
            Ok(p)
        }
        LayerKind::Dense => {
            let mut z = dense_raw(x, w, b)?;
            for v in z.data.iter_mut() {
                *v = leaky(*v);
            }
            Ok(z)
        }
        LayerKind::Logits => dense_raw(x, w, b),
    }
}

fn layer_forward_cached(
    spec: &LayerSpec,
    x: &Tensor,
    w: &Tensor,
    b: &Tensor,
) -> Result<(Tensor, LayerCache)> {
    match spec.kind {
        LayerKind::ConvPool => {
            let z = conv2d_raw(x, w, b)?;
            let mut a = z.clone();
            for v in a.data.iter_mut() {
                *v = leaky(*v);
            }
            let (p, pool_idx) = maxpool2x2(&a);
            Ok((p, LayerCache::ConvPool { z, pool_idx }))
        }
        LayerKind::Dense => {
            let z = dense_raw(x, w, b)?;
            let mut a = z.clone();
            for v in a.data.iter_mut() {
                *v = leaky(*v);
            }
            Ok((a, LayerCache::Dense { z }))
        }
        LayerKind::Logits => {
            let z = dense_raw(x, w, b)?;
            Ok((z.clone(), LayerCache::Dense { z }))
        }
    }
}

/// Backward through one layer. Returns (dw, db, dx).
fn layer_backward(
    spec: &LayerSpec,
    x: &Tensor,
    w: &Tensor,
    cache: &LayerCache,
    dout: &Tensor,
) -> Result<(Tensor, Tensor, Tensor)> {
    match (spec.kind, cache) {
        (LayerKind::ConvPool, LayerCache::ConvPool { z, pool_idx }) => {
            // un-pool: route each pooled gradient to its argmax source
            let mut da = Tensor::zeros(z.shape.clone());
            for (o, &src) in pool_idx.iter().enumerate() {
                da.data[src] += dout.data[o];
            }
            // through the leaky ReLU
            let mut dz = da;
            for (g, &zv) in dz.data.iter_mut().zip(&z.data) {
                *g *= leaky_grad(zv);
            }
            conv2d_backward(x, w, &dz)
        }
        (LayerKind::Dense, LayerCache::Dense { z }) => {
            let mut dz = dout.clone();
            for (g, &zv) in dz.data.iter_mut().zip(&z.data) {
                *g *= leaky_grad(zv);
            }
            dense_backward(x, w, &dz)
        }
        (LayerKind::Logits, LayerCache::Dense { .. }) => {
            dense_backward(x, w, dout)
        }
        _ => bail!("layer cache kind mismatch"),
    }
}

fn apply_sgd(p: &mut Tensor, g: &Tensor, lr: f32) {
    debug_assert_eq!(p.shape, g.shape);
    for (pv, &gv) in p.data.iter_mut().zip(&g.data) {
        *pv -= lr * gv;
    }
}

// ------------------------------------------------------------------ dense

/// y = flatten(x) @ w + b. x: (B, ...); w: (K, D); b: (D).
///
/// Batched rows are processed in sample blocks of 4/2/1
/// ([`dense_block`]): each sample keeps its own accumulator and walks the
/// weight rows in the same order as a batch-1 call, so the result is
/// bitwise identical row-for-row regardless of how frames are batched —
/// the invariant the sharded/batched serving parity tests rely on. The
/// block form reuses each weight row across the block and gives the CPU
/// independent accumulation chains, which is where cross-frame batching
/// earns its wall-clock speedup (EXPERIMENTS.md §Perf).
fn dense_raw(x: &Tensor, w: &Tensor, b: &Tensor) -> Result<Tensor> {
    let bsz = x.shape[0];
    let k: usize = x.shape[1..].iter().product();
    if w.shape.len() != 2 || w.shape[0] != k {
        bail!("dense: input {:?} vs weights {:?}", x.shape, w.shape);
    }
    let d = w.shape[1];
    if b.shape != [d] {
        bail!("dense: bias {:?} vs width {d}", b.shape);
    }
    let mut out = vec![0.0f32; bsz * d];
    for row in out.chunks_mut(d) {
        row.copy_from_slice(&b.data);
    }
    let mut i = 0;
    while i + 4 <= bsz {
        dense_block::<4>(x, w, &mut out, i, k, d);
        i += 4;
    }
    while i + 2 <= bsz {
        dense_block::<2>(x, w, &mut out, i, k, d);
        i += 2;
    }
    while i < bsz {
        dense_block::<1>(x, w, &mut out, i, k, d);
        i += 1;
    }
    Ok(Tensor::new(vec![bsz, d], out))
}

/// Accumulate `NB` consecutive samples starting at row `i0`. Per sample
/// the weight rows are visited in exactly the batch-1 order (kk ascending,
/// zero inputs skipped), so each output row is bitwise independent of NB.
fn dense_block<const NB: usize>(
    x: &Tensor,
    w: &Tensor,
    out: &mut [f32],
    i0: usize,
    k: usize,
    d: usize,
) {
    for kk in 0..k {
        let wrow = &w.data[kk * d..(kk + 1) * d];
        for sb in 0..NB {
            let xv = x.data[(i0 + sb) * k + kk];
            if xv == 0.0 {
                continue;
            }
            let oi = &mut out[(i0 + sb) * d..(i0 + sb + 1) * d];
            for (ov, &wv) in oi.iter_mut().zip(wrow) {
                *ov += xv * wv;
            }
        }
    }
}

/// Backward for y = flatten(x) @ w + b given dz = ∂L/∂y.
/// Returns (dw, db, dx) with dx in x's original shape.
fn dense_backward(x: &Tensor, w: &Tensor, dz: &Tensor) -> Result<(Tensor, Tensor, Tensor)> {
    let bsz = x.shape[0];
    let k: usize = x.shape[1..].iter().product();
    let d = w.shape[1];
    if dz.shape != [bsz, d] {
        bail!("dense backward: dz {:?} vs ({bsz}, {d})", dz.shape);
    }
    let mut dw = vec![0.0f32; k * d];
    let mut db = vec![0.0f32; d];
    let mut dx = vec![0.0f32; bsz * k];
    for i in 0..bsz {
        let xi = &x.data[i * k..(i + 1) * k];
        let gi = &dz.data[i * d..(i + 1) * d];
        for (bv, &gv) in db.iter_mut().zip(gi) {
            *bv += gv;
        }
        let dxi = &mut dx[i * k..(i + 1) * k];
        for kk in 0..k {
            let wrow = &w.data[kk * d..(kk + 1) * d];
            let dwrow = &mut dw[kk * d..(kk + 1) * d];
            let xv = xi[kk];
            let mut acc = 0.0f32;
            for dd in 0..d {
                dwrow[dd] += xv * gi[dd];
                acc += wrow[dd] * gi[dd];
            }
            dxi[kk] = acc;
        }
    }
    Ok((
        Tensor::new(vec![k, d], dw),
        Tensor::new(vec![d], db),
        Tensor::new(x.shape.clone(), dx),
    ))
}

// ------------------------------------------------------------------- conv

/// Same-padded stride-1 conv + bias (no activation).
/// x: (B, H, W, Cin) NHWC; w: (KH, KW, Cin, Cout) HWIO; b: (Cout).
///
/// Like [`dense_raw`], the batch is processed in sample blocks of 4/2/1
/// ([`conv2d_block`]) with per-sample accumulation order identical to a
/// batch-1 call — bitwise-identical rows for any batch split. Blocking
/// amortizes the padding tests, index arithmetic and kernel-row loads
/// over the block, and (crucially for the narrow per-pixel accumulators
/// of these MCU-scale nets) gives the CPU NB independent FMA chains
/// instead of one latency-bound chain — the batched serving speedup
/// measured by `benches/runtime_hotpath.rs` (EXPERIMENTS.md §Perf).
fn conv2d_raw(x: &Tensor, w: &Tensor, b: &Tensor) -> Result<Tensor> {
    if x.rank() != 4 || w.rank() != 4 {
        bail!("conv2d: x {:?}, w {:?}", x.shape, w.shape);
    }
    let (bsz, h, wd, cin) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (kh, kw, wcin, cout) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    if wcin != cin {
        bail!("conv2d: cin {cin} vs kernel {wcin}");
    }
    if b.shape != [cout] {
        bail!("conv2d: bias {:?} vs cout {cout}", b.shape);
    }
    let mut out = vec![0.0f32; bsz * h * wd * cout];
    let mut n = 0;
    while n + 4 <= bsz {
        conv2d_block::<4>(x, w, b, &mut out, n);
        n += 4;
    }
    while n + 2 <= bsz {
        conv2d_block::<2>(x, w, b, &mut out, n);
        n += 2;
    }
    while n < bsz {
        conv2d_block::<1>(x, w, b, &mut out, n);
        n += 1;
    }
    Ok(Tensor::new(vec![bsz, h, wd, cout], out))
}

/// Convolve `NB` consecutive samples starting at batch row `n0` into
/// `out`. Shapes are re-read from the (already validated) tensors. Per
/// sample the kernel taps are visited in exactly the batch-1 order
/// (ky, kx, ci ascending; zero inputs skipped), so every output row is
/// bitwise independent of the blocking factor.
fn conv2d_block<const NB: usize>(
    x: &Tensor,
    w: &Tensor,
    b: &Tensor,
    out: &mut [f32],
    n0: usize,
) {
    let (h, wd, cin) = (x.shape[1], x.shape[2], x.shape[3]);
    let (kh, kw, cout) = (w.shape[0], w.shape[1], w.shape[3]);
    // XLA SAME padding for stride 1: total k-1, low half rounded down.
    let (pad_t, pad_l) = ((kh - 1) / 2, (kw - 1) / 2);
    let mut acc = vec![0.0f32; NB * cout];
    for oy in 0..h {
        for ox in 0..wd {
            for sb in 0..NB {
                acc[sb * cout..(sb + 1) * cout].copy_from_slice(&b.data);
            }
            for ky in 0..kh {
                let iy = oy + ky;
                if iy < pad_t || iy >= h + pad_t {
                    continue;
                }
                let iy = iy - pad_t;
                for kx in 0..kw {
                    let ix = ox + kx;
                    if ix < pad_l || ix >= wd + pad_l {
                        continue;
                    }
                    let ix = ix - pad_l;
                    let wbase = (ky * kw + kx) * cin * cout;
                    for ci in 0..cin {
                        let wrow =
                            &w.data[wbase + ci * cout..wbase + (ci + 1) * cout];
                        for sb in 0..NB {
                            let xbase =
                                (((n0 + sb) * h + iy) * wd + ix) * cin;
                            let xv = x.data[xbase + ci];
                            if xv == 0.0 {
                                continue;
                            }
                            let accs = &mut acc[sb * cout..(sb + 1) * cout];
                            for (av, &wv) in accs.iter_mut().zip(wrow) {
                                *av += xv * wv;
                            }
                        }
                    }
                }
            }
            for sb in 0..NB {
                let obase = (((n0 + sb) * h + oy) * wd + ox) * cout;
                out[obase..obase + cout]
                    .copy_from_slice(&acc[sb * cout..(sb + 1) * cout]);
            }
        }
    }
}

/// Backward for z = conv2d(x, w) + b given dz. Returns (dw, db, dx).
fn conv2d_backward(x: &Tensor, w: &Tensor, dz: &Tensor) -> Result<(Tensor, Tensor, Tensor)> {
    let (bsz, h, wd, cin) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (kh, kw, _, cout) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    if dz.shape != [bsz, h, wd, cout] {
        bail!("conv backward: dz {:?}", dz.shape);
    }
    let (pad_t, pad_l) = ((kh - 1) / 2, (kw - 1) / 2);
    let mut dw = vec![0.0f32; kh * kw * cin * cout];
    let mut db = vec![0.0f32; cout];
    let mut dx = vec![0.0f32; bsz * h * wd * cin];
    for n in 0..bsz {
        for oy in 0..h {
            for ox in 0..wd {
                let zbase = ((n * h + oy) * wd + ox) * cout;
                let gz = &dz.data[zbase..zbase + cout];
                for (bv, &gv) in db.iter_mut().zip(gz) {
                    *bv += gv;
                }
                for ky in 0..kh {
                    let iy = oy + ky;
                    if iy < pad_t || iy >= h + pad_t {
                        continue;
                    }
                    let iy = iy - pad_t;
                    for kx in 0..kw {
                        let ix = ox + kx;
                        if ix < pad_l || ix >= wd + pad_l {
                            continue;
                        }
                        let ix = ix - pad_l;
                        let xbase = ((n * h + iy) * wd + ix) * cin;
                        let wbase = (ky * kw + kx) * cin * cout;
                        for ci in 0..cin {
                            let xv = x.data[xbase + ci];
                            let woff = wbase + ci * cout;
                            let wrow = &w.data[woff..woff + cout];
                            let dwrow = &mut dw[woff..woff + cout];
                            let mut acc = 0.0f32;
                            for co in 0..cout {
                                dwrow[co] += xv * gz[co];
                                acc += wrow[co] * gz[co];
                            }
                            dx[xbase + ci] += acc;
                        }
                    }
                }
            }
        }
    }
    Ok((
        Tensor::new(w.shape.clone(), dw),
        Tensor::new(vec![cout], db),
        Tensor::new(x.shape.clone(), dx),
    ))
}

// ------------------------------------------------------------------- pool

/// 2×2 max pooling, stride 2 (even H, W). Returns the pooled tensor and
/// the flat source index of every pooled element (for the backward pass).
fn maxpool2x2(x: &Tensor) -> (Tensor, Vec<usize>) {
    let (bsz, h, wd, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (oh, ow) = (h / 2, wd / 2);
    let mut out = vec![0.0f32; bsz * oh * ow * c];
    let mut idx = vec![0usize; out.len()];
    for n in 0..bsz {
        for oy in 0..oh {
            for ox in 0..ow {
                for cc in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut best_i = 0usize;
                    for dy in 0..2 {
                        for dxo in 0..2 {
                            let src =
                                ((n * h + 2 * oy + dy) * wd + 2 * ox + dxo) * c + cc;
                            let v = x.data[src];
                            if v > best {
                                best = v;
                                best_i = src;
                            }
                        }
                    }
                    let o = ((n * oh + oy) * ow + ox) * c + cc;
                    out[o] = best;
                    idx[o] = best_i;
                }
            }
        }
    }
    (Tensor::new(vec![bsz, oh, ow, c], out), idx)
}

// ------------------------------------------------------------------- loss

/// Mean softmax cross-entropy and ∂L/∂logits for int labels.
fn ce_loss_and_grad(logits: &Tensor, y: &[i32], ncls: usize) -> Result<(f32, Tensor)> {
    let bsz = logits.shape[0];
    if logits.shape != [bsz, ncls] {
        bail!("loss: logits {:?} vs ncls {ncls}", logits.shape);
    }
    let mut loss = 0.0f32;
    let mut grad = vec![0.0f32; bsz * ncls];
    let inv_b = 1.0 / bsz as f32;
    for i in 0..bsz {
        let label = y[i];
        if label < 0 || label as usize >= ncls {
            bail!("label {label} out of range 0..{ncls}");
        }
        let row = &logits.data[i * ncls..(i + 1) * ncls];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let sum: f32 = row.iter().map(|&v| (v - m).exp()).sum();
        let lse = m + sum.ln();
        loss += lse - row[label as usize];
        let g = &mut grad[i * ncls..(i + 1) * ncls];
        for (j, gv) in g.iter_mut().enumerate() {
            let p = (row[j] - lse).exp();
            *gv = (p - if j == label as usize { 1.0 } else { 0.0 }) * inv_b;
        }
    }
    Ok((loss * inv_b, Tensor::new(vec![bsz, ncls], grad)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn backend() -> ReferenceBackend {
        ReferenceBackend::new()
    }

    #[test]
    fn conv_pool_layer_matches_hand_value() {
        // mirror of the PJRT `engine_runs_a_layer_artifact` oracle
        let be = backend();
        let arch = be.arch("cnn5").unwrap();
        let x = Tensor::full(vec![1, 16, 16, 1], 0.5);
        let w = Tensor::full(vec![3, 3, 1, 8], 0.1);
        let b = Tensor::zeros(vec![8]);
        let y = be.run_layer(&arch, 0, None, &x, &w, &b).unwrap();
        assert_eq!(y.shape, vec![1, 8, 8, 8]);
        // conv(0.5, 0.1 kernel) interior = 9*0.5*0.1 = 0.45; pooled max > 0
        assert!(y.data.iter().all(|&v| v > 0.0));
        assert!(y.data.iter().any(|&v| (v - 0.45).abs() < 1e-5));
        assert_eq!(be.layer_exec_count(), 1);
    }

    #[test]
    fn dense_layer_computes_affine_leaky() {
        let be = backend();
        let arch = be.arch("dnn4").unwrap();
        // din=128 for layer 0; use w = 0 except first row → y depends on x[0]
        let mut wdat = vec![0.0f32; 128 * 64];
        wdat[0] = 2.0; // w[0][0]
        let w = Tensor::new(vec![128, 64], wdat);
        let b = Tensor::full(vec![64], 0.5);
        let mut xdat = vec![0.0f32; 128];
        xdat[0] = -1.0;
        let x = Tensor::new(vec![1, 128], xdat);
        let y = be.run_layer(&arch, 0, None, &x, &w, &b).unwrap();
        // y[0] = leaky(-2 + 0.5) = 0.01 * -1.5; y[1..] = 0.5
        assert!((y.data[0] - (-0.015)).abs() < 1e-6);
        assert!((y.data[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn maxpool_routes_to_argmax() {
        let x = Tensor::new(
            vec![1, 2, 2, 1],
            vec![1.0, 4.0, 3.0, 2.0], // (0,0)=1 (0,1)=4 (1,0)=3 (1,1)=2
        );
        let (p, idx) = maxpool2x2(&x);
        assert_eq!(p.shape, vec![1, 1, 1, 1]);
        assert_eq!(p.data, vec![4.0]);
        assert_eq!(idx, vec![1]);
    }

    #[test]
    fn softmax_loss_and_grad_sum_to_zero() {
        let logits = Tensor::new(vec![2, 3], vec![1.0, 2.0, 0.5, -1.0, 0.0, 3.0]);
        let (loss, grad) = ce_loss_and_grad(&logits, &[1, 2], 3).unwrap();
        assert!(loss > 0.0);
        // each row of the softmax-CE gradient sums to zero
        for i in 0..2 {
            let s: f32 = grad.data[i * 3..(i + 1) * 3].iter().sum();
            assert!(s.abs() < 1e-6, "row {i} sums to {s}");
        }
        // the true-label entry is negative (probability < 1)
        assert!(grad.data[1] < 0.0);
        assert!(grad.data[3 + 2] < 0.0);
    }

    /// Finite-difference gradient check of the whole train_step backward
    /// pass, through conv+pool+leaky and dense layers alike.
    #[test]
    fn train_step_gradients_match_finite_differences() {
        let be = backend();
        for arch_name in ["dnn4", "cnn5"] {
            let arch = be.arch(arch_name).unwrap();
            let ncls = 2usize;
            let mut rng = Pcg32::seed(0x9A0 + arch.n_layers() as u64);
            let params: Vec<Tensor> = arch
                .flat_param_shapes(ncls)
                .into_iter()
                .map(|s| Tensor::he_init(s, &mut rng))
                .collect();
            let bsz = 3usize;
            let mut xshape = vec![bsz];
            xshape.extend_from_slice(&arch.input);
            let n: usize = xshape.iter().product();
            let x = Tensor::new(
                xshape,
                (0..n).map(|_| rng.gauss() * 0.5).collect(),
            );
            let y: Vec<i32> = (0..bsz).map(|i| (i % ncls) as i32).collect();

            // analytic gradient via the SGD update: g = (before - after)/lr
            let lr = 1e-3f32;
            let mut stepped = params.clone();
            be.train_step(&arch, ncls, &mut stepped, &x, &y, lr).unwrap();

            // probe a few parameter coordinates across tensors
            for (ti, off) in [(0usize, 0usize), (0, 3), (2, 1)] {
                let g_analytic =
                    (params[ti].data[off] - stepped[ti].data[off]) / lr;
                let eps = 1e-2f32;
                let mut plus = params.clone();
                plus[ti].data[off] += eps;
                let mut minus = params.clone();
                minus[ti].data[off] -= eps;
                let lp = be.loss(&arch, ncls, &plus, &x, &y).unwrap();
                let lm = be.loss(&arch, ncls, &minus, &x, &y).unwrap();
                let g_numeric = (lp - lm) / (2.0 * eps);
                let tol = 1e-2f32.max(0.15 * g_numeric.abs());
                assert!(
                    (g_analytic - g_numeric).abs() < tol,
                    "{arch_name} param {ti}[{off}]: analytic {g_analytic} vs numeric {g_numeric}"
                );
            }
        }
    }

    #[test]
    fn training_reduces_loss_on_separable_toy_task() {
        let be = backend();
        let arch = be.arch("dnn4").unwrap();
        let mut rng = Pcg32::seed(77);
        let mut params: Vec<Tensor> = arch
            .flat_param_shapes(2)
            .into_iter()
            .map(|s| Tensor::he_init(s, &mut rng))
            .collect();
        let mut losses = Vec::new();
        for _ in 0..100 {
            // label = sign of the mean of the first 8 features
            let bsz = 32;
            let mut xd = Vec::with_capacity(bsz * 128);
            let mut y = Vec::with_capacity(bsz);
            for _ in 0..bsz {
                let row: Vec<f32> = (0..128).map(|_| rng.gauss()).collect();
                let m: f32 = row[..8].iter().sum::<f32>() / 8.0;
                y.push((m > 0.0) as i32);
                xd.extend(row);
            }
            let x = Tensor::new(vec![bsz, 128], xd);
            losses.push(be.train_step(&arch, 2, &mut params, &x, &y, 0.05).unwrap());
        }
        let head = losses[..5].iter().sum::<f32>() / 5.0;
        let tail = losses[losses.len() - 5..].iter().sum::<f32>() / 5.0;
        assert!(
            tail < head * 0.7,
            "loss did not fall: {head} -> {tail}"
        );
    }

    #[test]
    fn eval_matches_layerwise_execution_exactly() {
        // blockwise (run_layer chain) and whole-net eval must agree bit-
        // for-bit: both walk the same kernels in the same order
        let be = backend();
        let arch = be.arch("cnn5").unwrap();
        let mut rng = Pcg32::seed(21);
        let params: Vec<Tensor> = arch
            .flat_param_shapes(3)
            .into_iter()
            .map(|s| Tensor::he_init(s, &mut rng))
            .collect();
        let x = Tensor::new(
            vec![2, 16, 16, 1],
            (0..512).map(|_| rng.gauss()).collect(),
        );
        let whole = be.eval_logits(&arch, 3, &params, &x).unwrap();
        let mut cur = x;
        for l in 0..arch.n_layers() {
            let is_logits = arch.layers[l].is_logits();
            cur = be
                .run_layer(
                    &arch,
                    l,
                    is_logits.then_some(3),
                    &cur,
                    &params[2 * l],
                    &params[2 * l + 1],
                )
                .unwrap();
        }
        assert_eq!(whole, cur);
    }

    /// Cross-frame batching contract: batched execution must be bitwise
    /// identical, row for row, to running every sample alone — the
    /// sharded/batched serving path depends on this to keep predictions
    /// frame-for-frame equal to the single-executor loop. Batch size 7
    /// exercises all three block widths (4 + 2 + 1).
    #[test]
    fn batched_forward_matches_per_sample_rows_exactly() {
        let be = backend();
        let arch = be.arch("cnn5").unwrap();
        let mut rng = Pcg32::seed(0xBA7C);
        let bsz = 7usize;
        let x = Tensor::new(
            vec![bsz, 16, 16, 1],
            (0..bsz * 256).map(|_| rng.gauss()).collect(),
        );
        // walk conv/pool + dense + logits layers through the whole net
        let params: Vec<Tensor> = arch
            .flat_param_shapes(3)
            .into_iter()
            .map(|s| Tensor::he_init(s, &mut rng))
            .collect();
        let mut batched = x.clone();
        let mut singles: Vec<Tensor> =
            (0..bsz).map(|i| x.slice_batch(i, 1)).collect();
        for l in 0..arch.n_layers() {
            let is_logits = arch.layers[l].is_logits();
            let ncls = is_logits.then_some(3);
            batched = be
                .run_layer(&arch, l, ncls, &batched, &params[2 * l], &params[2 * l + 1])
                .unwrap();
            for s in singles.iter_mut() {
                *s = be
                    .run_layer(&arch, l, ncls, s, &params[2 * l], &params[2 * l + 1])
                    .unwrap();
            }
            for (i, s) in singles.iter().enumerate() {
                assert_eq!(
                    batched.slice_batch(i, 1).data,
                    s.data,
                    "layer {l} row {i} diverged from per-sample execution"
                );
            }
        }
    }

    #[test]
    fn dense_block_widths_agree_exactly() {
        // every batch size from 1 to 9 must produce identical rows — the
        // 4/2/1 block dispatch must be invisible
        let mut rng = Pcg32::seed(0xDE45);
        let w = Tensor::he_init(vec![32, 16], &mut rng);
        let b = Tensor::new(vec![16], (0..16).map(|i| i as f32 * 0.01).collect());
        let x9 = Tensor::new(
            vec![9, 32],
            (0..9 * 32).map(|_| rng.gauss()).collect(),
        );
        let full = dense_raw(&x9, &w, &b).unwrap();
        for bsz in 1..=9usize {
            let xs = x9.slice_batch(0, bsz);
            let ys = dense_raw(&xs, &w, &b).unwrap();
            assert_eq!(ys.data, full.data[..bsz * 16], "bsz {bsz}");
        }
    }

    #[test]
    fn rejects_bad_shapes_and_labels() {
        let be = backend();
        let arch = be.arch("dnn4").unwrap();
        let mut rng = Pcg32::seed(1);
        let mut params: Vec<Tensor> = arch
            .flat_param_shapes(2)
            .into_iter()
            .map(|s| Tensor::he_init(s, &mut rng))
            .collect();
        let x = Tensor::zeros(vec![2, 128]);
        // wrong arity
        assert!(be.eval_logits(&arch, 2, &params[1..], &x).is_err());
        // out-of-range label
        assert!(be.train_step(&arch, 2, &mut params, &x, &[0, 5], 0.1).is_err());
        // label count mismatch
        assert!(be.train_step(&arch, 2, &mut params, &x, &[0], 0.1).is_err());
    }
}
