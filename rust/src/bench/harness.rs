//! Wall-clock micro-benchmark harness (the offline mirror has no
//! criterion): warmup, fixed iteration count, percentile summary. Used by
//! the `cargo bench` targets (`harness = false`).

use std::time::Instant;

use crate::util::stats;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{:.0}ns", ns)
    } else if ns < 1_000_000.0 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Benchmark `f` with `warmup` unmeasured and `iters` measured calls.
pub fn bench_fn<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: stats::mean(&samples),
        p50_ns: stats::percentile(&samples, 50.0),
        p95_ns: stats::percentile(&samples, 95.0),
        min_ns: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        max_ns: samples.iter().cloned().fold(0.0, f64::max),
    };
    r.report();
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_counts_and_orders() {
        let mut n = 0u64;
        let r = bench_fn("noop", 2, 25, || n += 1);
        assert_eq!(n, 27);
        assert_eq!(r.iters, 25);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.max_ns);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50us");
        assert_eq!(fmt_ns(2_500_000.0), "2.50ms");
        assert_eq!(fmt_ns(3_200_000_000.0), "3.200s");
    }
}
