//! Simulation-only figure/table drivers (no training needed): Fig. 3,
//! Fig. 7, Fig. 8, Table 3, Fig. 9, Fig. 10, Fig. 11, Table 4.
//!
//! Cost figures use the *selected* task graph per dataset, chosen from
//! affinity-guided enumeration over a synthetic affinity tensor seeded
//! per dataset (training-derived affinity is exercised by the fig12/15
//! drivers and the examples; the cost figures only need graph *shape*).

use anyhow::Result;

use super::{fmt_energy, fmt_time, print_table};
use crate::affinity::{synthetic_affinity, AffinityTensor};
use crate::baselines::{self, SystemKind};
use crate::data::standard_datasets;
use crate::device::Device;
use crate::model::{manifest::default_artifacts_dir, ArchSpec};
use crate::ordering::{solve_genetic, solve_held_karp, GaConfig};
use crate::taskgraph::select::{
    budget_extremes, score_graph, select_tradeoff, tradeoff_curve, GraphScore,
};
use crate::taskgraph::{enumerate, TaskGraph};
use crate::tsplib::{table3_instances, Variant};
use crate::util::cli::Args;
use crate::util::rng::Pcg32;

/// Arch specs come from the manifest when artifacts are built, otherwise
/// from the built-in registry (`model::archs`) so the sim figures work
/// standalone.
pub fn arch_specs() -> std::collections::BTreeMap<String, ArchSpec> {
    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        if let Ok(m) = crate::model::manifest::Manifest::load(&dir) {
            return m.archs;
        }
    }
    crate::model::archs::builtin_archs()
}

/// Score a dataset's candidate graphs under a device; shared by several
/// drivers.
pub fn dataset_scores(
    ds_name: &str,
    arch: &ArchSpec,
    n_tasks: usize,
    seed: u64,
    device: &Device,
    branch_points: usize,
    max_graphs: usize,
) -> (AffinityTensor, Vec<GraphScore>) {
    let bounds = TaskGraph::default_bounds(arch.n_layers(), branch_points);
    let mut rng = Pcg32::seed(seed ^ 0xD5);
    let aff = synthetic_affinity(n_tasks, bounds.len(), &mut rng);
    let graphs = if n_tasks <= 5 {
        enumerate::enumerate_all(n_tasks, &bounds, Some(max_graphs))
    } else {
        enumerate::clustered(&aff, &bounds, max_graphs)
    };
    let ncls = vec![2usize; n_tasks];
    let scores = graphs
        .iter()
        .map(|g| score_graph(g, &aff, arch, &ncls, device))
        .collect();
    let _ = ds_name;
    (aff, scores)
}

// ------------------------------------------------------------------ fig3

/// Fig. 3: variety vs execution cost tradeoff as the model-size budget
/// sweeps, for five image tasks on the 5-layer CNN.
pub fn fig3_tradeoff(args: &Args) -> Result<()> {
    let archs = arch_specs();
    let arch = &archs["cnn5"];
    let device = Device::msp430();
    let max_graphs = args.usize("max-graphs", 2000);
    let (_aff, scores) =
        dataset_scores("mnist-s", arch, 5, 42, &device, 3, max_graphs);
    let curve = tradeoff_curve(&scores);
    let chosen = select_tradeoff(&scores);
    println!(
        "Fig 3: {} candidate graphs, budget sweep ({} points); * = selected",
        scores.len(),
        curve.len()
    );
    let rows: Vec<Vec<String>> = curve
        .iter()
        .map(|p| {
            vec![
                format!("{:.1}KB", p.budget_bytes as f64 / 1024.0),
                format!("{:.3}", p.variety_norm),
                format!("{:.3}", p.cost_norm),
                if p.pick == chosen { "*".into() } else { "".into() },
            ]
        })
        .collect();
    print_table(&["budget", "variety(norm)", "exec-cost(norm)", "sel"], &rows);
    let s = &scores[chosen];
    println!(
        "selected: variety={:.3} size={:.1}KB round={}",
        s.variety,
        s.model_bytes as f64 / 1024.0,
        fmt_time(s.exec_time)
    );
    Ok(())
}

// ------------------------------------------------------------------ fig7

/// Fig. 7: branch point count BP ∈ {3,5,7} vs variety and overhead.
pub fn fig7_branch_points(args: &Args) -> Result<()> {
    let archs = arch_specs();
    let device = Device::msp430();
    let max_graphs = args.usize("max-graphs", 400);
    let mut rows = Vec::new();
    for ds in standard_datasets() {
        let arch = &archs[ds.arch];
        for bp in [3usize, 5, 7] {
            let eff_bp = bp.min(arch.n_layers() - 1);
            let (_a, scores) = dataset_scores(
                ds.name, arch, ds.n_classes, ds.seed + bp as u64, &device,
                eff_bp, max_graphs,
            );
            let sel = select_tradeoff(&scores);
            rows.push(vec![
                ds.name.to_string(),
                format!("{bp}{}", if eff_bp != bp { "(clamped)" } else { "" }),
                format!("{:.3}", scores[sel].variety),
                fmt_time(scores[sel].exec_time),
            ]);
        }
    }
    println!("Fig 7: branch points vs variety (lower=better) and overhead");
    print_table(&["dataset", "BP", "variety", "round-time"], &rows);
    Ok(())
}

// ------------------------------------------------------------------ fig8

/// Fig. 8: variety vs execution cost at min / tradeoff / max budget.
pub fn fig8_budget_tradeoff(args: &Args) -> Result<()> {
    let archs = arch_specs();
    let device = Device::msp430();
    let max_graphs = args.usize("max-graphs", 400);
    let mut rows = Vec::new();
    for ds in standard_datasets() {
        let arch = &archs[ds.arch];
        let (_a, scores) =
            dataset_scores(ds.name, arch, ds.n_classes, ds.seed, &device, 3, max_graphs);
        let (lo, mid, hi) = budget_extremes(&scores);
        for (label, i) in [("min", lo), ("tradeoff", mid), ("max", hi)] {
            rows.push(vec![
                ds.name.to_string(),
                label.to_string(),
                format!("{:.3}", scores[i].variety),
                fmt_time(scores[i].exec_time),
                format!("{:.1}KB", scores[i].model_bytes as f64 / 1024.0),
            ]);
        }
    }
    println!("Fig 8: budget extremes vs the selected tradeoff point");
    print_table(&["dataset", "budget", "variety", "round-time", "size"], &rows);
    Ok(())
}

// ---------------------------------------------------------------- table3

/// Table 3: genetic algorithm vs exact optimum on the TSPLIB-style
/// ordering instances (regular / precedence / conditional).
pub fn table3_ga(args: &Args) -> Result<()> {
    let seed = args.u64("seed", 0xA417);
    let mut rows = Vec::new();
    for inst in table3_instances() {
        let optimal = solve_held_karp(&inst.problem)
            .expect("feasible instance")
            .cost;
        let ga = solve_genetic(&inst.problem, &GaConfig { seed, ..Default::default() })
            .expect("ga solution");
        let variant = match inst.variant {
            Variant::Regular => "Regular",
            Variant::Precedence => "Precedence",
            Variant::Conditional => "Conditional",
        };
        rows.push(vec![
            variant.to_string(),
            inst.name.to_string(),
            format!("{}/{}/{}", inst.nodes, inst.n_precedence, inst.n_conditional),
            format!("{:.0}", optimal),
            format!("{:.0}", ga.cost),
            format!("{:+.1}%", (ga.cost / optimal - 1.0) * 100.0),
        ]);
    }
    println!("Table 3: GA vs exact optimal task ordering");
    print_table(
        &["variant", "instance", "node/pre/cnd", "optimal", "antler(GA)", "gap"],
        &rows,
    );
    Ok(())
}

// ------------------------------------------------------------- fig9/fig10

fn comparison(args: &Args, energy: bool) -> Result<()> {
    let archs = arch_specs();
    let max_graphs = args.usize("max-graphs", 400);
    for device in [Device::msp430(), Device::stm32h747()] {
        println!(
            "\nFig {}: per-input all-task {} on {}",
            if energy { 10 } else { 9 },
            if energy { "energy" } else { "execution time" },
            device.name
        );
        let mut rows = Vec::new();
        for ds in standard_datasets() {
            let arch = &archs[ds.arch];
            let (_a, scores) = dataset_scores(
                ds.name, arch, ds.n_classes, ds.seed, &device, 3, max_graphs,
            );
            let sel = select_tradeoff(&scores);
            let graph = &scores[sel].graph;
            let ncls = vec![2usize; ds.n_classes];
            let net_bytes = arch.total_params(2) * 4;
            let inp = baselines::CostInputs {
                device: &device,
                arch,
                ncls: &ncls,
                antler_graph: graph,
                antler_order: &scores[sel].order,
                nws_ext_bytes_per_task: (net_bytes as f64 * 0.07) as usize,
            };
            let mut row = vec![ds.name.to_string()];
            let mut antler_v = 0.0;
            let mut worst: f64 = 0.0;
            for sys in SystemKind::all() {
                let c = baselines::round_cost(sys, &inp);
                let v = if energy { c.energy() } else { c.time() };
                if sys == SystemKind::Antler {
                    antler_v = v;
                }
                worst = worst.max(v);
                row.push(if energy { fmt_energy(v) } else { fmt_time(v) });
            }
            row.push(format!("{:.1}x", worst / antler_v.max(1e-12)));
            rows.push(row);
        }
        print_table(
            &["dataset", "Vanilla", "Antler", "NWV", "NWS", "YONO", "win"],
            &rows,
        );
    }
    Ok(())
}

/// Fig. 9: execution time vs baselines, both platforms.
pub fn fig9_time(args: &Args) -> Result<()> {
    comparison(args, false)
}

/// Fig. 10: energy vs baselines, both platforms.
pub fn fig10_energy(args: &Args) -> Result<()> {
    comparison(args, true)
}

// ----------------------------------------------------------------- fig11

/// Fig. 11: time/energy split into inference vs weight-reload overhead
/// for Antler / Vanilla / NWS, averaged over datasets, per platform.
pub fn fig11_breakdown(args: &Args) -> Result<()> {
    let archs = arch_specs();
    let max_graphs = args.usize("max-graphs", 400);
    for device in [Device::msp430(), Device::stm32h747()] {
        let mut acc: std::collections::BTreeMap<&str, (f64, f64, f64, f64)> =
            Default::default();
        let mut n_ds = 0.0;
        for ds in standard_datasets() {
            let arch = &archs[ds.arch];
            let (_a, scores) = dataset_scores(
                ds.name, arch, ds.n_classes, ds.seed, &device, 3, max_graphs,
            );
            let sel = select_tradeoff(&scores);
            let ncls = vec![2usize; ds.n_classes];
            let net_bytes = arch.total_params(2) * 4;
            let inp = baselines::CostInputs {
                device: &device,
                arch,
                ncls: &ncls,
                antler_graph: &scores[sel].graph,
                antler_order: &scores[sel].order,
                nws_ext_bytes_per_task: (net_bytes as f64 * 0.07) as usize,
            };
            for sys in [SystemKind::Vanilla, SystemKind::Antler, SystemKind::Nws] {
                let c = baselines::round_cost(sys, &inp);
                let e = acc.entry(sys.name()).or_default();
                e.0 += c.exec_s;
                e.1 += c.load_s;
                e.2 += c.exec_j;
                e.3 += c.load_j;
            }
            n_ds += 1.0;
        }
        println!("\nFig 11 ({}): inference vs reload breakdown (mean over datasets)", device.name);
        let rows: Vec<Vec<String>> = acc
            .iter()
            .map(|(name, (es, ls, ej, lj))| {
                vec![
                    name.to_string(),
                    fmt_time(es / n_ds),
                    fmt_time(ls / n_ds),
                    format!("{:.1}%", ls / (es + ls) * 100.0),
                    fmt_energy(ej / n_ds),
                    fmt_energy(lj / n_ds),
                ]
            })
            .collect();
        print_table(
            &["system", "inference", "reload", "reload%", "inf-energy", "reload-energy"],
            &rows,
        );
    }
    Ok(())
}

// ---------------------------------------------------------------- table4

/// Table 4: total weight memory per system (10-task cnn5 set, packed
/// budgets from the mechanism transforms on He-initialized nets — packing
/// geometry does not depend on training).
pub fn table4_memory(args: &Args) -> Result<()> {
    let archs = arch_specs();
    let arch = &archs["cnn5"];
    let device = Device::msp430();
    let n = 10usize;
    let ncls = vec![2usize; n];
    let (_a, scores) =
        dataset_scores("mnist-s", arch, n, 42, &device, 3, args.usize("max-graphs", 400));
    let sel = select_tradeoff(&scores);
    let mut rng = Pcg32::seed(4);
    let per_task: Vec<Vec<crate::model::Tensor>> = (0..n)
        .map(|_| {
            arch.flat_param_shapes(2)
                .into_iter()
                .map(|s| crate::model::Tensor::he_init(s, &mut rng))
                .collect()
        })
        .collect();
    let ram_budget = 128 * 1024; // the in-memory systems' RAM budget
    let nwv = baselines::nwv_pack(&per_task, ram_budget, 256, &mut rng);
    let nws = baselines::nws_pack(&per_task, ram_budget, 0.07, 256, &mut rng);
    let yono = baselines::yono_pack(&per_task, 8, 256, &mut rng);
    let rows: Vec<Vec<String>> = [
        ("Vanilla", baselines::memory_bytes(SystemKind::Vanilla, arch, &ncls, &scores[sel].graph, None, 0)),
        ("Antler", baselines::memory_bytes(SystemKind::Antler, arch, &ncls, &scores[sel].graph, None, 0)),
        ("NWS", nws.ram_bytes + nws.ext_bytes_per_task * n),
        ("NWV", nwv.ram_bytes),
        ("YONO", yono.ram_bytes),
    ]
    .iter()
    .map(|(name, bytes)| vec![name.to_string(), format!("{:.0}KB", *bytes as f64 / 1024.0)])
    .collect();
    println!("Table 4: weight memory consumption (10 tasks, cnn5)");
    print_table(&["system", "memory"], &rows);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args() -> Args {
        Args::parse(["x", "--max-graphs", "120"].iter().map(|s| s.to_string()))
    }

    #[test]
    fn all_sim_drivers_run() {
        let a = args();
        fig3_tradeoff(&a).unwrap();
        fig8_budget_tradeoff(&a).unwrap();
        table4_memory(&a).unwrap();
    }

    #[test]
    fn fig9_shape_antler_wins() {
        // the headline claim: Antler's round cost is the lowest of all
        // five systems on both platforms, for every dataset
        let archs = arch_specs();
        for device in [Device::msp430(), Device::stm32h747()] {
            for ds in standard_datasets().into_iter().take(3) {
                let arch = &archs[ds.arch];
                let (_a, scores) =
                    dataset_scores(ds.name, arch, ds.n_classes, ds.seed, &device, 3, 150);
                let sel = select_tradeoff(&scores);
                let ncls = vec![2usize; ds.n_classes];
                let inp = baselines::CostInputs {
                    device: &device,
                    arch,
                    ncls: &ncls,
                    antler_graph: &scores[sel].graph,
                    antler_order: &scores[sel].order,
                    nws_ext_bytes_per_task: (arch.total_params(2) * 4) * 7 / 100,
                };
                let antler =
                    baselines::round_cost(SystemKind::Antler, &inp).time();
                for sys in [SystemKind::Vanilla, SystemKind::Nwv, SystemKind::Nws, SystemKind::Yono] {
                    let t = baselines::round_cost(sys, &inp).time();
                    assert!(
                        antler <= t * 1.001,
                        "{} {} {}: antler {} vs {}",
                        device.name,
                        ds.name,
                        sys.name(),
                        antler,
                        t
                    );
                }
            }
        }
    }

    #[test]
    fn table3_ga_close_to_optimal() {
        for inst in table3_instances() {
            let optimal = solve_held_karp(&inst.problem).unwrap().cost;
            let ga =
                solve_genetic(&inst.problem, &GaConfig::default()).unwrap();
            assert!(
                ga.cost <= optimal * 1.08 + 1e-9,
                "{}: ga {} vs opt {}",
                inst.name,
                ga.cost,
                optimal
            );
        }
    }
}
