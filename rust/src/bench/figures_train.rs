//! Training-backed figure drivers: Fig. 12 accuracy comparison, and the
//! §7 deployment set Fig. 14/15/16 + Table 5. Runs on whichever backend
//! `ANTLER_BACKEND` selects — the pure-Rust reference interpreter needs
//! no artifacts; `make artifacts` + the `pjrt` feature switches to the
//! AOT path. Step counts are CLI-tunable; defaults are sized for a
//! single-core CI run.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{anyhow, Result};

use super::{fmt_energy, fmt_time, print_table};
use crate::baselines;
use crate::coordinator::{pipeline, serve, BlockExecutor, ServePlan};
use crate::data::{audio_stream_spec, image_stream_spec, standard_datasets};
use crate::device::Device;
use crate::runtime::{backend_from_env, Backend};
use crate::taskgraph::TaskGraph;
use crate::trainer::{self, GraphWeights};
use crate::util::cli::Args;
use crate::util::rng::Pcg32;

fn backend() -> Result<Box<dyn Backend>> {
    backend_from_env()
}

fn cfg_from_args(args: &Args, device: Device) -> pipeline::PrepareConfig {
    pipeline::PrepareConfig {
        steps_individual: args.usize("steps-ind", 80),
        steps_retrain: args.usize("steps-re", 100),
        lr: args.f64("lr", 0.05) as f32,
        branch_points: args.usize("bp", 3),
        max_graphs: args.usize("max-graphs", 200),
        device,
        ..Default::default()
    }
}

// ----------------------------------------------------------------- fig12

/// Fig. 12: mean inference accuracy of all five systems per dataset.
/// Vanilla/Antler accuracies come from real training; NWV/NWS/YONO apply
/// their packing transforms to the Vanilla weights and re-evaluate.
pub fn fig12_accuracy(args: &Args) -> Result<()> {
    let be = backend()?;
    let n_datasets = args.usize("datasets", 9);
    let samples = args.usize("samples", 400);
    let mut rows = Vec::new();
    for ds_spec in standard_datasets().into_iter().take(n_datasets) {
        let arch = be.arch(ds_spec.arch)?;
        let ds = ds_spec.generate(&arch.input, samples);
        let cfg = cfg_from_args(args, Device::msp430());
        let prep = pipeline::prepare(be.as_ref(), ds_spec.arch, &ds, &cfg)?;
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;

        // in-memory baselines: pack the Vanilla weights, re-evaluate
        let mut rng = Pcg32::seed(ds_spec.seed ^ 0xFACE);
        let ram_budget = (arch.total_params(2) * 4 * 13) / 10; // 1.3 nets
        let packs = [
            ("NWV", baselines::nwv_pack(&prep.task_params, ram_budget, 256, &mut rng)),
            ("NWS", baselines::nws_pack(&prep.task_params, ram_budget, 0.07, 256, &mut rng)),
            ("YONO", baselines::yono_pack(&prep.task_params, 8, 256, &mut rng)),
        ];
        let mut packed_acc = HashMap::new();
        for (name, pack) in &packs {
            let mut accs = Vec::new();
            for t in 0..ds.n_tasks() {
                let (xt, yt) = {
                    let (_, test) = ds.split();
                    ds.gather(&test, t)
                };
                accs.push(trainer::evaluate(
                    be.as_ref(),
                    &arch,
                    2,
                    &pack.params[t],
                    &xt,
                    &yt,
                )?);
            }
            packed_acc.insert(*name, mean(&accs));
        }
        rows.push(vec![
            ds_spec.name.to_string(),
            format!("{:.1}%", mean(&prep.vanilla_acc) * 100.0),
            format!("{:.1}%", mean(&prep.antler_acc) * 100.0),
            format!("{:.1}%", packed_acc["NWV"] * 100.0),
            format!("{:.1}%", packed_acc["NWS"] * 100.0),
            format!("{:.1}%", packed_acc["YONO"] * 100.0),
        ]);
    }
    println!("Fig 12: mean task accuracy per system");
    print_table(&["dataset", "Vanilla", "Antler", "NWV", "NWS", "YONO"], &rows);
    Ok(())
}

// ------------------------------------------------- deployment shared prep

pub struct DeploymentBundle {
    pub prep: pipeline::Prepared,
    pub data: crate::data::deployment::DeploymentData,
    pub device: Device,
}

thread_local! {
    static DEPLOY_CACHE: RefCell<HashMap<String, Rc<DeploymentBundle>>> =
        RefCell::new(HashMap::new());
}

/// Prepare (and cache per-process) one §7 deployment.
pub fn deployment_bundle(
    which: &str,
    args: &Args,
) -> Result<(Rc<DeploymentBundle>, Box<dyn Backend>)> {
    let be = backend()?;
    let key = format!(
        "{which}-{}-{}",
        args.usize("steps-ind", 80),
        args.usize("steps-re", 100)
    );
    if let Some(b) = DEPLOY_CACHE.with(|c| c.borrow().get(&key).cloned()) {
        return Ok((b, be));
    }
    let (spec, device) = match which {
        "audio" => (audio_stream_spec(), Device::msp430()),
        "image" => (image_stream_spec(), Device::stm32h747()),
        other => return Err(anyhow!("unknown deployment {other}")),
    };
    let data = spec.generate(args.usize("samples", 600));
    let cfg = cfg_from_args(args, device.clone());
    let prep = pipeline::prepare(be.as_ref(), spec.arch, &data, &cfg)?;
    let bundle = Rc::new(DeploymentBundle { prep, data, device });
    DEPLOY_CACHE.with(|c| c.borrow_mut().insert(key, Rc::clone(&bundle)));
    Ok((bundle, be))
}

// ----------------------------------------------------------------- fig14

/// Fig. 14: the selected multitask inference graphs for both deployments.
pub fn fig14_deployment_graphs(args: &Args) -> Result<()> {
    for which in ["audio", "image"] {
        let (b, _be) = deployment_bundle(which, args)?;
        let g = &b.prep.graph;
        println!("\nFig 14 ({which}): bounds {:?}, order {:?}", g.bounds, b.prep.order);
        for (s, p) in g.partitions.iter().enumerate() {
            let layers = g.segment_layers(&b.prep.arch, s);
            println!(
                "  segment {s} (layers {:?}): groups {:?}",
                layers,
                p.groups()
            );
        }
        println!(
            "  blocks={} size={:.0}KB (vanilla {:.0}KB)",
            g.n_blocks(),
            g.model_bytes(&b.prep.arch, &b.prep.ncls) as f64 / 1024.0,
            b.prep
                .ncls
                .iter()
                .map(|&c| b.prep.arch.total_params(c) * 4)
                .sum::<usize>() as f64
                / 1024.0
        );
    }
    Ok(())
}

// ----------------------------------------------------------------- fig15

/// Fig. 15: per-frame time and energy for Vanilla vs Antler, Antler-PC
/// (presence precedence) and Antler-CC (presence conditional, live
/// skipping), on the real serving loop.
pub fn fig15_deployment_cost(args: &Args) -> Result<()> {
    let frames_n = args.usize("frames", 40);
    for which in ["audio", "image"] {
        let (b, be) = deployment_bundle(which, args)?;
        let prep = &b.prep;
        let n = prep.ncls.len();
        let presence = 0usize;

        // orders for the three Antler variants
        let order_free = prep.order.clone();
        let prec: Vec<(usize, usize)> =
            (1..n).map(|t| (presence, t)).collect();
        let order_pc = pipeline::deployment_order(prep, &b.device, prec.clone(), vec![])?;
        let cond: Vec<(usize, usize, f64)> = (1..n)
            .map(|t| (presence, t, b.data.spec.presence_prob))
            .collect();
        let order_cc = pipeline::deployment_order(prep, &b.device, vec![], cond)?;

        let frames: Vec<(u64, crate::model::Tensor)> = (0..frames_n)
            .map(|i| (i as u64, b.data.x.slice_batch(i % b.data.len(), 1)))
            .collect();

        let mut rows = Vec::new();
        let variants: Vec<(&str, TaskGraph, Vec<usize>, Vec<(usize, usize)>)> = vec![
            (
                "Vanilla",
                TaskGraph::disjoint(n, prep.graph.bounds.clone()),
                (0..n).collect(),
                vec![],
            ),
            ("Antler", prep.graph.clone(), order_free, vec![]),
            ("Antler-PC", prep.graph.clone(), order_pc, vec![]),
            (
                "Antler-CC",
                prep.graph.clone(),
                order_cc,
                (1..n).map(|t| (presence, t)).collect(),
            ),
        ];
        for (name, graph, order, conditional) in variants {
            let store = if name == "Vanilla" {
                GraphWeights::from_task_params(&graph, &prep.arch, &prep.task_params)
            } else {
                prep.store.clone()
            };
            let mut ex = BlockExecutor::new(
                be.as_ref(),
                b.device.clone(),
                prep.arch.clone(),
                graph,
                prep.ncls.clone(),
                store,
            );
            ex.warmup()?;
            let plan = ServePlan { order: order.clone(), conditional };
            let report = serve(&mut ex, &plan, frames.clone(), 64, None)?;
            rows.push(vec![
                name.to_string(),
                fmt_time(report.sim_time_per_frame_s),
                fmt_energy(report.sim_energy_per_frame_j),
                format!("{:.1}", report.throughput_fps),
                format!("{:.1}ms", report.latency_p50_ms),
                format!("{}", report.tasks_skipped),
            ]);
        }
        println!("\nFig 15 ({which}, {}): per-frame cost over {frames_n} frames", b.device.name);
        print_table(
            &["system", "sim-time", "sim-energy", "host-fps", "host-p50", "skipped"],
            &rows,
        );
    }
    Ok(())
}

// ----------------------------------------------------------------- fig16

/// Fig. 16: per-task accuracy, Vanilla vs Antler, both deployments.
pub fn fig16_deployment_accuracy(args: &Args) -> Result<()> {
    for which in ["audio", "image"] {
        let (b, _be) = deployment_bundle(which, args)?;
        println!("\nFig 16 ({which}): per-task accuracy");
        let rows: Vec<Vec<String>> = (0..b.prep.ncls.len())
            .map(|t| {
                vec![
                    b.data.spec.tasks[t].name.to_string(),
                    format!("{}", b.prep.ncls[t]),
                    format!("{:.1}%", b.prep.vanilla_acc[t] * 100.0),
                    format!("{:.1}%", b.prep.antler_acc[t] * 100.0),
                    format!(
                        "{:+.1}%",
                        (b.prep.antler_acc[t] - b.prep.vanilla_acc[t]) * 100.0
                    ),
                ]
            })
            .collect();
        print_table(&["task", "classes", "Vanilla", "Antler", "delta"], &rows);
    }
    Ok(())
}

// ---------------------------------------------------------------- table5

/// Table 5: deployment memory usage, Vanilla vs Antler.
pub fn table5_deployment_memory(args: &Args) -> Result<()> {
    let mut rows = Vec::new();
    for which in ["audio", "image"] {
        let (b, _be) = deployment_bundle(which, args)?;
        let vanilla: usize = b
            .prep
            .ncls
            .iter()
            .map(|&c| b.prep.arch.total_params(c) * 4)
            .sum();
        let antler = b.prep.graph.model_bytes(&b.prep.arch, &b.prep.ncls);
        rows.push(vec![
            which.to_string(),
            format!("{:.0}KB", vanilla as f64 / 1024.0),
            format!("{:.0}KB", antler as f64 / 1024.0),
            format!("{:.2}x", vanilla as f64 / antler as f64),
        ]);
    }
    println!("Table 5: deployment memory usage");
    print_table(&["deployment", "Vanilla", "Antler", "reduction"], &rows);
    Ok(())
}
