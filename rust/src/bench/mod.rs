//! Benchmark harness + the drivers that regenerate every table and
//! figure of the paper's evaluation (§6–§7). `antler bench <id>` runs a
//! driver; the `cargo bench` targets call the same drivers plus wall-time
//! micro-benchmarks of the hot paths.

pub mod figures_sim;
pub mod figures_train;
pub mod harness;

pub use harness::{bench_fn, BenchResult};

use crate::util::cli::Args;

/// Dispatch a bench/figure driver by id. Returns false for unknown ids.
pub fn run_driver(id: &str, args: &Args) -> anyhow::Result<bool> {
    match id {
        "fig3" => figures_sim::fig3_tradeoff(args)?,
        "fig7" => figures_sim::fig7_branch_points(args)?,
        "fig8" => figures_sim::fig8_budget_tradeoff(args)?,
        "table3" => figures_sim::table3_ga(args)?,
        "fig9" => figures_sim::fig9_time(args)?,
        "fig10" => figures_sim::fig10_energy(args)?,
        "fig11" => figures_sim::fig11_breakdown(args)?,
        "table4" => figures_sim::table4_memory(args)?,
        "fig12" => figures_train::fig12_accuracy(args)?,
        "fig14" => figures_train::fig14_deployment_graphs(args)?,
        "fig15" => figures_train::fig15_deployment_cost(args)?,
        "fig16" => figures_train::fig16_deployment_accuracy(args)?,
        "table5" => figures_train::table5_deployment_memory(args)?,
        "all-sim" => {
            for id in ["fig3", "fig7", "fig8", "table3", "fig9", "fig10", "fig11", "table4"] {
                println!("\n################ {id} ################");
                run_driver(id, args)?;
            }
        }
        "all" => {
            for id in [
                "fig3", "fig7", "fig8", "table3", "fig9", "fig10", "fig11",
                "table4", "fig12", "fig14", "fig15", "fig16", "table5",
            ] {
                println!("\n################ {id} ################");
                run_driver(id, args)?;
            }
        }
        _ => return Ok(false),
    }
    Ok(true)
}

/// Simple fixed-width table printer used by all drivers.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        println!("{}", s.trim_end());
    };
    line(headers.iter().map(|h| h.to_string()).collect());
    line(widths.iter().map(|w| "-".repeat(*w)).collect());
    for row in rows {
        line(row.clone());
    }
}

/// Pretty time: µs/ms/s with 3 significant digits.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.1}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.2}s", secs)
    }
}

/// Pretty energy: µJ/mJ/J.
pub fn fmt_energy(j: f64) -> String {
    if j < 1e-3 {
        format!("{:.1}uJ", j * 1e6)
    } else if j < 1.0 {
        format!("{:.2}mJ", j * 1e3)
    } else {
        format!("{:.2}J", j)
    }
}
