//! Property-testing mini-framework (the offline mirror has no proptest).
//!
//! `prop_check(name, cases, gen, prop)` draws `cases` inputs from `gen`
//! with a seeded PCG32 and asserts `prop` on each; failures report the
//! generator seed and the case so they replay deterministically:
//! `ANTLER_PROP_SEED=<seed> cargo test <name>` reproduces a failure.

use crate::util::rng::Pcg32;

pub fn prop_seed() -> u64 {
    std::env::var("ANTLER_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xA57_1E5)
}

/// Run a property over `cases` generated inputs. Panics with the failing
/// case's Debug form and its seed on the first violation.
pub fn prop_check<T, G, P>(name: &str, cases: usize, mut gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg32) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let base = prop_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Pcg32::seed(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed}):\n  \
                 {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Generator helpers for common shapes.
pub mod gen {
    use crate::util::rng::Pcg32;

    pub fn usize_in(rng: &mut Pcg32, lo: usize, hi: usize) -> usize {
        rng.range(lo, hi)
    }

    pub fn f32_vec(rng: &mut Pcg32, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.gauss() * scale).collect()
    }

    pub fn permutation(rng: &mut Pcg32, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut v);
        v
    }

    /// Random symmetric cost matrix with zero diagonal, entries in [1, hi).
    pub fn sym_cost_matrix(rng: &mut Pcg32, n: usize, hi: f64) -> Vec<f64> {
        let mut c = vec![0.0; n * n];
        for i in 0..n {
            for j in (i + 1)..n {
                let v = 1.0 + rng.f64() * (hi - 1.0);
                c[i * n + j] = v;
                c[j * n + i] = v;
            }
        }
        c
    }

    /// Random DAG precedence set over n nodes (edges i->j only for i<j in a
    /// random topological relabeling, guaranteeing acyclicity).
    pub fn precedence_dag(rng: &mut Pcg32, n: usize, edges: usize) -> Vec<(usize, usize)> {
        let order = permutation(rng, n);
        let mut set = std::collections::BTreeSet::new();
        let mut tries = 0;
        while set.len() < edges && tries < edges * 20 {
            tries += 1;
            let a = rng.below(n);
            let b = rng.below(n);
            if a == b {
                continue;
            }
            let (pa, pb) = (
                order.iter().position(|&x| x == a).unwrap(),
                order.iter().position(|&x| x == b).unwrap(),
            );
            let (u, v) = if pa < pb { (a, b) } else { (b, a) };
            set.insert((u, v));
        }
        set.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_check_passes_valid_property() {
        prop_check(
            "perm-is-perm",
            50,
            |rng| gen::permutation(rng, 8),
            |p| {
                let mut s = p.clone();
                s.sort_unstable();
                if s == (0..8).collect::<Vec<_>>() {
                    Ok(())
                } else {
                    Err("not a permutation".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn prop_check_reports_failure() {
        prop_check("always-fails", 5, |rng| rng.below(10), |_| Err("nope".into()));
    }

    #[test]
    fn precedence_dag_is_acyclic() {
        prop_check(
            "dag-acyclic",
            30,
            |rng| {
                let n = gen::usize_in(rng, 3, 12);
                (n, gen::precedence_dag(rng, n, n))
            },
            |(n, edges)| {
                // Kahn's algorithm must consume all nodes.
                let mut indeg = vec![0usize; *n];
                for &(_, v) in edges {
                    indeg[v] += 1;
                }
                let mut queue: Vec<usize> =
                    (0..*n).filter(|&i| indeg[i] == 0).collect();
                let mut seen = 0;
                while let Some(u) = queue.pop() {
                    seen += 1;
                    for &(a, b) in edges {
                        if a == u {
                            indeg[b] -= 1;
                            if indeg[b] == 0 {
                                queue.push(b);
                            }
                        }
                    }
                }
                if seen == *n {
                    Ok(())
                } else {
                    Err(format!("cycle detected ({} of {} sorted)", seen, n))
                }
            },
        );
    }
}
