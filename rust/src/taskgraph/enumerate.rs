//! Task graph enumeration (§3.3 Step 2). Two generators:
//!
//! * `enumerate_all` — exhaustive refinement chains, the analog of the
//!   paper's recursive Λ(g) expansion. The space is super-exponential, so
//!   this is used for n ≤ ~6 (Fig. 3's five-task study, the §7
//!   deployments) with an optional cap.
//! * `clustered` — affinity-guided candidates for larger task sets: at
//!   each level, complete-linkage agglomerative clustering *within* the
//!   previous level's groups yields a nested family of partitions; chains
//!   are the products of cut levels. This is the scalable generator the
//!   10-task dataset experiments use (see DESIGN.md, Enumeration scale
//!   note).

use super::graph::TaskGraph;
use super::partition::Partition;
use crate::affinity::AffinityTensor;

/// Exhaustive: all task graphs with `d` branch points over `n` tasks,
/// capped at `limit` (None = unbounded — beware beyond n = 6).
pub fn enumerate_all(n: usize, bounds: &[usize], limit: Option<usize>) -> Vec<TaskGraph> {
    let d = bounds.len();
    let mut out = Vec::new();
    let mut chain: Vec<Partition> = Vec::with_capacity(d + 1);
    rec(n, d, &mut chain, &mut out, limit);
    out.into_iter()
        .map(|partitions| TaskGraph::new(n, bounds.to_vec(), partitions).unwrap())
        .collect()
}

fn rec(
    n: usize,
    d: usize,
    chain: &mut Vec<Partition>,
    out: &mut Vec<Vec<Partition>>,
    limit: Option<usize>,
) {
    if limit.is_some_and(|l| out.len() >= l) {
        return;
    }
    if chain.len() == d {
        let mut full = chain.clone();
        full.push(Partition::singletons(n));
        out.push(full);
        return;
    }
    let candidates = match chain.last() {
        None => Partition::enumerate_all(n),
        Some(prev) => Partition::enumerate_refinements(prev),
    };
    for c in candidates {
        chain.push(c);
        rec(n, d, chain, out, limit);
        chain.pop();
        if limit.is_some_and(|l| out.len() >= l) {
            return;
        }
    }
}

/// Affinity-guided generator for large n: nested clustering candidates
/// per level, chained under the refinement constraint.
pub fn clustered(
    affinity: &AffinityTensor,
    bounds: &[usize],
    max_graphs: usize,
) -> Vec<TaskGraph> {
    let n = affinity.n;
    let d = bounds.len();
    assert_eq!(affinity.d, d, "affinity tensor must match branch points");
    let mut out: Vec<Vec<Partition>> = Vec::new();
    let mut chain: Vec<Partition> = Vec::new();
    rec_clustered(affinity, n, d, &mut chain, &mut out, max_graphs);
    out.into_iter()
        .map(|p| TaskGraph::new(n, bounds.to_vec(), p).unwrap())
        .collect()
}

fn rec_clustered(
    affinity: &AffinityTensor,
    n: usize,
    d: usize,
    chain: &mut Vec<Partition>,
    out: &mut Vec<Vec<Partition>>,
    max_graphs: usize,
) {
    if out.len() >= max_graphs {
        return;
    }
    if chain.len() == d {
        let mut full = chain.clone();
        full.push(Partition::singletons(n));
        out.push(full);
        return;
    }
    let level = chain.len();
    // affinity measured at the branch point *before* this partition's
    // segment; the first (unscored) level reuses the first branch point.
    let rho = level.saturating_sub(1);
    let prev = chain
        .last()
        .cloned()
        .unwrap_or_else(|| Partition::one_group(n));
    for cand in nested_partitions(affinity, rho, &prev) {
        chain.push(cand);
        rec_clustered(affinity, n, d, chain, out, max_graphs);
        chain.pop();
        if out.len() >= max_graphs {
            return;
        }
    }
}

/// Complete-linkage agglomerative clustering constrained to merge only
/// within `coarser`'s groups: returns every cut of the merge tree, from
/// singletons up to `coarser` itself. All results refine `coarser`.
pub fn nested_partitions(
    affinity: &AffinityTensor,
    rho: usize,
    coarser: &Partition,
) -> Vec<Partition> {
    let n = coarser.len();
    // cluster membership as list of task lists
    let mut clusters: Vec<Vec<usize>> = (0..n).map(|t| vec![t]).collect();
    let mut cuts = vec![Partition::singletons(n)];
    loop {
        // find the closest mergeable pair (complete linkage)
        let mut best: Option<(f64, usize, usize)> = None;
        for i in 0..clusters.len() {
            for j in (i + 1)..clusters.len() {
                // same coarser group required
                let gi = coarser.group_of(clusters[i][0]);
                if clusters[j].iter().any(|&t| coarser.group_of(t) != gi) {
                    continue;
                }
                let mut dist = 0.0f64;
                for &a in &clusters[i] {
                    for &b in &clusters[j] {
                        dist = dist.max(affinity.dissimilarity(rho, a, b));
                    }
                }
                if best.map_or(true, |(bd, _, _)| dist < bd) {
                    best = Some((dist, i, j));
                }
            }
        }
        let Some((_, i, j)) = best else { break };
        let merged = clusters.remove(j);
        clusters[i].extend(merged);
        // materialize the cut
        let mut ids = vec![0usize; n];
        for (g, c) in clusters.iter().enumerate() {
            for &t in c {
                ids[t] = g;
            }
        }
        cuts.push(Partition::canonicalize(&ids));
    }
    cuts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::affinity::synthetic_affinity;
    use crate::util::rng::Pcg32;

    #[test]
    fn exhaustive_counts_small() {
        // n=2, d=1: chains = partitions of 2 = 2 graphs
        assert_eq!(enumerate_all(2, &[1], None).len(), 2);
        // n=3, d=1: Bell(3) = 5
        assert_eq!(enumerate_all(3, &[2], None).len(), 5);
        // n=3, d=2: sum over P0 of #refinements(P0) = 5+…= known value 12
        let g = enumerate_all(3, &[1, 2], None);
        assert_eq!(g.len(), 12);
    }

    #[test]
    fn exhaustive_graphs_are_valid_and_unique() {
        let graphs = enumerate_all(4, &[1, 3], None);
        let set: std::collections::HashSet<_> = graphs.iter().cloned().collect();
        assert_eq!(set.len(), graphs.len());
        // extremes are present
        assert!(graphs.iter().any(|g| g.partitions[0].n_groups() == 1
            && g.partitions[1].n_groups() == 1));
        assert!(graphs
            .iter()
            .any(|g| g.partitions.iter().all(|p| p.is_identity())));
    }

    #[test]
    fn limit_caps_output() {
        assert_eq!(enumerate_all(5, &[1, 3, 4], Some(100)).len(), 100);
    }

    #[test]
    fn nested_partitions_refine_and_include_extremes() {
        let mut rng = Pcg32::seed(17);
        let aff = synthetic_affinity(6, 3, &mut rng);
        let coarse = Partition::one_group(6);
        let cuts = nested_partitions(&aff, 0, &coarse);
        assert_eq!(cuts.len(), 6); // singletons .. one group
        for c in &cuts {
            assert!(c.refines(&coarse));
        }
        assert!(cuts.first().unwrap().is_identity());
        assert_eq!(cuts.last().unwrap().n_groups(), 1);
    }

    #[test]
    fn nested_respects_group_boundaries() {
        let mut rng = Pcg32::seed(19);
        let aff = synthetic_affinity(5, 2, &mut rng);
        let coarse = Partition(vec![0, 0, 1, 1, 1]);
        for cut in nested_partitions(&aff, 0, &coarse) {
            assert!(cut.refines(&coarse), "{:?}", cut);
        }
    }

    #[test]
    fn clustered_generates_valid_graphs_for_ten_tasks() {
        let mut rng = Pcg32::seed(23);
        let aff = synthetic_affinity(10, 3, &mut rng);
        let graphs = clustered(&aff, &[1, 3, 4], 500);
        assert!(!graphs.is_empty());
        assert!(graphs.len() <= 500);
        for g in &graphs {
            assert_eq!(g.n_tasks, 10);
            // validity is enforced by TaskGraph::new; spot-check refinement
            for s in 0..g.d() {
                assert!(g.partitions[s + 1].refines(&g.partitions[s]));
            }
        }
        // the family must contain both compact and dispersed graphs
        let min_blocks = graphs.iter().map(|g| g.n_blocks()).min().unwrap();
        let max_blocks = graphs.iter().map(|g| g.n_blocks()).max().unwrap();
        assert!(min_blocks < max_blocks);
    }
}
