//! Task graphs (§2.2, §3): compact shared-structure representations of a
//! multitask set, their quality metrics, enumeration, and selection.

pub mod enumerate;
pub mod graph;
pub mod partition;
pub mod select;

pub use graph::{Block, TaskGraph};
pub use partition::Partition;

/// Deal `n_tasks` task ids across `n_tenants` round-robin: tenant `t`
/// takes every task `i` with `i % n_tenants == t`. When there are more
/// tenants than tasks, the surplus tenants wrap and take the FULL task
/// set instead of an empty one — a tenant with nothing to serve is a
/// configuration accident, not a useful plan. Every subset preserves
/// ascending task order, so the identity-fallback plan for a subset is
/// well-defined.
pub fn tenant_task_split(n_tasks: usize, n_tenants: usize) -> Vec<Vec<usize>> {
    let nt = n_tenants.max(1);
    (0..nt)
        .map(|t| {
            let own: Vec<usize> =
                (0..n_tasks).filter(|i| i % nt == t).collect();
            if own.is_empty() {
                (0..n_tasks).collect()
            } else {
                own
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::tenant_task_split;

    #[test]
    fn split_partitions_tasks_round_robin() {
        assert_eq!(
            tenant_task_split(5, 2),
            vec![vec![0, 2, 4], vec![1, 3]]
        );
        // one tenant owns everything — the single-tenant parity case
        assert_eq!(tenant_task_split(3, 1), vec![vec![0, 1, 2]]);
        // zero tenants is clamped to one
        assert_eq!(tenant_task_split(2, 0), vec![vec![0, 1]]);
    }

    #[test]
    fn surplus_tenants_take_the_full_set() {
        let split = tenant_task_split(2, 4);
        assert_eq!(split[0], vec![0]);
        assert_eq!(split[1], vec![1]);
        assert_eq!(split[2], vec![0, 1]);
        assert_eq!(split[3], vec![0, 1]);
    }

    #[test]
    fn split_covers_every_task_exactly_once_across_owners() {
        for nt in 1..=4usize {
            let split = tenant_task_split(7, nt);
            let mut all: Vec<usize> =
                split.iter().take(7.min(nt)).flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..7).collect::<Vec<_>>(), "nt={nt}");
        }
    }
}
