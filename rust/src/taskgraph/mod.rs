//! Task graphs (§2.2, §3): compact shared-structure representations of a
//! multitask set, their quality metrics, enumeration, and selection.

pub mod enumerate;
pub mod graph;
pub mod partition;
pub mod select;

pub use graph::{Block, TaskGraph};
pub use partition::Partition;
