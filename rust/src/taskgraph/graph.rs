//! The task graph (§2.2): segments of the common architecture shared by
//! groups of tasks, represented as a refinement chain of partitions.
//!
//! With D branch points at layer boundaries `bounds[0..D]`, the network
//! splits into D+1 segments; `partitions[s]` groups tasks sharing segment
//! `s`. Refinement (`partitions[s+1]` refines `partitions[s]`) encodes the
//! tree shape: once two tasks diverge they never re-merge. The final
//! segment holds the task-private logits layer, so `partitions[D]` is the
//! identity.

use anyhow::{bail, Result};

use super::partition::Partition;
use crate::affinity::AffinityTensor;
use crate::model::ArchSpec;

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TaskGraph {
    pub n_tasks: usize,
    /// D strictly increasing internal layer boundaries in `1..n_layers`.
    pub bounds: Vec<usize>,
    /// D+1 partitions, a refinement chain ending in the identity.
    pub partitions: Vec<Partition>,
}

/// A distinct block: one group's instance of one segment.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Block {
    pub segment: usize,
    pub group: usize,
    pub tasks: Vec<usize>,
}

impl TaskGraph {
    pub fn new(
        n_tasks: usize,
        bounds: Vec<usize>,
        partitions: Vec<Partition>,
    ) -> Result<TaskGraph> {
        if partitions.len() != bounds.len() + 1 {
            bail!(
                "need {} partitions for {} branch points, got {}",
                bounds.len() + 1,
                bounds.len(),
                partitions.len()
            );
        }
        for w in bounds.windows(2) {
            if w[0] >= w[1] {
                bail!("bounds must be strictly increasing: {:?}", bounds);
            }
        }
        if bounds.first().is_some_and(|&b| b == 0) {
            bail!("bounds start at layer boundary 1");
        }
        for p in &partitions {
            if p.len() != n_tasks {
                bail!("partition arity mismatch");
            }
        }
        for s in 0..partitions.len() - 1 {
            if !partitions[s + 1].refines(&partitions[s]) {
                bail!("partitions[{}] does not refine partitions[{}]", s + 1, s);
            }
        }
        if !partitions[bounds.len()].is_identity() {
            bail!("final segment (logits) must be task-private");
        }
        Ok(TaskGraph { n_tasks, bounds, partitions })
    }

    /// Number of branch points D.
    pub fn d(&self) -> usize {
        self.bounds.len()
    }

    pub fn n_segments(&self) -> usize {
        self.bounds.len() + 1
    }

    /// Layer index range [start, end) of segment `s` in the architecture.
    pub fn segment_layers(&self, arch: &ArchSpec, s: usize) -> std::ops::Range<usize> {
        let start = if s == 0 { 0 } else { self.bounds[s - 1] };
        let end = if s == self.d() { arch.n_layers() } else { self.bounds[s] };
        start..end
    }

    /// The fully shared graph: all tasks in one group until the head.
    pub fn shared(n_tasks: usize, bounds: Vec<usize>) -> TaskGraph {
        let d = bounds.len();
        let mut partitions = vec![Partition::one_group(n_tasks); d];
        partitions.push(Partition::singletons(n_tasks));
        TaskGraph::new(n_tasks, bounds, partitions).unwrap()
    }

    /// The fully disjoint graph: every task keeps its own network
    /// (the Vanilla structure).
    pub fn disjoint(n_tasks: usize, bounds: Vec<usize>) -> TaskGraph {
        let partitions = vec![Partition::singletons(n_tasks); bounds.len() + 1];
        TaskGraph::new(n_tasks, bounds, partitions).unwrap()
    }

    pub fn group_of(&self, segment: usize, task: usize) -> usize {
        self.partitions[segment].group_of(task)
    }

    /// Number of leading segments tasks `i` and `j` share. Contiguous by
    /// the refinement invariant (divergence is permanent).
    pub fn shared_prefix(&self, i: usize, j: usize) -> usize {
        let mut s = 0;
        while s < self.n_segments() && self.group_of(s, i) == self.group_of(s, j) {
            s += 1;
        }
        s
    }

    /// All distinct blocks of the graph.
    pub fn blocks(&self) -> Vec<Block> {
        let mut out = Vec::new();
        for (s, p) in self.partitions.iter().enumerate() {
            for (g, tasks) in p.groups().into_iter().enumerate() {
                out.push(Block { segment: s, group: g, tasks });
            }
        }
        out
    }

    pub fn n_blocks(&self) -> usize {
        self.partitions.iter().map(|p| p.n_groups()).sum()
    }

    /// Variety score (Eq. 1–2): at each branch point b, the mean over
    /// child groups (the groups of `partitions[b+1]`) of the maximum
    /// pairwise dissimilarity within the group, summed over branch points.
    /// `affinity` must have d == self.d(), with ρ indexing `bounds` order.
    pub fn variety(&self, affinity: &AffinityTensor) -> f64 {
        assert_eq!(affinity.d, self.d());
        assert_eq!(affinity.n, self.n_tasks);
        let mut total = 0.0;
        for b in 0..self.d() {
            let groups = self.partitions[b + 1].groups();
            let m = groups.len() as f64;
            let mut v = 0.0;
            for g in &groups {
                let mut worst = 0.0f64;
                for (ai, &i) in g.iter().enumerate() {
                    for &j in &g[ai + 1..] {
                        worst = worst.max(affinity.dissimilarity(b, i, j));
                    }
                }
                v += worst;
            }
            total += v / m;
        }
        total
    }

    /// Total stored model size in bytes: every block's parameters, with
    /// the logits layer sized per its task's class count.
    pub fn model_bytes(&self, arch: &ArchSpec, ncls: &[usize]) -> usize {
        assert_eq!(ncls.len(), self.n_tasks);
        let mut total = 0usize;
        for (s, p) in self.partitions.iter().enumerate() {
            let layers = self.segment_layers(arch, s);
            for tasks in p.groups() {
                for l in layers.clone() {
                    let spec = &arch.layers[l];
                    let c = if spec.is_logits() {
                        assert_eq!(tasks.len(), 1, "logits layer must be private");
                        ncls[tasks[0]]
                    } else {
                        2 // irrelevant: shapes don't depend on it
                    };
                    total += spec.param_bytes(c);
                }
            }
        }
        total
    }

    /// MACs to execute segment `s` once for one sample.
    pub fn segment_macs(&self, arch: &ArchSpec, s: usize) -> u64 {
        self.segment_layers(arch, s)
            .map(|l| arch.layers[l].macs_per_sample)
            .sum()
    }

    /// Output activation elements of segment `s` (for buffer sizing).
    pub fn segment_out_elems(&self, arch: &ArchSpec, s: usize) -> usize {
        let r = self.segment_layers(arch, s);
        if r.is_empty() {
            0
        } else {
            arch.layers[r.end - 1].out_elems()
        }
    }

    /// Parameter bytes of one group-instance of segment `s`.
    pub fn segment_bytes(&self, arch: &ArchSpec, s: usize, task: usize, ncls: &[usize]) -> usize {
        self.segment_layers(arch, s)
            .map(|l| {
                let spec = &arch.layers[l];
                let c = if spec.is_logits() { ncls[task] } else { 2 };
                spec.param_bytes(c)
            })
            .sum()
    }

    /// Evenly spread D boundaries over the architecture, §7-style (first
    /// boundary right after layer 0, last right before the head).
    pub fn default_bounds(n_layers: usize, d: usize) -> Vec<usize> {
        assert!(n_layers >= 2);
        let d = d.min(n_layers - 1);
        if d == 1 {
            return vec![n_layers - 1];
        }
        let mut out: Vec<usize> = (0..d)
            .map(|i| {
                let x = 1.0 + i as f64 * (n_layers as f64 - 2.0) / (d as f64 - 1.0);
                x.round() as usize
            })
            .collect();
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::affinity::synthetic_affinity;
    use crate::util::rng::Pcg32;

    fn arch5() -> ArchSpec {
        // mirror of cnn5 shapes, built inline so unit tests don't need disk
        crate::model::manifest::Manifest::from_json(
            std::path::PathBuf::from("/tmp"),
            &crate::util::json::Json::parse(TINY).unwrap(),
        )
        .unwrap()
        .arch("cnn5")
        .unwrap()
        .clone()
    }

    const TINY: &str = r#"{
      "version": 1,
      "archs": {"cnn5": {"input": [16,16,1], "ncls": [2],
        "layers": [
          {"kind":"conv_pool","cfg":{"kh":3,"kw":3,"cin":1,"cout":8},"in":[16,16,1],"out":[8,8,8],"macs_per_sample":18432},
          {"kind":"conv_pool","cfg":{"kh":3,"kw":3,"cin":8,"cout":16},"in":[8,8,8],"out":[4,4,16],"macs_per_sample":73728},
          {"kind":"dense","cfg":{"din":256,"dout":64},"in":[4,4,16],"out":[64],"macs_per_sample":16384},
          {"kind":"dense","cfg":{"din":64,"dout":32},"in":[64],"out":[32],"macs_per_sample":2048},
          {"kind":"logits","cfg":{"din":32,"dout":0},"in":[32],"out":[2],"macs_per_sample":64}
        ]}},
      "entries": []
    }"#;

    #[test]
    fn default_bounds_match_paper_examples() {
        assert_eq!(TaskGraph::default_bounds(5, 3), vec![1, 3, 4]);
        assert_eq!(TaskGraph::default_bounds(7, 3), vec![1, 4, 6]);
        assert_eq!(TaskGraph::default_bounds(7, 5), vec![1, 2, 4, 5, 6]);
        // clamped when the architecture is too shallow
        assert_eq!(TaskGraph::default_bounds(5, 7).len(), 4);
    }

    #[test]
    fn segments_partition_the_layer_list() {
        let arch = arch5();
        let g = TaskGraph::shared(4, vec![1, 3, 4]);
        let mut covered = Vec::new();
        for s in 0..g.n_segments() {
            covered.extend(g.segment_layers(&arch, s));
        }
        assert_eq!(covered, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn validation_rejects_bad_graphs() {
        // non-refining chain
        let p = vec![
            Partition(vec![0, 1, 0]),
            Partition(vec![0, 0, 1]),
            Partition::singletons(3),
        ];
        assert!(TaskGraph::new(3, vec![1, 3], p).is_err());
        // shared head
        let p = vec![Partition::one_group(3), Partition::one_group(3)];
        assert!(TaskGraph::new(3, vec![2], p).is_err());
        // non-increasing bounds
        assert!(TaskGraph::new(
            2,
            vec![2, 2],
            vec![
                Partition::one_group(2),
                Partition::one_group(2),
                Partition::singletons(2)
            ]
        )
        .is_err());
    }

    #[test]
    fn shared_prefix_is_contiguous() {
        let g = TaskGraph::new(
            3,
            vec![1, 3, 4],
            vec![
                Partition(vec![0, 0, 0]),
                Partition(vec![0, 0, 1]),
                Partition(vec![0, 1, 2]),
                Partition::singletons(3),
            ],
        )
        .unwrap();
        assert_eq!(g.shared_prefix(0, 1), 2);
        assert_eq!(g.shared_prefix(0, 2), 1);
        assert_eq!(g.shared_prefix(1, 2), 1);
        assert_eq!(g.shared_prefix(0, 0), 4);
    }

    #[test]
    fn variety_extremes() {
        let mut rng = Pcg32::seed(11);
        let aff = synthetic_affinity(5, 3, &mut rng);
        let shared = TaskGraph::shared(5, vec![1, 3, 4]);
        let disjoint = TaskGraph::disjoint(5, vec![1, 3, 4]);
        assert_eq!(disjoint.variety(&aff), 0.0);
        assert!(shared.variety(&aff) > disjoint.variety(&aff));
    }

    #[test]
    fn model_bytes_shared_less_than_disjoint() {
        let arch = arch5();
        let ncls = vec![2usize; 5];
        let shared = TaskGraph::shared(5, vec![1, 3, 4]);
        let disjoint = TaskGraph::disjoint(5, vec![1, 3, 4]);
        let sb = shared.model_bytes(&arch, &ncls);
        let db = disjoint.model_bytes(&arch, &ncls);
        assert!(sb < db, "{} vs {}", sb, db);
        // disjoint is exactly 5 independent networks
        assert_eq!(db, 5 * arch.total_params(2) * 4);
    }

    #[test]
    fn blocks_count() {
        let g = TaskGraph::new(
            3,
            vec![1, 3, 4],
            vec![
                Partition(vec![0, 0, 0]),
                Partition(vec![0, 0, 1]),
                Partition(vec![0, 1, 2]),
                Partition::singletons(3),
            ],
        )
        .unwrap();
        assert_eq!(g.n_blocks(), 1 + 2 + 3 + 3);
        let blocks = g.blocks();
        assert_eq!(blocks[0].tasks, vec![0, 1, 2]);
    }

    #[test]
    fn segment_macs_sum_to_arch_total() {
        let arch = arch5();
        let g = TaskGraph::shared(2, vec![1, 3, 4]);
        let total: u64 = (0..g.n_segments()).map(|s| g.segment_macs(&arch, s)).sum();
        assert_eq!(total, arch.total_macs());
    }
}
