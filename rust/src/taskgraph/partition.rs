//! Canonical set partitions of the task set. A task graph (§2.2) is a
//! refinement chain of partitions, one per network segment: tasks in the
//! same group at segment `s` share that segment's block (weights and, for
//! a fixed input, its output activation).

/// A partition of `0..n` into groups, stored as a group id per element.
/// Canonical form: group ids are assigned in order of first appearance
/// (so `[0,1,0,2]` is canonical, `[1,0,1,2]` is not).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Partition(pub Vec<usize>);

impl Partition {
    pub fn singletons(n: usize) -> Partition {
        Partition((0..n).collect())
    }

    pub fn one_group(n: usize) -> Partition {
        Partition(vec![0; n])
    }

    pub fn canonicalize(ids: &[usize]) -> Partition {
        let mut map = std::collections::HashMap::new();
        let mut next = 0usize;
        let out = ids
            .iter()
            .map(|&g| {
                *map.entry(g).or_insert_with(|| {
                    let v = next;
                    next += 1;
                    v
                })
            })
            .collect();
        Partition(out)
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn group_of(&self, task: usize) -> usize {
        self.0[task]
    }

    pub fn n_groups(&self) -> usize {
        self.0.iter().copied().max().map_or(0, |m| m + 1)
    }

    /// Tasks per group, ordered by group id.
    pub fn groups(&self) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.n_groups()];
        for (t, &g) in self.0.iter().enumerate() {
            out[g].push(t);
        }
        out
    }

    /// True if `self` refines `coarser` (every group of self is contained
    /// in a single group of coarser).
    pub fn refines(&self, coarser: &Partition) -> bool {
        assert_eq!(self.len(), coarser.len());
        let mut rep: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::new();
        for t in 0..self.len() {
            match rep.entry(self.0[t]) {
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(coarser.0[t]);
                }
                std::collections::hash_map::Entry::Occupied(e) => {
                    if *e.get() != coarser.0[t] {
                        return false;
                    }
                }
            }
        }
        true
    }

    pub fn is_identity(&self) -> bool {
        self.0.iter().enumerate().all(|(i, &g)| i == g)
    }

    /// All canonical partitions of `0..n` (restricted growth strings).
    /// Bell(n) of them — intended for n <= 10.
    pub fn enumerate_all(n: usize) -> Vec<Partition> {
        let mut out = Vec::new();
        let mut cur = vec![0usize; n];
        fn rec(cur: &mut Vec<usize>, i: usize, maxg: usize, out: &mut Vec<Partition>) {
            if i == cur.len() {
                out.push(Partition(cur.clone()));
                return;
            }
            for g in 0..=maxg {
                cur[i] = g;
                rec(cur, i + 1, if g == maxg { maxg + 1 } else { maxg }, out);
            }
        }
        if n == 0 {
            return vec![Partition(vec![])];
        }
        rec(&mut cur, 1, 1, &mut out);
        out
    }

    /// All canonical partitions refining `coarser`: the cartesian product
    /// of the partitions of each group of `coarser`.
    pub fn enumerate_refinements(coarser: &Partition) -> Vec<Partition> {
        let groups = coarser.groups();
        let per_group: Vec<Vec<Partition>> = groups
            .iter()
            .map(|g| Partition::enumerate_all(g.len()))
            .collect();
        let mut out = Vec::new();
        let mut choice = vec![0usize; groups.len()];
        loop {
            // materialize this combination
            let mut ids = vec![0usize; coarser.len()];
            let mut base = 0usize;
            for (gi, g) in groups.iter().enumerate() {
                let sub = &per_group[gi][choice[gi]];
                for (k, &task) in g.iter().enumerate() {
                    ids[task] = base + sub.0[k];
                }
                base += sub.n_groups();
            }
            out.push(Partition::canonicalize(&ids));
            // advance odometer
            let mut i = 0;
            loop {
                if i == groups.len() {
                    return out;
                }
                choice[i] += 1;
                if choice[i] < per_group[i].len() {
                    break;
                }
                choice[i] = 0;
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_form() {
        assert_eq!(Partition::canonicalize(&[5, 2, 5, 9]).0, vec![0, 1, 0, 2]);
    }

    #[test]
    fn groups_roundtrip() {
        let p = Partition(vec![0, 1, 0, 2, 1]);
        assert_eq!(p.n_groups(), 3);
        assert_eq!(p.groups(), vec![vec![0, 2], vec![1, 4], vec![3]]);
    }

    #[test]
    fn refinement_relation() {
        let coarse = Partition(vec![0, 0, 1, 1]);
        let fine = Partition(vec![0, 1, 2, 2]);
        assert!(fine.refines(&coarse));
        assert!(!coarse.refines(&fine));
        assert!(coarse.refines(&coarse));
        assert!(Partition::singletons(4).refines(&coarse));
        assert!(coarse.refines(&Partition::one_group(4)));
    }

    #[test]
    fn bell_numbers() {
        assert_eq!(Partition::enumerate_all(1).len(), 1);
        assert_eq!(Partition::enumerate_all(3).len(), 5);
        assert_eq!(Partition::enumerate_all(5).len(), 52);
        assert_eq!(Partition::enumerate_all(7).len(), 877);
    }

    #[test]
    fn enumerated_partitions_canonical_and_unique() {
        let all = Partition::enumerate_all(5);
        let set: std::collections::HashSet<_> = all.iter().cloned().collect();
        assert_eq!(set.len(), all.len());
        for p in &all {
            assert_eq!(Partition::canonicalize(&p.0), *p);
        }
    }

    #[test]
    fn refinements_of_pair_groups() {
        // {0,1},{2,3}: each group has 2 partitions -> 4 refinements
        let coarse = Partition(vec![0, 0, 1, 1]);
        let refs = Partition::enumerate_refinements(&coarse);
        assert_eq!(refs.len(), 4);
        for r in &refs {
            assert!(r.refines(&coarse));
        }
    }

    #[test]
    fn refinements_count_matches_product_of_bell() {
        let coarse = Partition(vec![0, 0, 0, 1, 1]); // Bell(3)*Bell(2) = 10
        assert_eq!(Partition::enumerate_refinements(&coarse).len(), 10);
    }
}
