//! Task graph scoring and tradeoff selection (§3.2–3.3, Fig. 3): score
//! every candidate graph on (variety, model size, execution cost with its
//! optimal task order), sweep the model-size budget, and pick the graph at
//! the intersection of the normalized variety and cost trend lines.

use crate::affinity::AffinityTensor;
use crate::device::Device;
use crate::memory::{cost_matrix, ExecSim};
use crate::model::ArchSpec;
use crate::ordering::{solve_genetic, solve_held_karp, GaConfig, OrderingProblem};
use crate::util::stats;

use super::graph::TaskGraph;

#[derive(Debug, Clone)]
pub struct GraphScore {
    pub graph: TaskGraph,
    pub variety: f64,
    pub model_bytes: usize,
    /// Steady-state per-round execution time under the optimal order, s.
    pub exec_time: f64,
    pub exec_energy: f64,
    pub order: Vec<usize>,
}

/// Score one graph: solve its ordering problem (exact for small n, GA
/// beyond), then simulate a steady round in that order.
pub fn score_graph(
    graph: &TaskGraph,
    affinity: &AffinityTensor,
    arch: &ArchSpec,
    ncls: &[usize],
    device: &Device,
) -> GraphScore {
    let order = optimal_order(graph, arch, ncls, device);
    let mut sim = ExecSim::new(device, arch, graph, ncls);
    let cost = sim.steady_round_cost(&order, 3);
    GraphScore {
        variety: graph.variety(affinity),
        model_bytes: graph.model_bytes(arch, ncls),
        exec_time: cost.time(),
        exec_energy: cost.energy(),
        order,
        graph: graph.clone(),
    }
}

/// The ordering step invoked per enumerated graph (§3.3 Step 3).
pub fn optimal_order(
    graph: &TaskGraph,
    arch: &ArchSpec,
    ncls: &[usize],
    device: &Device,
) -> Vec<usize> {
    let c = cost_matrix(device, arch, graph, ncls, false);
    let p = OrderingProblem::from_matrix(c);
    let sol = if graph.n_tasks <= 14 {
        solve_held_karp(&p)
    } else {
        solve_genetic(&p, &GaConfig::default())
    };
    sol.map(|s| s.order).unwrap_or_else(|| (0..graph.n_tasks).collect())
}

/// One point of the Fig. 3 tradeoff curve.
#[derive(Debug, Clone)]
pub struct TradeoffPoint {
    pub budget_bytes: usize,
    /// Index into the scored graph list of the pick at this budget.
    pub pick: usize,
    pub variety_norm: f64,
    pub cost_norm: f64,
}

/// Sweep the model-size budget over all candidate sizes; at each budget
/// pick the lowest-variety graph that fits; normalize both trends.
pub fn tradeoff_curve(scores: &[GraphScore]) -> Vec<TradeoffPoint> {
    assert!(!scores.is_empty());
    let mut budgets: Vec<usize> = scores.iter().map(|s| s.model_bytes).collect();
    budgets.sort_unstable();
    budgets.dedup();
    let mut picks = Vec::new();
    for &b in &budgets {
        let pick = scores
            .iter()
            .enumerate()
            .filter(|(_, s)| s.model_bytes <= b)
            .min_by(|a, b| {
                a.1.variety
                    .partial_cmp(&b.1.variety)
                    .unwrap()
                    .then(a.1.exec_time.partial_cmp(&b.1.exec_time).unwrap())
            })
            .map(|(i, _)| i)
            .expect("some graph fits its own size");
        picks.push(pick);
    }
    let variety: Vec<f64> = picks.iter().map(|&i| scores[i].variety).collect();
    let cost: Vec<f64> = picks.iter().map(|&i| scores[i].exec_time).collect();
    let vn = stats::normalize(&variety);
    let cn = stats::normalize(&cost);
    budgets
        .iter()
        .zip(picks)
        .zip(vn.iter().zip(cn.iter()))
        .map(|((&budget_bytes, pick), (&variety_norm, &cost_norm))| TradeoffPoint {
            budget_bytes,
            pick,
            variety_norm,
            cost_norm,
        })
        .collect()
}

/// The selected graph: where the normalized variety (falling in budget)
/// and cost (rising in budget) trend lines intersect (§3.2).
pub fn select_tradeoff(scores: &[GraphScore]) -> usize {
    let curve = tradeoff_curve(scores);
    for w in curve.windows(2) {
        let d0 = w[0].variety_norm - w[0].cost_norm;
        let d1 = w[1].variety_norm - w[1].cost_norm;
        if d0 >= 0.0 && d1 <= 0.0 {
            // crossing between the two budgets: pick the closer one
            return if d0.abs() <= d1.abs() { w[0].pick } else { w[1].pick };
        }
    }
    // no crossing: minimize |variety_norm - cost_norm|
    curve
        .iter()
        .min_by(|a, b| {
            (a.variety_norm - a.cost_norm)
                .abs()
                .partial_cmp(&(b.variety_norm - b.cost_norm).abs())
                .unwrap()
        })
        .map(|p| p.pick)
        .unwrap()
}

/// Budget extremes for Fig. 8: (min-budget pick, tradeoff pick,
/// max-budget pick).
pub fn budget_extremes(scores: &[GraphScore]) -> (usize, usize, usize) {
    let curve = tradeoff_curve(scores);
    let min_pick = curve.first().unwrap().pick;
    let max_pick = curve.last().unwrap().pick;
    (min_pick, select_tradeoff(scores), max_pick)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::affinity::synthetic_affinity;
    use crate::taskgraph::enumerate::enumerate_all;
    use crate::util::rng::Pcg32;

    const TINY: &str = r#"{
      "version": 1,
      "archs": {"cnn5": {"input": [16,16,1], "ncls": [2],
        "layers": [
          {"kind":"conv_pool","cfg":{"kh":3,"kw":3,"cin":1,"cout":8},"in":[16,16,1],"out":[8,8,8],"macs_per_sample":18432},
          {"kind":"conv_pool","cfg":{"kh":3,"kw":3,"cin":8,"cout":16},"in":[8,8,8],"out":[4,4,16],"macs_per_sample":73728},
          {"kind":"dense","cfg":{"din":256,"dout":64},"in":[4,4,16],"out":[64],"macs_per_sample":16384},
          {"kind":"dense","cfg":{"din":64,"dout":32},"in":[64],"out":[32],"macs_per_sample":2048},
          {"kind":"logits","cfg":{"din":32,"dout":0},"in":[32],"out":[2],"macs_per_sample":64}
        ]}},
      "entries": []
    }"#;

    fn arch() -> ArchSpec {
        crate::model::manifest::Manifest::from_json(
            std::path::PathBuf::from("/tmp"),
            &crate::util::json::Json::parse(TINY).unwrap(),
        )
        .unwrap()
        .arch("cnn5")
        .unwrap()
        .clone()
    }

    fn scored_universe(n: usize) -> Vec<GraphScore> {
        let arch = arch();
        let dev = Device::msp430();
        let mut rng = Pcg32::seed(31);
        let aff = synthetic_affinity(n, 3, &mut rng);
        let graphs = enumerate_all(n, &[1, 3, 4], Some(400));
        graphs
            .iter()
            .map(|g| score_graph(g, &aff, &arch, &vec![2; n], &dev))
            .collect()
    }

    #[test]
    fn variety_and_cost_oppose() {
        let scores = scored_universe(4);
        // most compact graph: min bytes; most dispersed: max bytes
        let min = scores.iter().min_by_key(|s| s.model_bytes).unwrap();
        let max = scores.iter().max_by_key(|s| s.model_bytes).unwrap();
        assert!(min.variety >= max.variety);
        assert!(min.exec_time <= max.exec_time);
    }

    #[test]
    fn tradeoff_curve_monotone_trends() {
        let scores = scored_universe(4);
        let curve = tradeoff_curve(&scores);
        assert!(curve.len() > 2);
        // variety trend is non-increasing in budget
        for w in curve.windows(2) {
            assert!(w[1].variety_norm <= w[0].variety_norm + 1e-9);
        }
        // endpoints normalized
        assert!(curve.first().unwrap().variety_norm >= 0.99);
        assert!(curve.last().unwrap().variety_norm <= 0.01);
    }

    #[test]
    fn selected_graph_is_strictly_between_extremes() {
        let scores = scored_universe(5);
        let (lo, mid, hi) = budget_extremes(&scores);
        let (bl, bm, bh) = (
            scores[lo].model_bytes,
            scores[mid].model_bytes,
            scores[hi].model_bytes,
        );
        assert!(bl <= bm && bm <= bh);
        // the tradeoff pick is neither extreme of the variety range
        assert!(scores[mid].variety <= scores[lo].variety);
        assert!(scores[mid].exec_time <= scores[hi].exec_time);
    }

    #[test]
    fn score_graph_order_is_valid_permutation() {
        let scores = scored_universe(4);
        for s in scores.iter().take(10) {
            let mut o = s.order.clone();
            o.sort_unstable();
            assert_eq!(o, (0..4).collect::<Vec<_>>());
        }
    }
}
