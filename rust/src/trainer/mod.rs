//! Training and evaluation drivers, generic over the execution
//! [`Backend`]: one SGD step computes (loss, updated params) — via the
//! AOT `train_*` artifact on PJRT, or the hand-derived backward pass on
//! the reference backend — and this module drives it from rust,
//! individually per task (the Vanilla baseline and the affinity-profiling
//! networks) or interleaved across a task graph (multitask training of
//! shared blocks, the rust-side analog of the paper's branched-MTL
//! retraining step [59]).

pub mod weights;

pub use weights::GraphWeights;

use anyhow::Result;

use crate::model::{ArchSpec, Tensor};
use crate::runtime::Backend;
use crate::taskgraph::TaskGraph;
use crate::util::rng::Pcg32;

pub const TRAIN_BATCH: usize = 32;
pub const EVAL_BATCH: usize = 64;

/// Initialize a fresh flat parameter list for one network instance.
pub fn init_params(arch: &ArchSpec, ncls: usize, rng: &mut Pcg32) -> Vec<Tensor> {
    arch.flat_param_shapes(ncls)
        .into_iter()
        .map(|s| Tensor::he_init(s, rng))
        .collect()
}

/// One SGD step on the backend. Returns the loss; `params` is updated in
/// place.
pub fn train_step<B: Backend + ?Sized>(
    backend: &B,
    arch: &ArchSpec,
    ncls: usize,
    params: &mut Vec<Tensor>,
    x: &Tensor,
    y: &[i32],
    lr: f32,
) -> Result<f32> {
    backend.train_step(arch, ncls, params, x, y, lr)
}

/// Train one network individually: `batch_fn(rng)` supplies (x, y).
pub fn train_individual<B: Backend + ?Sized>(
    backend: &B,
    arch: &ArchSpec,
    ncls: usize,
    steps: usize,
    lr: f32,
    rng: &mut Pcg32,
    mut batch_fn: impl FnMut(&mut Pcg32) -> (Tensor, Vec<i32>),
) -> Result<(Vec<Tensor>, Vec<f32>)> {
    let mut params = init_params(arch, ncls, rng);
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        let (x, y) = batch_fn(rng);
        losses.push(train_step(backend, arch, ncls, &mut params, &x, &y, lr)?);
    }
    Ok((params, losses))
}

/// Multitask training of a task graph: per step, one task is trained
/// round-robin; its path parameters are assembled from the block store,
/// stepped, and written back — shared blocks therefore accumulate
/// gradients from every task that owns them.
#[allow(clippy::too_many_arguments)]
pub fn train_graph<B: Backend + ?Sized>(
    backend: &B,
    arch: &ArchSpec,
    graph: &TaskGraph,
    ncls: &[usize],
    store: &mut GraphWeights,
    steps: usize,
    lr: f32,
    rng: &mut Pcg32,
    mut batch_fn: impl FnMut(usize, &mut Pcg32) -> (Tensor, Vec<i32>),
) -> Result<Vec<f32>> {
    // class-weighted round-robin: harder tasks (more classes) take
    // proportionally more joint steps, then every task gets a head-only
    // specialization phase with the shared trunk frozen
    let mut schedule: Vec<usize> = Vec::new();
    for (t, &c) in ncls.iter().enumerate() {
        for _ in 0..c.max(2) / 2 {
            schedule.push(t);
        }
    }
    // gentle joint phase (low lr so conflicting task gradients do not
    // wreck the shared trunks the individual nets seeded), then a longer
    // head-only phase at full lr
    let joint = steps / 2;
    let mut losses = Vec::with_capacity(steps);
    for step in 0..joint {
        let task = schedule[step % schedule.len()];
        let mut params = store.assemble(graph, arch, task);
        let (x, y) = batch_fn(task, rng);
        let loss = train_step(
            backend, arch, ncls[task], &mut params, &x, &y, lr * 0.2,
        )?;
        store.write_back(graph, arch, task, params);
        losses.push(loss);
    }
    for step in joint..steps {
        let task = schedule[step % schedule.len()];
        let mut params = store.assemble(graph, arch, task);
        let (x, y) = batch_fn(task, rng);
        let loss =
            train_step(backend, arch, ncls[task], &mut params, &x, &y, lr)?;
        store.write_back_filtered(graph, arch, task, params, true);
        losses.push(loss);
    }
    Ok(losses)
}

/// Accuracy of a parameter set over a test set, via the backend's batch
/// eval (the Pallas serving path on PJRT). The final ragged batch is
/// padded by repetition and the padding predictions are discarded — the
/// same flow on every backend, so accuracies stay comparable.
pub fn evaluate<B: Backend + ?Sized>(
    backend: &B,
    arch: &ArchSpec,
    ncls: usize,
    params: &[Tensor],
    x: &Tensor,
    y: &[i32],
) -> Result<f64> {
    let n = x.shape[0];
    assert_eq!(n, y.len());
    let mut correct = 0usize;
    let mut done = 0usize;
    while done < n {
        let take = (n - done).min(EVAL_BATCH);
        let batch = if take == EVAL_BATCH {
            x.slice_batch(done, EVAL_BATCH)
        } else {
            // pad by repeating the first rows
            let part = x.slice_batch(done, take);
            let pad = x.slice_batch(0, EVAL_BATCH - take);
            Tensor::concat_batch(&[&part, &pad])
        };
        let logits = backend.eval_logits(arch, ncls, params, &batch)?;
        for i in 0..take {
            let row = &logits.data[i * ncls..(i + 1) * ncls];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            if pred as i32 == y[done + i] {
                correct += 1;
            }
        }
        done += take;
    }
    Ok(correct as f64 / n as f64)
}

/// Mean of the last `k` losses — convergence check helper.
pub fn tail_mean(losses: &[f32], k: usize) -> f32 {
    let k = k.min(losses.len()).max(1);
    losses[losses.len() - k..].iter().sum::<f32>() / k as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset_by_name;
    use crate::runtime::ReferenceBackend;

    #[test]
    fn individual_training_learns_imu_task() {
        let be = ReferenceBackend::new();
        let arch = be.arch("dnn4").unwrap();
        let ds = dataset_by_name("hhar-s").unwrap().generate(&[128], 360);
        let (train, test) = ds.split();
        let mut rng = Pcg32::seed(1);
        let (params, losses) = train_individual(
            &be,
            &arch,
            2,
            60,
            0.05,
            &mut rng,
            |r| ds.balanced_batch(0, &train, TRAIN_BATCH, r),
        )
        .unwrap();
        assert!(
            tail_mean(&losses, 10) < losses[0] * 0.8,
            "loss did not fall: {} -> {}",
            losses[0],
            tail_mean(&losses, 10)
        );
        let (xt, yt) = ds.gather(&test, 0);
        let acc = evaluate(&be, &arch, 2, &params, &xt, &yt).unwrap();
        assert!(acc > 0.7, "accuracy {acc}");
    }

    #[test]
    fn graph_training_updates_shared_blocks() {
        let be = ReferenceBackend::new();
        let arch = be.arch("dnn4").unwrap();
        let graph = TaskGraph::shared(2, TaskGraph::default_bounds(4, 3));
        let ncls = vec![2, 2];
        let mut rng = Pcg32::seed(2);
        let mut store = GraphWeights::init(&graph, &arch, &ncls, &mut rng);
        let ds = dataset_by_name("hhar-s").unwrap().generate(&[128], 240);
        let (train, _) = ds.split();
        let before = store.assemble(&graph, &arch, 0);
        let losses = train_graph(
            &be,
            &arch,
            &graph,
            &ncls,
            &mut store,
            20,
            0.05,
            &mut rng,
            |task, r| ds.balanced_batch(task, &train, TRAIN_BATCH, r),
        )
        .unwrap();
        assert_eq!(losses.len(), 20);
        // the shared trunk moved
        let after = store.assemble(&graph, &arch, 0);
        assert!(before[0].l2_dist(&after[0]) > 0.0);
        // task 1's head differs from task 0's head (private blocks)
        let p0 = store.assemble(&graph, &arch, 0);
        let p1 = store.assemble(&graph, &arch, 1);
        let last = p0.len() - 2;
        assert!(p0[last].l2_dist(&p1[last]) > 0.0);
        // but they share the trunk tensors exactly
        assert_eq!(p0[0], p1[0]);
    }

    /// Same training flow on the PJRT engine — kept behind artifact
    /// detection so `make artifacts` coverage still exercises the AOT
    /// train path.
    #[cfg(feature = "pjrt")]
    mod pjrt {
        use super::super::*;
        use crate::data::dataset_by_name;
        use crate::runtime::pjrt_test_engine as engine;

        #[test]
        fn individual_training_learns_imu_task_pjrt() {
            let Some(eng) = engine() else { return };
            let arch = eng.arch("dnn4").unwrap();
            let ds = dataset_by_name("hhar-s").unwrap().generate(&[128], 360);
            let (train, test) = ds.split();
            let mut rng = Pcg32::seed(1);
            let (params, losses) = train_individual(
                &eng,
                &arch,
                2,
                60,
                0.05,
                &mut rng,
                |r| ds.balanced_batch(0, &train, TRAIN_BATCH, r),
            )
            .unwrap();
            assert!(tail_mean(&losses, 10) < losses[0] * 0.8);
            let (xt, yt) = ds.gather(&test, 0);
            let acc = evaluate(&eng, &arch, 2, &params, &xt, &yt).unwrap();
            assert!(acc > 0.7, "accuracy {acc}");
        }
    }
}
