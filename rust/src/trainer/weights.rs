//! Block-granular weight store for a task graph: one parameter set per
//! (segment, group) block. Assembling a task's flat parameter list walks
//! its root→leaf path; writing back after a training step updates the
//! blocks in place, which is how shared blocks receive gradients from
//! every task that owns them.

use crate::model::{ArchSpec, Tensor};
use crate::taskgraph::TaskGraph;
use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct GraphWeights {
    /// blocks[segment][group] = flat [w, b] tensors of that segment's
    /// layers, in layer order.
    pub blocks: Vec<Vec<Vec<Tensor>>>,
}

impl GraphWeights {
    /// He-initialize every block. Logits shapes use the owning task's
    /// class count (private head blocks by construction).
    pub fn init(
        graph: &TaskGraph,
        arch: &ArchSpec,
        ncls: &[usize],
        rng: &mut Pcg32,
    ) -> GraphWeights {
        let mut blocks = Vec::with_capacity(graph.n_segments());
        for (s, p) in graph.partitions.iter().enumerate() {
            let mut seg = Vec::new();
            for tasks in p.groups() {
                let mut tensors = Vec::new();
                for l in graph.segment_layers(arch, s) {
                    let spec = &arch.layers[l];
                    let c = if spec.is_logits() {
                        ncls[tasks[0]]
                    } else {
                        2
                    };
                    for shape in spec.param_shapes(c) {
                        tensors.push(Tensor::he_init(shape, rng));
                    }
                }
                seg.push(tensors);
            }
            blocks.push(seg);
        }
        GraphWeights { blocks }
    }

    /// Build a store for an already-trained parameter set per task
    /// (e.g. Vanilla nets dropped into a disjoint graph). `per_task[t]`
    /// is a flat [w0, b0, ...] list. Shared blocks take task-0-in-group's
    /// tensors (the retraining step then reconciles them).
    pub fn from_task_params(
        graph: &TaskGraph,
        arch: &ArchSpec,
        per_task: &[Vec<Tensor>],
    ) -> GraphWeights {
        let mut blocks = Vec::with_capacity(graph.n_segments());
        for (s, p) in graph.partitions.iter().enumerate() {
            let mut seg = Vec::new();
            for tasks in p.groups() {
                let owner = tasks[0];
                let mut tensors = Vec::new();
                for l in graph.segment_layers(arch, s) {
                    tensors.push(per_task[owner][2 * l].clone());
                    tensors.push(per_task[owner][2 * l + 1].clone());
                }
                seg.push(tensors);
            }
            blocks.push(seg);
        }
        GraphWeights { blocks }
    }

    /// Flat [w0, b0, ..., wk, bk] parameter list along `task`'s path.
    pub fn assemble(&self, graph: &TaskGraph, arch: &ArchSpec, task: usize) -> Vec<Tensor> {
        let mut out = Vec::with_capacity(2 * arch.n_layers());
        for s in 0..graph.n_segments() {
            let g = graph.group_of(s, task);
            out.extend(self.blocks[s][g].iter().cloned());
        }
        debug_assert_eq!(out.len(), 2 * arch.n_layers());
        out
    }

    /// Write an updated flat parameter list back into the blocks.
    pub fn write_back(
        &mut self,
        graph: &TaskGraph,
        arch: &ArchSpec,
        task: usize,
        params: Vec<Tensor>,
    ) {
        self.write_back_filtered(graph, arch, task, params, false)
    }

    /// Write back, optionally touching only the task-PRIVATE blocks
    /// (singleton groups) — the head-specialization phase of multitask
    /// training: shared trunks stay frozen while each task's private
    /// layers adapt.
    pub fn write_back_filtered(
        &mut self,
        graph: &TaskGraph,
        arch: &ArchSpec,
        task: usize,
        params: Vec<Tensor>,
        private_only: bool,
    ) {
        assert_eq!(params.len(), 2 * arch.n_layers());
        let mut it = params.into_iter();
        for s in 0..graph.n_segments() {
            let g = graph.group_of(s, task);
            let private = graph.partitions[s].groups()[g].len() == 1;
            for slot in self.blocks[s][g].iter_mut() {
                let p = it.next().expect("param count");
                if !private_only || private {
                    *slot = p;
                }
            }
        }
    }

    /// Total stored bytes (must agree with `TaskGraph::model_bytes`).
    pub fn total_bytes(&self) -> usize {
        self.blocks
            .iter()
            .flat_map(|seg| seg.iter())
            .flat_map(|blk| blk.iter())
            .map(|t| t.bytes())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskgraph::Partition;

    const TINY: &str = r#"{
      "version": 1,
      "archs": {"cnn5": {"input": [16,16,1], "ncls": [2],
        "layers": [
          {"kind":"conv_pool","cfg":{"kh":3,"kw":3,"cin":1,"cout":8},"in":[16,16,1],"out":[8,8,8],"macs_per_sample":18432},
          {"kind":"conv_pool","cfg":{"kh":3,"kw":3,"cin":8,"cout":16},"in":[8,8,8],"out":[4,4,16],"macs_per_sample":73728},
          {"kind":"dense","cfg":{"din":256,"dout":64},"in":[4,4,16],"out":[64],"macs_per_sample":16384},
          {"kind":"dense","cfg":{"din":64,"dout":32},"in":[64],"out":[32],"macs_per_sample":2048},
          {"kind":"logits","cfg":{"din":32,"dout":0},"in":[32],"out":[2],"macs_per_sample":64}
        ]}},
      "entries": []
    }"#;

    fn arch() -> ArchSpec {
        crate::model::manifest::Manifest::from_json(
            std::path::PathBuf::from("/tmp"),
            &crate::util::json::Json::parse(TINY).unwrap(),
        )
        .unwrap()
        .arch("cnn5")
        .unwrap()
        .clone()
    }

    fn graph() -> TaskGraph {
        TaskGraph::new(
            3,
            vec![1, 3, 4],
            vec![
                Partition(vec![0, 0, 0]),
                Partition(vec![0, 0, 1]),
                Partition(vec![0, 1, 2]),
                Partition::singletons(3),
            ],
        )
        .unwrap()
    }

    #[test]
    fn assemble_has_full_param_list() {
        let arch = arch();
        let g = graph();
        let mut rng = Pcg32::seed(3);
        let store = GraphWeights::init(&g, &arch, &[2, 3, 5], &mut rng);
        for (t, &c) in [2usize, 3, 5].iter().enumerate() {
            let params = store.assemble(&g, &arch, t);
            let shapes: Vec<Vec<usize>> =
                params.iter().map(|p| p.shape.clone()).collect();
            assert_eq!(shapes, arch.flat_param_shapes(c), "task {t}");
        }
    }

    #[test]
    fn shared_blocks_are_shared_private_are_not() {
        let arch = arch();
        let g = graph();
        let mut rng = Pcg32::seed(4);
        let store = GraphWeights::init(&g, &arch, &[2, 2, 2], &mut rng);
        let p0 = store.assemble(&g, &arch, 0);
        let p1 = store.assemble(&g, &arch, 1);
        let p2 = store.assemble(&g, &arch, 2);
        assert_eq!(p0[0], p1[0]); // segment 0 shared by all
        assert_eq!(p0[0], p2[0]);
        assert_eq!(p0[2], p1[2]); // segment 1 shared by 0,1
        assert_ne!(p0[2], p2[2]); // ...but not by 2
        assert_ne!(p0[8], p1[8]); // heads private
    }

    #[test]
    fn write_back_propagates_to_groupmates() {
        let arch = arch();
        let g = graph();
        let mut rng = Pcg32::seed(5);
        let mut store = GraphWeights::init(&g, &arch, &[2, 2, 2], &mut rng);
        let mut params = store.assemble(&g, &arch, 0);
        for p in params.iter_mut() {
            for v in p.data.iter_mut() {
                *v += 1.0;
            }
        }
        store.write_back(&g, &arch, 0, params.clone());
        let p1 = store.assemble(&g, &arch, 1);
        // task 1 sees task 0's update on shared segments 0 and 1
        assert_eq!(p1[0], params[0]);
        assert_eq!(p1[2], params[2]);
        // but not on the private head
        assert_ne!(p1[8], params[8]);
    }

    #[test]
    fn total_bytes_matches_graph_model_bytes() {
        let arch = arch();
        let g = graph();
        let mut rng = Pcg32::seed(6);
        let ncls = vec![2usize, 3, 5];
        let store = GraphWeights::init(&g, &arch, &ncls, &mut rng);
        assert_eq!(store.total_bytes(), g.model_bytes(&arch, &ncls));
    }

    #[test]
    fn from_task_params_roundtrip_disjoint() {
        let arch = arch();
        let g = TaskGraph::disjoint(2, vec![1, 3, 4]);
        let mut rng = Pcg32::seed(7);
        let per_task: Vec<Vec<Tensor>> = (0..2)
            .map(|_| {
                arch.flat_param_shapes(2)
                    .into_iter()
                    .map(|s| Tensor::he_init(s, &mut rng))
                    .collect()
            })
            .collect();
        let store = GraphWeights::from_task_params(&g, &arch, &per_task);
        for t in 0..2 {
            assert_eq!(store.assemble(&g, &arch, t), per_task[t]);
        }
    }
}
