//! `antler` — CLI for the Antler multitask-inference coordinator.
//!
//!   antler bench <fig3|fig7|fig8|table3|fig9|fig10|fig11|table4|
//!                 fig12|fig14|fig15|fig16|table5|all-sim|all> [opts]
//!   antler order  --nodes N [--precedence a>b,c>d] [--cyclic]
//!   antler graph  --dataset <name> [--bp 3] [--max-graphs 400]
//!   antler serve  --deployment <audio|image> [--frames 100]
//!                 [--conditional] [--shards N] [--batch B|auto]
//!                 [--batch-max M] [--producers K] [--queue-depth D]
//!                 [--steal] [--round-robin] [--steps-ind N] [--steps-re N]
//!                 [--fast-tier-bytes N|max] [--prefetch on|off]
//!                 [--listen ADDR] [--conns N] [--qos on|off]
//!                 [--tenants N] [--replan on|off] [--drift-threshold X]
//!   antler check  # verify backend + layer round-trip
//!
//! Every subcommand accepts `--backend reference|pjrt` (equivalent to
//! setting `ANTLER_BACKEND`); the default is PJRT when built with the
//! `pjrt` feature and artifacts exist, the pure-Rust reference backend
//! otherwise.

use anyhow::{anyhow, Result};

use antler::bench;
use antler::coordinator::{
    pipeline, serve, serve_net, serve_net_registry, serve_sharded_opts,
    serve_sharded_registry, serve_sharded_sources_registry, spawn_replanner,
    BlockExecutor, DriftConfig, NetOpts, PlanRegistry, ServePlan, ShardOpts,
    TenantSpec,
};
use antler::sync::Arc;
use antler::data;
use antler::device::Device;
use antler::ordering::{solve_held_karp, OrderingProblem};
use antler::runtime::{self, Backend, ReferenceBackend};
use antler::taskgraph::select::select_tradeoff;
use antler::testkit::gen;
use antler::util::cli::{self, Args};
use antler::util::rng::Pcg32;

fn main() {
    let args = Args::from_env();
    if let Some(b) = args.get("backend") {
        std::env::set_var(runtime::BACKEND_ENV, b);
    }
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("bench") => {
            let id = args
                .positional
                .first()
                .map(|s| s.as_str())
                .unwrap_or("all-sim");
            if !bench::run_driver(id, args)? {
                return Err(anyhow!("unknown bench id {id:?}"));
            }
            Ok(())
        }
        Some("order") => cmd_order(args),
        Some("graph") => cmd_graph(args),
        Some("serve") => cmd_serve(args),
        Some("check") => cmd_check(),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown subcommand {cmd:?}\n");
            }
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "antler — efficient multitask inference for resource-constrained systems\n\
         \n\
         subcommands:\n\
         \x20 bench <id>      regenerate a paper table/figure (fig3..table5, all-sim, all)\n\
         \x20 order           solve a random task-ordering instance exactly\n\
         \x20 graph           enumerate+select a task graph for a dataset analog\n\
         \x20 serve           run the live serving loop on a deployment stream\n\
         \x20                 (--shards N executors, work-stealing scheduler;\n\
         \x20                 --batch B drains B frames per forward, --batch auto\n\
         \x20                 adapts within [1, --batch-max] from load;\n\
         \x20                 --producers K feeds via K ingest threads;\n\
         \x20                 --queue-depth D bounds the injector;\n\
         \x20                 --round-robin selects the baseline scheduler;\n\
         \x20                 --fast-tier-bytes N caps the two-tier weight\n\
         \x20                 memory per executor ('max' = unbounded) and\n\
         \x20                 --prefetch on|off toggles its pipelined loads;\n\
         \x20                 --listen ADDR serves length-prefixed frames\n\
         \x20                 with tenant/QoS/deadline headers over TCP,\n\
         \x20                 --conns N caps accepted connections and\n\
         \x20                 --qos on|off toggles class-aware admission;\n\
         \x20                 --tenants N compiles N per-tenant plans into a\n\
         \x20                 versioned registry (frames route by tenant,\n\
         \x20                 plans hot-swap by epoch), --replan on runs the\n\
         \x20                 background cost-drift replanner and\n\
         \x20                 --drift-threshold X sets its trigger)\n\
         \x20 check           verify backend + layer round-trip\n\
         \n\
         global: --backend reference|pjrt (or ANTLER_BACKEND)"
    );
}

fn cmd_order(args: &Args) -> Result<()> {
    let n = args.usize("nodes", 8);
    let seed = args.u64("seed", 1);
    let mut rng = Pcg32::seed(seed);
    let flat = gen::sym_cost_matrix(&mut rng, n, 100.0);
    let cost: Vec<Vec<f64>> =
        (0..n).map(|i| flat[i * n..(i + 1) * n].to_vec()).collect();
    let mut p = OrderingProblem::from_matrix(cost);
    if args.flag("cyclic") {
        p = p.cyclic();
    }
    if let Some(spec) = args.get("precedence") {
        // strict: a malformed pair is an error, not a silently dropped
        // constraint
        let prec = cli::parse_precedence(spec).map_err(|e| anyhow!(e))?;
        p = p.with_precedence(prec);
    }
    let s = solve_held_karp(&p).ok_or_else(|| anyhow!("infeasible instance"))?;
    println!("order: {:?}\ncost:  {:.2}", s.order, s.cost);
    Ok(())
}

fn cmd_graph(args: &Args) -> Result<()> {
    let name = args.get_or("dataset", "mnist-s");
    let ds = data::dataset_by_name(name)
        .ok_or_else(|| anyhow!("unknown dataset {name:?}"))?;
    let archs = bench::figures_sim::arch_specs();
    let arch = &archs[ds.arch];
    let device = Device::by_name(args.get_or("device", "msp430"))
        .ok_or_else(|| anyhow!("unknown device"))?;
    let (_aff, scores) = bench::figures_sim::dataset_scores(
        ds.name,
        arch,
        ds.n_classes,
        ds.seed,
        &device,
        args.usize("bp", 3),
        args.usize("max-graphs", 400),
    );
    let sel = select_tradeoff(&scores);
    let g = &scores[sel].graph;
    println!(
        "dataset {} ({} tasks, arch {}): {} candidates scored",
        ds.name,
        ds.n_classes,
        ds.arch,
        scores.len()
    );
    println!("selected graph: bounds {:?}", g.bounds);
    for (s, p) in g.partitions.iter().enumerate() {
        println!("  segment {s}: {:?}", p.groups());
    }
    println!(
        "variety {:.3}, size {:.1}KB, round {} on {}, order {:?}",
        scores[sel].variety,
        scores[sel].model_bytes as f64 / 1024.0,
        bench::fmt_time(scores[sel].exec_time),
        device.name,
        scores[sel].order
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let which = args.get_or("deployment", "audio");
    // numeric serve flags parse strictly: a typo'd value is a loud exit
    // naming the flag, never a silent fallback to the default
    let strict = |key: &str, default| {
        args.usize_strict(key, default).map_err(|e| anyhow!(e))
    };
    let shards = strict("shards", 1)?;
    // `--batch B` drains a fixed B frames per forward; `--batch auto`
    // lets each shard adapt within [1, --batch-max] (AIMD on injector
    // depth and its own service time — coordinator::shard::BatchPolicy)
    let (batch, adaptive) =
        match cli::parse_batch_arg(args.get_or("batch", "1"))
            .map_err(|e| anyhow!(e))?
        {
            None => (strict("batch-max", 8)?, true),
            Some(b) => (b, false),
        };
    // `--producers K` splits the deployment stream over K sources fed by
    // K ingest threads (the multi-producer tier in front of the
    // work-stealing scheduler)
    let producers = strict("producers", 1)?;
    let queue_depth = strict("queue-depth", 64)?;
    // --steal is the (default) work-stealing scheduler; --round-robin
    // opts back into the PR-3 baseline for comparison
    let steal = args.flag("steal") || !args.flag("round-robin");
    // `--listen ADDR` swaps the synthetic deployment stream for the
    // framed TCP front-end (coordinator::net): frames arrive over up to
    // `--conns` connections carrying tenant/QoS/deadline headers
    let listen = args.get("listen");
    // `--tenants N` compiles N per-tenant plans (round-robin task split
    // through the same affinity/Held-Karp pipeline) into a versioned
    // PlanRegistry; frames route by tenant and plans hot-swap by epoch.
    // `--replan on` runs the background cost-drift replanner, which also
    // forces the registry path at N=1 (the whole task set is one tenant).
    let tenants = strict("tenants", 1)?.max(1);
    let replan = cli::parse_switch("replan", args.get_or("replan", "off"))
        .map_err(|e| anyhow!(e))?;
    let drift_threshold: f64 = match args.get("drift-threshold") {
        Some(v) => v.parse().map_err(|_| {
            anyhow!("--drift-threshold wants a number, got {v:?}")
        })?,
        None => DriftConfig::default().threshold,
    };
    let multi = tenants > 1 || replan;
    let sharded = listen.is_some()
        || shards > 1
        || batch > 1
        || adaptive
        || producers > 1
        || multi;
    // refuse the incompatible combination BEFORE the expensive prepare:
    // sharded/batched serving needs Send executors, and the PJRT engine
    // is Rc-based (!Send)
    if sharded && std::env::var(runtime::BACKEND_ENV).as_deref() == Ok("pjrt") {
        return Err(anyhow!(
            "--shards/--batch/--producers require the Send reference \
             backend; the pjrt engine is single-threaded (drop --backend \
             pjrt, --shards, --batch and --producers)"
        ));
    }
    if producers > 1 && !steal {
        return Err(anyhow!(
            "--producers feeds the work-stealing scheduler; drop \
             --round-robin"
        ));
    }
    if listen.is_some() && !steal {
        // serve_net re-checks this, but refuse before the expensive
        // deployment prepare
        return Err(anyhow!(
            "the network front-end fronts the work-stealing scheduler; \
             drop --round-robin to use --listen"
        ));
    }
    if adaptive && !steal {
        return Err(anyhow!(
            "--batch auto adapts the work-stealing scheduler's pops; the \
             round-robin baseline is frame-at-a-time (drop --round-robin)"
        ));
    }
    if multi && !steal {
        // serve_sharded_registry_feed re-checks this, but refuse before
        // the expensive deployment prepare
        return Err(anyhow!(
            "tenant-routed serving runs on the work-stealing scheduler; \
             drop --round-robin to use --tenants"
        ));
    }
    let (bundle, be) = bench::figures_train::deployment_bundle(which, args)?;
    let prep = &bundle.prep;
    let n = prep.ncls.len();
    let frames_n = strict("frames", 100)?;
    let frames: Vec<(u64, antler::model::Tensor)> = (0..frames_n)
        .map(|i| (i as u64, bundle.data.x.slice_batch(i % bundle.data.len(), 1)))
        .collect();
    let conditional: Vec<(usize, usize)> = if args.flag("conditional") {
        (1..n).map(|t| (0usize, t)).collect()
    } else {
        vec![]
    };
    let plan =
        ServePlan { order: prep.order.clone(), conditional: conditional.clone() };

    // `--fast-tier-bytes N` turns on the two-tier weight memory
    // (`memory::tier`): each executor gets a bounded fast tier priced
    // from the deployment device's external-read bandwidth; `--prefetch
    // off` keeps the tier but disables its pipelined lookahead loads
    let tier = match args.get("fast-tier-bytes") {
        Some(v) => {
            let bytes = if v == "max" {
                usize::MAX
            } else {
                v.parse().map_err(|_| {
                    anyhow!("--fast-tier-bytes wants a byte count or 'max'")
                })?
            };
            let prefetch = match args.get_or("prefetch", "on") {
                "on" => true,
                "off" => false,
                other => {
                    return Err(anyhow!("--prefetch on|off, got {other:?}"))
                }
            };
            Some(antler::memory::tier::TierConfig::for_device(
                &bundle.device,
                bytes,
                prefetch,
            ))
        }
        None => None,
    };

    let (report, tier_counters) = if sharded {
        // sharded/batched serving always runs on the Send reference
        // backend — one executor per shard on the scheduler pool
        println!(
            "sharded serving runs on the reference backend ({shards} \
             executor{}, {} scheduler{})",
            if shards == 1 { "" } else { "s" },
            if steal { "work-stealing" } else { "round-robin" },
            if steal {
                if adaptive {
                    format!(", batch auto (max {batch})")
                } else {
                    format!(", batch {batch}")
                }
            } else {
                String::from(", frame-at-a-time")
            },
        );
        if !steal && batch > 1 {
            println!(
                "note: --batch is a work-stealing feature; the round-robin \
                 baseline serves frame-at-a-time"
            );
        }
        let make = |_s: usize| {
            Ok(BlockExecutor::new(
                ReferenceBackend::new(),
                bundle.device.clone(),
                prep.arch.clone(),
                prep.graph.clone(),
                prep.ncls.clone(),
                prep.store.clone(),
            ))
        };
        let opts = ShardOpts {
            queue_depth,
            batch,
            adaptive_batch: adaptive,
            steal,
            tier,
            ..ShardOpts::default()
        };
        // --tenants / --replan: compile one plan per tenant through the
        // same affinity/Held-Karp pipeline, seed the versioned registry
        // at epoch 0, and (with --replan on) start the background
        // cost-drift replanner that publishes new epochs mid-stream
        let mut registry_ctx = if multi {
            let plans: Vec<ServePlan> = pipeline::compile_tenant_plans(
                prep,
                &bundle.device,
                tenants,
                &[],
                &[],
            )
            .into_iter()
            .map(|mut p| {
                // the CLI's conditional gates apply to whichever tenant
                // owns both endpoints
                p.conditional = conditional
                    .iter()
                    .copied()
                    .filter(|&(a, b)| {
                        p.order.contains(&a) && p.order.contains(&b)
                    })
                    .collect();
                p
            })
            .collect();
            for (t, p) in plans.iter().enumerate() {
                println!("tenant {t}: plan order {:?}", p.order);
            }
            let registry = Arc::new(PlanRegistry::new(plans));
            let (obs, replanner) = if replan {
                let cost = antler::memory::cost_matrix(
                    &bundle.device,
                    &prep.arch,
                    &prep.graph,
                    &prep.ncls,
                    false,
                );
                let specs: Vec<TenantSpec> =
                    antler::taskgraph::tenant_task_split(n, tenants)
                        .into_iter()
                        .enumerate()
                        .map(|(t, tasks)| TenantSpec {
                            tenant: t as u32,
                            tasks,
                            cost: cost.clone(),
                            precedence: vec![],
                            conditional: vec![],
                        })
                        .collect();
                let cfg = DriftConfig {
                    threshold: drift_threshold,
                    ..DriftConfig::default()
                };
                println!(
                    "replanner on: drift threshold {:.2}, min samples {}",
                    cfg.threshold, cfg.min_samples
                );
                let (tx, handle) =
                    spawn_replanner(Arc::clone(&registry), specs, cfg);
                (Some(tx), Some(handle))
            } else {
                (None, None)
            };
            Some((registry, obs, replanner))
        } else {
            None
        };
        let sr = if let Some(addr) = listen {
            let conns = strict("conns", 1024)?;
            let qos = cli::parse_switch("qos", args.get_or("qos", "on"))
                .map_err(|e| anyhow!(e))?;
            let listener = std::net::TcpListener::bind(addr)
                .map_err(|e| anyhow!("--listen cannot bind {addr}: {e}"))?;
            let local = listener
                .local_addr()
                .map_err(|e| anyhow!("--listen local_addr: {e}"))?;
            println!(
                "listening on {local}: up to {conns} connection{} over {} \
                 producer{}, qos {}",
                if conns == 1 { "" } else { "s" },
                producers.max(1),
                if producers.max(1) == 1 { "" } else { "s" },
                if qos { "on" } else { "off" }
            );
            let net = NetOpts {
                producers: producers.max(1),
                max_conns: conns,
                qos,
                ..NetOpts::default()
            };
            let (sr, nr) = match &mut registry_ctx {
                Some((registry, obs, _)) => serve_net_registry(
                    make,
                    shards,
                    Arc::clone(registry),
                    listener,
                    &net,
                    &opts,
                    obs.take(),
                )?,
                None => serve_net(make, shards, &plan, listener, &net, &opts)?,
            };
            println!(
                "network front-end: {} connection{} closed, offered {} \
                 delivered {} dropped {} ({} truncated)",
                nr.conns.len(),
                if nr.conns.len() == 1 { "" } else { "s" },
                nr.offered(),
                nr.delivered(),
                nr.dropped(),
                nr.dropped_truncated()
            );
            print!("{}", nr.class_table());
            print!("{}", nr.tenant_table());
            sr
        } else if producers > 1 {
            // ONE assignment convention for frame→producer fan-out:
            // positional round-robin (ingest::split_round_robin), the same
            // rule run_ingest and the listener use. The old inline
            // `id % producers` split disagreed with it whenever the
            // producer count was clamped, stranding whole sources.
            let sources = antler::coordinator::ingest::split_round_robin(
                frames, producers, "src",
            );
            let (sr, ingest) = match &mut registry_ctx {
                Some((registry, obs, _)) => {
                    // source i belongs to tenant i % N — the positional
                    // rule again, one level up
                    let sources: Vec<_> = sources
                        .into_iter()
                        .enumerate()
                        .map(|(i, s)| s.with_tenant((i % tenants) as u32))
                        .collect();
                    serve_sharded_sources_registry(
                        make,
                        shards,
                        Arc::clone(registry),
                        sources,
                        producers,
                        &opts,
                        obs.take(),
                    )?
                }
                None => antler::coordinator::serve_sharded_sources(
                    make, shards, &plan, sources, producers, &opts,
                )?,
            };
            println!("ingest over {} producers:", ingest.producers);
            for s in &ingest.sources {
                println!(
                    "  {}: offered {} delivered {} dropped {} \
                     ({} stale, {} backpressure)",
                    s.name,
                    s.offered,
                    s.delivered,
                    s.dropped(),
                    s.dropped_stale,
                    s.dropped_backpressure
                );
            }
            sr
        } else {
            match &mut registry_ctx {
                Some((registry, obs, _)) => {
                    // frame i belongs to tenant i % N: the synthetic
                    // stream interleaves tenants round-robin
                    let tframes: Vec<_> = frames
                        .into_iter()
                        .enumerate()
                        .map(|(i, (id, x))| (id, (i % tenants) as u32, x))
                        .collect();
                    serve_sharded_registry(
                        make,
                        shards,
                        Arc::clone(registry),
                        tframes,
                        &opts,
                        obs.take(),
                    )?
                }
                None => serve_sharded_opts(make, shards, &plan, frames, &opts)?,
            }
        };
        // the replanner exits when the serve drops the last observation
        // sender; its join returns every epoch it published
        if let Some((_registry, obs, replanner)) = registry_ctx {
            drop(obs);
            if let Some(handle) = replanner {
                let events = handle
                    .join()
                    .map_err(|_| anyhow!("replanner thread panicked"))?;
                println!("replanner: {} replan(s) published", events.len());
                for e in &events {
                    println!(
                        "  tenant {} -> epoch {} (max drift {:.2})",
                        e.tenant, e.epoch, e.max_drift
                    );
                }
            }
            println!("frames per tenant: {:?}", sr.frames_per_tenant());
            if let Some(t) = sr.epoch_table() {
                print!("{t}");
            }
        }
        println!(
            "sharded over {} executors ({} busy): per-shard frames {:?}",
            sr.shards,
            sr.busy_shards(),
            sr.frames_per_shard
        );
        if steal && (batch > 1 || adaptive) {
            let agg = sr.total_hist();
            println!(
                "batch histogram (pops of size 1..{}): {:?}, mean batch {:.2}",
                agg.len(),
                agg,
                sr.mean_batch()
            );
        }
        if let Some(table) = sr.shard_error_table() {
            print!("{table}");
        }
        (sr.aggregate, sr.tier)
    } else {
        let mut ex = BlockExecutor::new(
            be.as_ref(),
            bundle.device.clone(),
            prep.arch.clone(),
            prep.graph.clone(),
            prep.ncls.clone(),
            prep.store.clone(),
        );
        if let Some(cfg) = tier {
            ex.enable_tier(cfg);
        }
        let warmed = ex.warmup()?;
        println!(
            "serving {which} on {}: {n} tasks, order {:?}, {warmed} executables warm",
            be.name(),
            prep.order
        );
        let r = serve(&mut ex, &plan, frames, 64, None)?;
        ex.tier_close();
        (r, ex.tier_counters())
    };
    println!(
        "frames={} dropped={} wall={:.2}s throughput={:.1} fps",
        report.frames, report.dropped, report.wall_s, report.throughput_fps
    );
    println!(
        "host latency p50/p95/p99 = {:.2}/{:.2}/{:.2} ms",
        report.latency_p50_ms, report.latency_p95_ms, report.latency_p99_ms
    );
    println!(
        "simulated device ({}): {}/frame, {}/frame; tasks skipped {}",
        bundle.device.name,
        bench::fmt_time(report.sim_time_per_frame_s),
        bench::fmt_energy(report.sim_energy_per_frame_j),
        report.tasks_skipped
    );
    println!(
        "layer execs {} / skips {} ({:.0}% compute avoided by sharing)",
        report.layer_execs,
        report.layer_skips,
        report.layer_skips as f64
            / (report.layer_execs + report.layer_skips).max(1) as f64
            * 100.0
    );
    if let Some(tc) = tier_counters {
        println!(
            "weight tier: {} hits / {} misses ({} prefetch hits), \
             {} evictions, {} load stall, {:.1} KB loaded",
            tc.hits,
            tc.misses,
            tc.prefetch_hits,
            tc.evictions,
            bench::fmt_time(tc.stall_s),
            tc.bytes_loaded as f64 / 1024.0
        );
    }
    let _ = pipeline::deployment_order(prep, &bundle.device, vec![], vec![])?;
    Ok(())
}

fn cmd_check() -> Result<()> {
    let be = runtime::backend_from_env()?;
    println!("backend: {}", be.name());
    // round-trip one layer per arch
    for name in be.arch_names() {
        let arch = be.arch(&name)?;
        let mut rng = Pcg32::seed(0);
        let mut shape = vec![1usize];
        shape.extend_from_slice(&arch.input);
        let x = antler::model::Tensor::he_init(shape, &mut rng);
        let ps = arch.layers[0].param_shapes(2);
        let w = antler::model::Tensor::he_init(ps[0].clone(), &mut rng);
        let b = antler::model::Tensor::zeros(ps[1].clone());
        let y = be.run_layer(&arch, 0, None, &x, &w, &b)?;
        println!("  {}: layer0 {:?} -> {:?} ok", arch.name, x.shape, y.shape);
    }
    println!("check OK");
    Ok(())
}
