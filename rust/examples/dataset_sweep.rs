//! Dataset-driven comparison sweep (§6.3, Figures 9–11 + Table 4 in one
//! pass): for each of the nine dataset analogs, select a task graph and
//! compare Antler's per-round cost against the four baselines on both
//! simulated platforms.
//!
//!   cargo run --release --example dataset_sweep [-- --max-graphs 800]

use antler::baselines::{self, SystemKind};
use antler::bench::figures_sim::{arch_specs, dataset_scores};
use antler::bench::{fmt_energy, fmt_time};
use antler::data::standard_datasets;
use antler::device::Device;
use antler::taskgraph::select::select_tradeoff;
use antler::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let max_graphs = args.usize("max-graphs", 400);
    let archs = arch_specs();
    for device in [Device::msp430(), Device::stm32h747()] {
        println!("\n=== {} ===", device.name);
        println!(
            "{:<10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>7}  {:>10}",
            "dataset", "Vanilla", "Antler", "NWV", "NWS", "YONO", "win", "energy-sav"
        );
        for ds in standard_datasets() {
            let arch = &archs[ds.arch];
            let (_aff, scores) = dataset_scores(
                ds.name,
                arch,
                ds.n_classes,
                ds.seed,
                &device,
                3,
                max_graphs,
            );
            let sel = select_tradeoff(&scores);
            let ncls = vec![2usize; ds.n_classes];
            let inp = baselines::CostInputs {
                device: &device,
                arch,
                ncls: &ncls,
                antler_graph: &scores[sel].graph,
                antler_order: &scores[sel].order,
                nws_ext_bytes_per_task: arch.total_params(2) * 4 * 7 / 100,
            };
            let mut times = Vec::new();
            let mut energies = Vec::new();
            for sys in SystemKind::all() {
                let c = baselines::round_cost(sys, &inp);
                times.push(c.time());
                energies.push(c.energy());
            }
            // SystemKind::all() = [Vanilla, Antler, NWV, NWS, YONO]
            let antler_t = times[1];
            let best_baseline = times
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != 1)
                .map(|(_, &t)| t)
                .fold(f64::INFINITY, f64::min);
            let antler_e = energies[1];
            let worst_e = energies.iter().cloned().fold(0.0, f64::max);
            println!(
                "{:<10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>6.1}x  {:>9.0}%",
                ds.name,
                fmt_time(times[0]),
                fmt_time(times[1]),
                fmt_time(times[2]),
                fmt_time(times[3]),
                fmt_time(times[4]),
                best_baseline / antler_t,
                (1.0 - antler_e / worst_e) * 100.0
            );
            let _ = fmt_energy(antler_e);
        }
    }
    println!("\n(win = Antler speedup over the best baseline; energy-sav vs worst baseline)");
}
