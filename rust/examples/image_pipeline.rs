//! §7.2 end-to-end: the four-task multitask IMAGE inference system
//! (presence / mask / identity / emotion) on the simulated 32-bit
//! STM32H747, with the paper's precedence constraint that presence
//! detection runs before everything else.
//!
//!   cargo run --release --example image_pipeline

use antler::coordinator::{pipeline, serve, BlockExecutor, ServePlan};
use antler::data::image_stream_spec;
use antler::device::Device;
use antler::runtime::{backend_from_env, Backend};

fn main() -> anyhow::Result<()> {
    let backend = backend_from_env()?;
    println!("backend: {}", backend.name());
    let spec = image_stream_spec();
    let device = Device::stm32h747();
    let data = spec.generate(600);
    println!(
        "image stream: {} samples, tasks {:?} (classes {:?})",
        data.len(),
        spec.tasks.iter().map(|t| t.name).collect::<Vec<_>>(),
        spec.ncls_vec()
    );

    let cfg = pipeline::PrepareConfig {
        steps_individual: 150,
        steps_retrain: 400,
        lr: 0.02,
        device: device.clone(),
        ..Default::default()
    };
    let prep = pipeline::prepare(backend.as_ref(), spec.arch, &data, &cfg)?;

    println!("\ntask graph (Fig 14b analog): bounds {:?}", prep.graph.bounds);
    for (s, p) in prep.graph.partitions.iter().enumerate() {
        println!("  segment {s}: {:?}", p.groups());
    }

    // the paper's §7 constraint: presence (τ0) precedes every other task
    let n = spec.n_tasks();
    let prec: Vec<(usize, usize)> = (1..n).map(|t| (0, t)).collect();
    let order = pipeline::deployment_order(&prep, &device, prec, vec![])?;
    assert_eq!(order[0], 0, "presence must run first");
    println!("order under precedence: {:?}", order);

    println!("\nper-task accuracy:");
    for (t, task) in spec.tasks.iter().enumerate() {
        println!(
            "  {:<9} vanilla {:>5.1}%  antler {:>5.1}%",
            task.name,
            prep.vanilla_acc[t] * 100.0,
            prep.antler_acc[t] * 100.0
        );
    }

    let frames: Vec<_> = (0..100u64)
        .map(|i| (i, data.x.slice_batch(i as usize % data.len(), 1)))
        .collect();
    let mut ex = BlockExecutor::new(
        backend.as_ref(),
        device.clone(),
        prep.arch.clone(),
        prep.graph.clone(),
        prep.ncls.clone(),
        prep.store.clone(),
    );
    ex.warmup()?;
    // presence gates the rest at runtime (conditional execution)
    let plan = ServePlan { order, conditional: (1..n).map(|t| (0, t)).collect() };
    let r = serve(&mut ex, &plan, frames, 64, None)?;
    println!(
        "\nserved {} frames: sim {:.3} ms/frame, {:.4} mJ/frame on {}, host {:.0} fps (p50 {:.2} ms), {} dependent tasks skipped",
        r.frames,
        r.sim_time_per_frame_s * 1e3,
        r.sim_energy_per_frame_j * 1e3,
        device.name,
        r.throughput_fps,
        r.latency_p50_ms,
        r.tasks_skipped
    );
    Ok(())
}
