//! §7.1 end-to-end: the five-task multitask AUDIO inference system
//! (presence / command / speaker / emotion / distance) on the simulated
//! 16-bit MSP430FR5994 — the repository's END-TO-END VALIDATION run
//! (recorded in EXPERIMENTS.md).
//!
//!   cargo run --release --example audio_assistant
//!
//! Trains the task set from a synthetic multi-factor audio-feature
//! stream, builds the task graph + order, then serves the stream three
//! ways: unconstrained, with the presence-precedence constraint
//! (Antler-PC), and with the 80%-conditional constraint (Antler-CC,
//! live skipping), reporting latency/throughput and simulated cost.

use antler::coordinator::{pipeline, serve, BlockExecutor, ServePlan};
use antler::data::audio_stream_spec;
use antler::device::Device;
use antler::runtime::{backend_from_env, Backend};
use antler::taskgraph::TaskGraph;
use antler::trainer::GraphWeights;

fn main() -> anyhow::Result<()> {
    let backend = backend_from_env()?;
    println!("backend: {}", backend.name());
    let spec = audio_stream_spec();
    let device = Device::msp430();
    let data = spec.generate(800);
    println!(
        "audio stream: {} samples, tasks {:?} (classes {:?})",
        data.len(),
        spec.tasks.iter().map(|t| t.name).collect::<Vec<_>>(),
        spec.ncls_vec()
    );

    let cfg = pipeline::PrepareConfig {
        steps_individual: 200,
        steps_retrain: 1200,
        device: device.clone(),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let prep = pipeline::prepare(backend.as_ref(), spec.arch, &data, &cfg)?;
    println!("pipeline prepared in {:.1}s", t0.elapsed().as_secs_f64());

    println!("\ntask graph (Fig 14a analog): bounds {:?}", prep.graph.bounds);
    for (s, p) in prep.graph.partitions.iter().enumerate() {
        println!("  segment {s}: {:?}", p.groups());
    }
    println!("\nper-task accuracy (Fig 16a analog):");
    for (t, task) in spec.tasks.iter().enumerate() {
        println!(
            "  {:<9} ({:>2} classes): vanilla {:>5.1}%  antler {:>5.1}%",
            task.name,
            task.ncls,
            prep.vanilla_acc[t] * 100.0,
            prep.antler_acc[t] * 100.0
        );
    }

    // three Antler variants + Vanilla (Fig 15a analog)
    let n = spec.n_tasks();
    let frames: Vec<_> = (0..120u64)
        .map(|i| (i, data.x.slice_batch(i as usize % data.len(), 1)))
        .collect();
    let prec: Vec<(usize, usize)> = (1..n).map(|t| (0, t)).collect();
    let cond: Vec<(usize, usize, f64)> =
        (1..n).map(|t| (0, t, spec.presence_prob)).collect();
    let order_pc = pipeline::deployment_order(&prep, &device, prec, vec![])?;
    let order_cc = pipeline::deployment_order(&prep, &device, vec![], cond)?;

    let variants: Vec<(&str, TaskGraph, Vec<usize>, Vec<(usize, usize)>)> = vec![
        ("Vanilla", TaskGraph::disjoint(n, prep.graph.bounds.clone()), (0..n).collect(), vec![]),
        ("Antler", prep.graph.clone(), prep.order.clone(), vec![]),
        ("Antler-PC", prep.graph.clone(), order_pc, vec![]),
        ("Antler-CC", prep.graph.clone(), order_cc, (1..n).map(|t| (0, t)).collect()),
    ];
    println!("\nserving 120 frames on simulated {}:", device.name);
    let mut vanilla_time = 0.0;
    for (name, graph, order, conditional) in variants {
        let store = if name == "Vanilla" {
            GraphWeights::from_task_params(&graph, &prep.arch, &prep.task_params)
        } else {
            prep.store.clone()
        };
        let mut ex = BlockExecutor::new(
            backend.as_ref(),
            device.clone(),
            prep.arch.clone(),
            graph,
            prep.ncls.clone(),
            store,
        );
        ex.warmup()?;
        let plan = ServePlan { order, conditional };
        let r = serve(&mut ex, &plan, frames.clone(), 64, None)?;
        if name == "Vanilla" {
            vanilla_time = r.sim_time_per_frame_s;
        }
        println!(
            "  {:<9} sim {:>8.2} ms/frame ({:>4.1}x) | {:>7.3} mJ/frame | host {:>6.1} fps p50 {:>5.2} ms | skipped {}",
            name,
            r.sim_time_per_frame_s * 1e3,
            vanilla_time / r.sim_time_per_frame_s,
            r.sim_energy_per_frame_j * 1e3,
            r.throughput_fps,
            r.latency_p50_ms,
            r.tasks_skipped
        );
    }
    println!(
        "\nmemory (Table 5 analog): vanilla {:.0}KB vs antler {:.0}KB",
        prep.ncls
            .iter()
            .map(|&c| prep.arch.total_params(c) * 4)
            .sum::<usize>() as f64
            / 1024.0,
        prep.graph.model_bytes(&prep.arch, &prep.ncls) as f64 / 1024.0
    );
    Ok(())
}
