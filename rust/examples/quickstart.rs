//! Quickstart: the whole Antler flow on a small task set in ~a minute.
//!
//!   cargo run --release --example quickstart
//!
//! Runs on the pure-Rust reference backend out of the box; build with
//! `--features pjrt` (plus `make artifacts`) to use the PJRT engine.
//!
//! 1. generate a 6-task IMU dataset analog
//! 2. train per-task networks (the Vanilla baseline) on the backend
//! 3. profile task affinity at the branch points
//! 4. enumerate task graphs, pick the variety/cost tradeoff point
//! 5. multitask-retrain the selected graph, solve the execution order
//! 6. serve a stream of frames and compare against Vanilla

use antler::coordinator::{pipeline, serve, BlockExecutor, ServePlan};
use antler::data::dataset_by_name;
use antler::device::Device;
use antler::runtime::{backend_from_env, Backend};
use antler::taskgraph::TaskGraph;
use antler::trainer::GraphWeights;

fn main() -> anyhow::Result<()> {
    let backend = backend_from_env()?;
    println!("backend: {}", backend.name());
    let spec = dataset_by_name("hhar-s").unwrap();
    let arch = backend.arch(spec.arch)?;
    let ds = spec.generate(&arch.input, 360);
    println!("dataset {}: {} samples, {} one-vs-rest tasks", spec.name, 360, ds.n_tasks());

    let cfg = pipeline::PrepareConfig {
        steps_individual: 80,
        steps_retrain: 120,
        device: Device::msp430(),
        ..Default::default()
    };
    let prep = pipeline::prepare(backend.as_ref(), spec.arch, &ds, &cfg)?;

    println!("\nselected task graph (of {} candidates):", prep.scores.len());
    println!("  bounds {:?}", prep.graph.bounds);
    for (s, p) in prep.graph.partitions.iter().enumerate() {
        println!("  segment {s}: groups {:?}", p.groups());
    }
    println!("  optimal order: {:?}", prep.order);
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    println!(
        "  accuracy: vanilla {:.1}% vs antler {:.1}%",
        mean(&prep.vanilla_acc) * 100.0,
        mean(&prep.antler_acc) * 100.0
    );

    // serve 50 frames with both systems and compare simulated device cost
    let frames: Vec<_> = (0..50u64)
        .map(|i| (i, ds.x.slice_batch(i as usize % ds.len(), 1)))
        .collect();
    let mut antler_ex = BlockExecutor::new(
        backend.as_ref(),
        Device::msp430(),
        prep.arch.clone(),
        prep.graph.clone(),
        prep.ncls.clone(),
        prep.store.clone(),
    );
    antler_ex.warmup()?;
    let plan = ServePlan::unconditional(prep.order.clone());
    let antler_report = serve(&mut antler_ex, &plan, frames.clone(), 64, None)?;

    let vanilla_graph = TaskGraph::disjoint(ds.n_tasks(), prep.graph.bounds.clone());
    let vstore = GraphWeights::from_task_params(&vanilla_graph, &prep.arch, &prep.task_params);
    let mut vanilla_ex = BlockExecutor::new(
        backend.as_ref(),
        Device::msp430(),
        prep.arch.clone(),
        vanilla_graph,
        prep.ncls.clone(),
        vstore,
    );
    vanilla_ex.warmup()?;
    let vplan = ServePlan::unconditional((0..ds.n_tasks()).collect());
    let vanilla_report = serve(&mut vanilla_ex, &vplan, frames, 64, None)?;

    println!("\nserving 50 frames (simulated MSP430FR5994):");
    println!(
        "  vanilla: {:.2} ms/frame, {:.3} mJ/frame",
        vanilla_report.sim_time_per_frame_s * 1e3,
        vanilla_report.sim_energy_per_frame_j * 1e3
    );
    println!(
        "  antler:  {:.2} ms/frame, {:.3} mJ/frame  ({:.1}x faster, {:.0}% energy saved)",
        antler_report.sim_time_per_frame_s * 1e3,
        antler_report.sim_energy_per_frame_j * 1e3,
        vanilla_report.sim_time_per_frame_s / antler_report.sim_time_per_frame_s,
        (1.0 - antler_report.sim_energy_per_frame_j / vanilla_report.sim_energy_per_frame_j)
            * 100.0
    );
    println!(
        "  host throughput: antler {:.0} fps (layer execs {} / skips {})",
        antler_report.throughput_fps, antler_report.layer_execs, antler_report.layer_skips
    );
    Ok(())
}
