//! Cross-module integration tests (no PJRT artifacts required): the
//! algorithmic pipeline — affinity → enumeration → selection → ordering →
//! cost simulation — plus solver cross-validation and coordinator
//! invariants under the property-testing harness.

use antler::affinity::synthetic_affinity;
use antler::baselines::{self, SystemKind};
use antler::bench::figures_sim::arch_specs;
use antler::device::Device;
use antler::memory::{cost_matrix, ExecSim};
use antler::ordering::{
    solve_brute, solve_genetic, solve_held_karp, GaConfig, OrderingProblem,
};
use antler::taskgraph::select::{score_graph, select_tradeoff, tradeoff_curve};
use antler::taskgraph::{enumerate, TaskGraph};
use antler::testkit::{gen, prop_check};
use antler::tsplib::table3_instances;
use antler::util::rng::Pcg32;

#[test]
fn full_sim_pipeline_five_tasks() {
    let archs = arch_specs();
    let arch = &archs["cnn5"];
    let device = Device::msp430();
    let mut rng = Pcg32::seed(1);
    let aff = synthetic_affinity(5, 3, &mut rng);
    let graphs = enumerate::enumerate_all(5, &[1, 3, 4], None);
    assert!(graphs.len() > 100, "5-task universe: {}", graphs.len());
    let ncls = vec![2usize; 5];
    let scores: Vec<_> = graphs
        .iter()
        .map(|g| score_graph(g, &aff, arch, &ncls, &device))
        .collect();
    let curve = tradeoff_curve(&scores);
    let sel = select_tradeoff(&scores);
    // the tradeoff point must not be an extreme of either trend
    let vmax = scores.iter().map(|s| s.variety).fold(0.0, f64::max);
    let cmax = scores.iter().map(|s| s.exec_time).fold(0.0, f64::max);
    assert!(scores[sel].variety < vmax);
    assert!(scores[sel].exec_time < cmax);
    assert!(curve.len() > 3);
}

#[test]
fn optimal_order_beats_worst_order_in_simulation() {
    // the §4 claim, checked against the *simulator* not the cost matrix:
    // the solver's order is no worse than any of 50 random orders
    let archs = arch_specs();
    let arch = &archs["cnn5"];
    let device = Device::msp430();
    let mut rng = Pcg32::seed(5);
    let aff = synthetic_affinity(6, 3, &mut rng);
    let graphs = enumerate::clustered(&aff, &[1, 3, 4], 100);
    let g = &graphs[graphs.len() / 2];
    let ncls = vec![2usize; 6];
    let c = cost_matrix(&device, arch, g, &ncls, false);
    let sol = solve_held_karp(&OrderingProblem::from_matrix(c)).unwrap();
    let mut sim = ExecSim::new(&device, arch, g, &ncls);
    let best = sim.steady_round_cost(&sol.order, 3).time();
    for _ in 0..50 {
        let perm = gen::permutation(&mut rng, 6);
        let mut sim2 = ExecSim::new(&device, arch, g, &ncls);
        let t = sim2.steady_round_cost(&perm, 3).time();
        assert!(best <= t * 1.2 + 1e-12, "best {} vs random {}", best, t);
    }
}

#[test]
fn three_solvers_agree_on_table3_small_instances() {
    for inst in table3_instances() {
        if inst.nodes > 11 {
            continue;
        }
        let hk = solve_held_karp(&inst.problem).unwrap();
        let bf = solve_brute(&inst.problem).unwrap();
        assert!((hk.cost - bf.cost).abs() < 1e-9, "{}", inst.name);
        let ga = solve_genetic(&inst.problem, &GaConfig::default()).unwrap();
        assert!(ga.cost >= hk.cost - 1e-9, "{}", inst.name);
        assert!(ga.cost <= hk.cost * 1.06 + 1e-9, "{}: ga {} hk {}", inst.name, ga.cost, hk.cost);
    }
}

#[test]
fn prop_cost_matrix_triangle_consistency() {
    // switching costs decompose by shared prefix: if i and j share more
    // segments than i and k, then c[i][j] <= c[i][k]
    let archs = arch_specs();
    let arch = archs["cnn5"].clone();
    prop_check(
        "cost-matrix-prefix-monotone",
        30,
        |rng| {
            let aff = synthetic_affinity(6, 3, rng);
            let graphs = enumerate::clustered(&aff, &[1, 3, 4], 60);
            let pick = rng.below(graphs.len());
            graphs[pick].clone()
        },
        |g| {
            let device = Device::msp430();
            let ncls = vec![2usize; 6];
            let c = cost_matrix(&device, &arch, g, &ncls, false);
            for i in 0..6 {
                for j in 0..6 {
                    for k in 0..6 {
                        if i == j || i == k {
                            continue;
                        }
                        let pj = g.shared_prefix(i, j);
                        let pk = g.shared_prefix(i, k);
                        if pj > pk && c[i][j] > c[i][k] + 1e-12 {
                            return Err(format!(
                                "prefix {} vs {} but cost {} vs {}",
                                pj, pk, c[i][j], c[i][k]
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_round_cost_invariant_under_sample_id() {
    let archs = arch_specs();
    let arch = archs["cnn5"].clone();
    prop_check(
        "round-cost-sample-invariant",
        20,
        |rng| {
            let aff = synthetic_affinity(5, 3, rng);
            let graphs = enumerate::clustered(&aff, &[1, 3, 4], 40);
            let g = graphs[rng.below(graphs.len())].clone();
            let order = gen::permutation(rng, 5);
            (g, order)
        },
        |(g, order)| {
            let device = Device::msp430();
            let ncls = vec![2usize; 5];
            let mut sim = ExecSim::new(&device, &arch, g, &ncls);
            let a = sim.run_round(1, order).time();
            let mut sim2 = ExecSim::new(&device, &arch, g, &ncls);
            let b = sim2.run_round(99, order).time();
            if (a - b).abs() < 1e-15 {
                Ok(())
            } else {
                Err(format!("{a} vs {b}"))
            }
        },
    );
}

#[test]
fn prop_antler_never_worse_than_vanilla() {
    // for ANY graph and ANY order, antler's steady round cost is within
    // epsilon of (and virtually always below) the vanilla disjoint cost
    let archs = arch_specs();
    let arch = archs["cnn5"].clone();
    prop_check(
        "antler-dominates-vanilla",
        25,
        |rng| {
            let aff = synthetic_affinity(6, 3, rng);
            let graphs = enumerate::clustered(&aff, &[1, 3, 4], 50);
            graphs[rng.below(graphs.len())].clone()
        },
        |g| {
            let device = Device::msp430();
            let ncls = vec![2usize; 6];
            let order: Vec<usize> = (0..6).collect();
            let inp = baselines::CostInputs {
                device: &device,
                arch: &arch,
                ncls: &ncls,
                antler_graph: g,
                antler_order: &order,
                nws_ext_bytes_per_task: 0,
            };
            let antler = baselines::round_cost(SystemKind::Antler, &inp).time();
            let vanilla = baselines::round_cost(SystemKind::Vanilla, &inp).time();
            if antler <= vanilla + 1e-12 {
                Ok(())
            } else {
                Err(format!("antler {antler} > vanilla {vanilla}"))
            }
        },
    );
}

#[test]
fn deployment_bounds_fit_architectures() {
    let archs = arch_specs();
    for (name, arch) in &archs {
        for d in 1..=7 {
            let bounds = TaskGraph::default_bounds(arch.n_layers(), d);
            assert!(!bounds.is_empty(), "{name} d={d}");
            assert!(*bounds.last().unwrap() < arch.n_layers());
            for w in bounds.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }
}
