//! End-to-end tests over the real PJRT runtime (skipped gracefully when
//! `make artifacts` has not run): blockwise serving equals whole-network
//! inference, training converges, conditional skipping reduces work.

use antler::coordinator::{pipeline, serve, BlockExecutor, ServePlan};
use antler::data::{audio_stream_spec, dataset_by_name};
use antler::device::Device;
use antler::model::manifest::default_artifacts_dir;
use antler::runtime::Engine;
use antler::taskgraph::TaskGraph;
use antler::trainer::GraphWeights;

fn engine() -> Option<Engine> {
    let dir = default_artifacts_dir();
    dir.join("manifest.json")
        .exists()
        .then(|| Engine::load(&dir).expect("engine loads"))
}

#[test]
fn imu_pipeline_serves_accurately() {
    let Some(eng) = engine() else { return };
    let spec = dataset_by_name("hhar-s").unwrap();
    let ds = spec.generate(&[128], 360);
    let cfg = pipeline::PrepareConfig {
        steps_individual: 60,
        steps_retrain: 90,
        max_graphs: 120,
        device: Device::msp430(),
        ..Default::default()
    };
    let prep = pipeline::prepare(&eng, "dnn4", &ds, &cfg).unwrap();

    // serving answers must match the batch-eval answers for each task
    let mut ex = BlockExecutor::new(
        &eng,
        Device::msp430(),
        prep.arch.clone(),
        prep.graph.clone(),
        prep.ncls.clone(),
        prep.store.clone(),
    );
    ex.warmup().unwrap();
    let mut agree = 0;
    let mut total = 0;
    for (i, sample_idx) in [0usize, 7, 21, 40].into_iter().enumerate() {
        let x = ds.x.slice_batch(sample_idx, 1);
        for t in 0..prep.ncls.len() {
            let (pred, _) = ex.run_task(i as u64, t, &x).unwrap();
            // reference via eval artifact at batch 64
            let params = prep.store.assemble(&prep.graph, &prep.arch, t);
            let mut big = vec![0.0f32; 64 * 128];
            big[..128].copy_from_slice(&x.data);
            let xb = antler::model::Tensor::new(vec![64, 128], big);
            let mut args = vec![antler::runtime::Arg::F32(&xb)];
            for p in &params {
                args.push(antler::runtime::Arg::F32(p));
            }
            let out = eng.run("eval_dnn4_c2", &args).unwrap();
            let row = &out[0].data[0..2];
            let want = (row[1] > row[0]) as usize;
            total += 1;
            if pred == want {
                agree += 1;
            }
        }
    }
    assert_eq!(agree, total, "blockwise serving diverged from batch eval");
}

#[test]
fn conditional_serving_skips_and_saves() {
    let Some(eng) = engine() else { return };
    let spec = audio_stream_spec();
    let data = spec.generate(400);
    let cfg = pipeline::PrepareConfig {
        steps_individual: 40,
        steps_retrain: 60,
        max_graphs: 100,
        device: Device::msp430(),
        ..Default::default()
    };
    let prep = pipeline::prepare(&eng, "cnn5", &data, &cfg).unwrap();
    let n = prep.ncls.len();
    let frames: Vec<_> = (0..30u64)
        .map(|i| (i, data.x.slice_batch(i as usize % data.len(), 1)))
        .collect();

    let run = |conditional: Vec<(usize, usize)>| {
        let mut ex = BlockExecutor::new(
            &eng,
            Device::msp430(),
            prep.arch.clone(),
            prep.graph.clone(),
            prep.ncls.clone(),
            prep.store.clone(),
        );
        ex.warmup().unwrap();
        let mut order = prep.order.clone();
        // presence first so it can gate
        order.retain(|&t| t != 0);
        order.insert(0, 0);
        let plan = ServePlan { order, conditional };
        serve(&mut ex, &plan, frames.clone(), 64, None).unwrap()
    };
    let unconditional = run(vec![]);
    let conditional = run((1..n).map(|t| (0usize, t)).collect());
    assert_eq!(unconditional.frames, 30);
    assert_eq!(conditional.frames, 30);
    // with ~80% presence the conditional run skips some dependents and
    // never costs more
    assert!(conditional.sim_time_per_frame_s <= unconditional.sim_time_per_frame_s + 1e-12);
    if conditional.tasks_skipped > 0 {
        assert!(conditional.sim_time_per_frame_s < unconditional.sim_time_per_frame_s);
    }
}

#[test]
fn vanilla_store_roundtrip_serves() {
    let Some(eng) = engine() else { return };
    let spec = dataset_by_name("hhar-s").unwrap();
    let ds = spec.generate(&[128], 240);
    let arch = eng.manifest().arch("dnn4").unwrap().clone();
    let graph = TaskGraph::disjoint(3, TaskGraph::default_bounds(4, 3));
    let mut rng = antler::util::rng::Pcg32::seed(3);
    let per_task: Vec<Vec<antler::model::Tensor>> = (0..3)
        .map(|_| {
            arch.flat_param_shapes(2)
                .into_iter()
                .map(|s| antler::model::Tensor::he_init(s, &mut rng))
                .collect()
        })
        .collect();
    let store = GraphWeights::from_task_params(&graph, &arch, &per_task);
    let mut ex = BlockExecutor::new(
        &eng,
        Device::msp430(),
        arch,
        graph,
        vec![2, 2, 2],
        store,
    );
    let x = ds.x.slice_batch(0, 1);
    for t in 0..3 {
        let (pred, cost) = ex.run_task(0, t, &x).unwrap();
        assert!(pred < 2);
        assert!(cost.time() > 0.0);
    }
    // disjoint graph: zero activation reuse
    assert_eq!(ex.layer_skips, 0);
}
