//! End-to-end tests over the live runtime. They run unconditionally on
//! the pure-Rust reference backend (no artifacts needed — CI can never
//! pass vacuously); tests/parity.rs cross-checks the PJRT engine against
//! the same backend when artifacts exist.

use antler::coordinator::{
    pipeline, serve, serve_sharded, BlockExecutor, ServePlan,
};
use antler::data::{audio_stream_spec, dataset_by_name};
use antler::device::Device;
use antler::runtime::{Backend, ReferenceBackend};
use antler::taskgraph::TaskGraph;
use antler::trainer::GraphWeights;

#[test]
fn imu_pipeline_serves_accurately() {
    let be = ReferenceBackend::new();
    let spec = dataset_by_name("hhar-s").unwrap();
    let ds = spec.generate(&[128], 360);
    let cfg = pipeline::PrepareConfig {
        steps_individual: 40,
        steps_retrain: 60,
        max_graphs: 120,
        device: Device::msp430(),
        ..Default::default()
    };
    let prep = pipeline::prepare(&be, "dnn4", &ds, &cfg).unwrap();

    // serving answers must match the whole-network eval answers per task
    let mut ex = BlockExecutor::new(
        &be,
        Device::msp430(),
        prep.arch.clone(),
        prep.graph.clone(),
        prep.ncls.clone(),
        prep.store.clone(),
    );
    ex.warmup().unwrap();
    let mut agree = 0;
    let mut total = 0;
    for (i, sample_idx) in [0usize, 7, 21, 40].into_iter().enumerate() {
        let x = ds.x.slice_batch(sample_idx, 1);
        for t in 0..prep.ncls.len() {
            let (pred, _) = ex.run_task(i as u64, t, &x).unwrap();
            let params = prep.store.assemble(&prep.graph, &prep.arch, t);
            let logits = be.eval_logits(&prep.arch, 2, &params, &x).unwrap();
            let want = (logits.data[1] > logits.data[0]) as usize;
            total += 1;
            if pred == want {
                agree += 1;
            }
        }
    }
    assert_eq!(agree, total, "blockwise serving diverged from whole-net eval");
}

#[test]
fn conditional_serving_skips_and_saves() {
    let be = ReferenceBackend::new();
    let spec = audio_stream_spec();
    let data = spec.generate(400);
    let cfg = pipeline::PrepareConfig {
        steps_individual: 16,
        steps_retrain: 24,
        max_graphs: 100,
        device: Device::msp430(),
        ..Default::default()
    };
    let prep = pipeline::prepare(&be, "cnn5", &data, &cfg).unwrap();
    let n = prep.ncls.len();
    let frames: Vec<_> = (0..30u64)
        .map(|i| (i, data.x.slice_batch(i as usize % data.len(), 1)))
        .collect();

    let run = |conditional: Vec<(usize, usize)>| {
        let mut ex = BlockExecutor::new(
            &be,
            Device::msp430(),
            prep.arch.clone(),
            prep.graph.clone(),
            prep.ncls.clone(),
            prep.store.clone(),
        );
        ex.warmup().unwrap();
        let mut order = prep.order.clone();
        // presence first so it can gate
        order.retain(|&t| t != 0);
        order.insert(0, 0);
        let plan = ServePlan { order, conditional };
        serve(&mut ex, &plan, frames.clone(), 64, None).unwrap()
    };
    let unconditional = run(vec![]);
    let conditional = run((1..n).map(|t| (0usize, t)).collect());
    assert_eq!(unconditional.frames, 30);
    assert_eq!(conditional.frames, 30);
    // with ~80% presence the conditional run skips some dependents and
    // never costs more
    assert!(conditional.sim_time_per_frame_s <= unconditional.sim_time_per_frame_s + 1e-12);
    if conditional.tasks_skipped > 0 {
        assert!(conditional.sim_time_per_frame_s < unconditional.sim_time_per_frame_s);
    }
}

#[test]
fn vanilla_store_roundtrip_serves() {
    let be = ReferenceBackend::new();
    let spec = dataset_by_name("hhar-s").unwrap();
    let ds = spec.generate(&[128], 240);
    let arch = be.arch("dnn4").unwrap();
    let graph = TaskGraph::disjoint(3, TaskGraph::default_bounds(4, 3));
    let mut rng = antler::util::rng::Pcg32::seed(3);
    let per_task: Vec<Vec<antler::model::Tensor>> = (0..3)
        .map(|_| {
            arch.flat_param_shapes(2)
                .into_iter()
                .map(|s| antler::model::Tensor::he_init(s, &mut rng))
                .collect()
        })
        .collect();
    let store = GraphWeights::from_task_params(&graph, &arch, &per_task);
    let mut ex = BlockExecutor::new(
        &be,
        Device::msp430(),
        arch,
        graph,
        vec![2, 2, 2],
        store,
    );
    let x = ds.x.slice_batch(0, 1);
    for t in 0..3 {
        let (pred, cost) = ex.run_task(0, t, &x).unwrap();
        assert!(pred < 2);
        assert!(cost.time() > 0.0);
    }
    // disjoint graph: zero activation reuse
    assert_eq!(ex.layer_skips, 0);
}

/// The acceptance-gate sharded-serve test: a trained deployment served
/// across several reference-backend executors, every frame processed,
/// ≥ 2 executors busy, aggregate metrics populated.
#[test]
fn sharded_serving_covers_all_frames() {
    let be = ReferenceBackend::new();
    let spec = dataset_by_name("hhar-s").unwrap();
    let ds = spec.generate(&[128], 240);
    let arch = be.arch("dnn4").unwrap();
    let graph = TaskGraph::shared(4, TaskGraph::default_bounds(4, 3));
    let ncls = vec![2usize; 4];
    let mut rng = antler::util::rng::Pcg32::seed(5);
    let store = GraphWeights::init(&graph, &arch, &ncls, &mut rng);

    let frames: Vec<_> = (0..32u64)
        .map(|i| (i, ds.x.slice_batch(i as usize % ds.len(), 1)))
        .collect();
    let plan = ServePlan::unconditional(vec![0, 1, 2, 3]);
    let make = |_s: usize| {
        Ok(BlockExecutor::new(
            ReferenceBackend::new(),
            Device::msp430(),
            arch.clone(),
            graph.clone(),
            ncls.clone(),
            store.clone(),
        ))
    };
    let report = serve_sharded(make, 4, &plan, frames, 16, None).unwrap();
    assert_eq!(report.shards, 4);
    assert_eq!(report.aggregate.frames, 32);
    assert_eq!(report.aggregate.dropped, 0);
    assert_eq!(report.frames_per_shard, vec![8, 8, 8, 8]);
    assert!(report.busy_shards() >= 2);
    assert!(report.aggregate.throughput_fps > 0.0);
    assert!(report.aggregate.sim_time_per_frame_s > 0.0);
    assert!(report.aggregate.layer_execs > 0);
    // the fully shared trunk means per-frame reuse inside every shard
    assert!(report.aggregate.layer_skips > 0);
}
