//! Property tests over the ordering solvers and the serve-plan
//! constraint machinery, on the `testkit::prop_check` harness
//! (`ANTLER_PROP_SEED=<seed> cargo test <name>` replays a failure).

use antler::affinity::synthetic_affinity;
use antler::coordinator::{
    process_frame, run_executor, serve_sharded_opts,
    serve_sharded_registry_feed, serve_sharded_sources, BlockExecutor, Frame,
    PlanRegistry, ServePlan, ShardOpts, Source,
};
use antler::sync::Arc;
use antler::device::Device;
use antler::memory::cost_matrix;
use antler::model::archs::builtin_archs;
use antler::model::Tensor;
use antler::ordering::{solve_brute, solve_held_karp, OrderingProblem};
use antler::runtime::ReferenceBackend;
use antler::taskgraph::enumerate;
use antler::testkit::{gen, prop_check};
use antler::trainer::GraphWeights;
use antler::util::rng::Pcg32;

/// Brute force and Held–Karp must agree on the optimal cost for every
/// small ordering instance derived from a random task graph — with and
/// without random precedence DAGs.
#[test]
fn prop_brute_and_held_karp_agree_on_random_task_graphs() {
    let archs = builtin_archs();
    let arch = archs["cnn5"].clone();
    prop_check(
        "brute-vs-held-karp",
        40,
        |rng| {
            let n = gen::usize_in(rng, 3, 7); // 3..=6 tasks
            let aff = synthetic_affinity(n, 3, rng);
            let graphs = enumerate::clustered(&aff, &[1, 3, 4], 40);
            let g = graphs[rng.below(graphs.len())].clone();
            let prec = gen::precedence_dag(rng, n, n / 2);
            (n, g, prec)
        },
        |(n, g, prec)| {
            let device = Device::msp430();
            let ncls = vec![2usize; *n];
            let c = cost_matrix(&device, &arch, g, &ncls, false);
            let p = OrderingProblem::from_matrix(c).with_precedence(prec.clone());
            match (solve_brute(&p), solve_held_karp(&p)) {
                (Some(bf), Some(hk)) => {
                    if !p.is_valid(&bf.order) {
                        return Err(format!("brute order invalid: {:?}", bf.order));
                    }
                    if !p.is_valid(&hk.order) {
                        return Err(format!("hk order invalid: {:?}", hk.order));
                    }
                    if (bf.cost - hk.cost).abs() > 1e-9 {
                        return Err(format!(
                            "cost mismatch: brute {} vs held-karp {}",
                            bf.cost, hk.cost
                        ));
                    }
                    Ok(())
                }
                (None, None) => Ok(()), // both deem it infeasible
                (bf, hk) => Err(format!(
                    "feasibility disagreement: brute {:?} vs hk {:?}",
                    bf.map(|s| s.order),
                    hk.map(|s| s.order)
                )),
            }
        },
    );
}

/// A ServePlan built from a conditional ordering solution never gates a
/// task on an undecided prerequisite: by the time the serving loop
/// consults `preds[pre]`, the prerequisite has already executed (or been
/// decided) earlier in the order — the §4.3 invariant.
#[test]
fn prop_serve_plan_conditional_respects_precedence() {
    prop_check(
        "serveplan-conditional-precedence",
        40,
        |rng| {
            let n = gen::usize_in(rng, 3, 9); // 3..=8 tasks
            let flat = gen::sym_cost_matrix(rng, n, 50.0);
            let cost: Vec<Vec<f64>> =
                (0..n).map(|i| flat[i * n..(i + 1) * n].to_vec()).collect();
            let dag = gen::precedence_dag(rng, n, n);
            let cond: Vec<(usize, usize, f64)> = dag
                .iter()
                .map(|&(a, b)| (a, b, 0.25 + 0.5 * rng.f64()))
                .collect();
            (n, cost, cond)
        },
        |(n, cost, cond)| {
            let p = OrderingProblem::from_matrix(cost.clone())
                .with_conditional(cond.clone());
            let sol = solve_held_karp(&p)
                .ok_or_else(|| "acyclic DAG must be feasible".to_string())?;
            if !p.is_valid(&sol.order) {
                return Err(format!("solver order invalid: {:?}", sol.order));
            }
            let plan = ServePlan {
                order: sol.order.clone(),
                conditional: cond.iter().map(|&(a, b, _)| (a, b)).collect(),
            };
            // replay the server's gating loop: every prerequisite a task
            // is gated on must already be decided when the task comes up
            let mut decided = vec![false; *n];
            for &t in &plan.order {
                for &(pre, dep) in &plan.conditional {
                    if dep == t && !decided[pre] {
                        return Err(format!(
                            "task {t} gated on undecided prerequisite {pre} \
                             in order {:?}",
                            plan.order
                        ));
                    }
                }
                decided[t] = true;
            }
            Ok(())
        },
    );
}

/// Conditional skipping under sharding + batching: for any random task
/// graph, execution order and conditional gates, the work-stealing
/// sharded/batched serve produces frame-for-frame identical
/// `predictions` to the single-executor loop on the same frames — the
/// §4.3 mechanism survives both the scheduler and the batched kernels
/// (which are bitwise identical row-for-row by construction).
#[test]
fn prop_sharded_batched_serving_matches_single_executor() {
    let archs = builtin_archs();
    let arch = archs["cnn5"].clone();
    let device = Device::msp430();
    prop_check(
        "sharded-batched-parity",
        8,
        |rng| {
            let n = gen::usize_in(rng, 3, 6); // 3..=5 tasks
            let aff = synthetic_affinity(n, 3, rng);
            let graphs = enumerate::clustered(&aff, &[1, 3, 4], 30);
            let g = graphs[rng.below(graphs.len())].clone();
            let order = gen::permutation(rng, n);
            // random gates that respect the order: prereq decided first
            let mut cond = Vec::new();
            for j in 1..n {
                if rng.chance(0.5) {
                    let i = rng.below(j);
                    cond.push((order[i], order[j]));
                }
            }
            let n_frames = gen::usize_in(rng, 5, 13);
            let seed = rng.next_u64();
            (g, order, cond, n_frames, seed)
        },
        |(g, order, cond, n_frames, seed)| {
            let n = g.n_tasks;
            let ncls = vec![2usize; n];
            let mut wrng = Pcg32::seed(*seed);
            let store = GraphWeights::init(g, &arch, &ncls, &mut wrng);
            let frames: Vec<(u64, Tensor)> = (0..*n_frames as u64)
                .map(|i| {
                    let data = (0..256).map(|_| wrng.gauss()).collect();
                    (i, Tensor::new(vec![1, 16, 16, 1], data))
                })
                .collect();
            let plan = ServePlan {
                order: order.clone(),
                conditional: cond.clone(),
            };
            let make_executor = |_s: usize| {
                Ok(BlockExecutor::new(
                    ReferenceBackend::new(),
                    device.clone(),
                    arch.clone(),
                    g.clone(),
                    ncls.clone(),
                    store.clone(),
                ))
            };

            // baseline: one executor, one frame at a time
            let mut ex = make_executor(0).map_err(|e: anyhow::Error| e.to_string())?;
            let (tx, rx) = std::sync::mpsc::channel();
            for (id, x) in frames.clone() {
                tx.send(Frame::new(id, x))
                    .map_err(|_| "feed failed".to_string())?;
            }
            drop(tx);
            let (mut base, _) =
                run_executor(&mut ex, &plan, rx).map_err(|e| e.to_string())?;
            base.sort_by_key(|r| r.id);

            // candidate: 3 shards, work stealing, micro-batches of 4
            let opts = ShardOpts {
                queue_depth: frames.len() + 1,
                batch: 4,
                ..ShardOpts::default()
            };
            let report =
                serve_sharded_opts(make_executor, 3, &plan, frames, &opts)
                    .map_err(|e| e.to_string())?;
            if report.aggregate.dropped != 0 {
                return Err(format!(
                    "unexpected drops: {}",
                    report.aggregate.dropped
                ));
            }
            if report.results.len() != base.len() {
                return Err(format!(
                    "{} sharded results vs {} baseline",
                    report.results.len(),
                    base.len()
                ));
            }
            for (got, want) in report.results.iter().zip(&base) {
                if got.id != want.id || got.predictions != want.predictions {
                    return Err(format!(
                        "frame {} predictions diverged: sharded {:?} vs \
                         single {:?}",
                        want.id, got.predictions, want.predictions
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The two-tier weight memory is provably a cost overlay: for any
/// random task graph, execution order, conditional gates and frame set,
/// the tier-enabled sharded serve — at a capacity of zero (pure
/// streaming), a random bound tighter than the weight footprint, and
/// unbounded, with prefetch on or off and under both eviction policies —
/// produces frame-for-frame identical `predictions` to the flat
/// (tier-less) serve. The tier decides *when bytes move*, never *what
/// executes*.
#[test]
fn prop_tiered_serving_matches_flat_baseline() {
    use antler::memory::tier::{EvictPolicy, TierConfig};

    let archs = builtin_archs();
    let arch = archs["cnn5"].clone();
    let device = Device::msp430();
    prop_check(
        "tiered-serving-parity",
        5,
        |rng| {
            let n = gen::usize_in(rng, 3, 6); // 3..=5 tasks
            let aff = synthetic_affinity(n, 3, rng);
            let graphs = enumerate::clustered(&aff, &[1, 3, 4], 30);
            let g = graphs[rng.below(graphs.len())].clone();
            let order = gen::permutation(rng, n);
            let mut cond = Vec::new();
            for j in 1..n {
                if rng.chance(0.5) {
                    let i = rng.below(j);
                    cond.push((order[i], order[j]));
                }
            }
            let n_frames = gen::usize_in(rng, 4, 10);
            let shards = gen::usize_in(rng, 1, 4); // 1..=3 shards
            let tight_cap = gen::usize_in(rng, 500, 8_000);
            let seed = rng.next_u64();
            (g, order, cond, n_frames, shards, tight_cap, seed)
        },
        |(g, order, cond, n_frames, shards, tight_cap, seed)| {
            let ncls = vec![2usize; g.n_tasks];
            let mut wrng = Pcg32::seed(*seed);
            let store = GraphWeights::init(g, &arch, &ncls, &mut wrng);
            let frames: Vec<(u64, Tensor)> = (0..*n_frames as u64)
                .map(|i| {
                    let data = (0..256).map(|_| wrng.gauss()).collect();
                    (i, Tensor::new(vec![1, 16, 16, 1], data))
                })
                .collect();
            let plan = ServePlan {
                order: order.clone(),
                conditional: cond.clone(),
            };
            let make_executor = |_s: usize| {
                Ok(BlockExecutor::new(
                    ReferenceBackend::new(),
                    device.clone(),
                    arch.clone(),
                    g.clone(),
                    ncls.clone(),
                    store.clone(),
                ))
            };
            let flat_opts = ShardOpts {
                queue_depth: frames.len() + 1,
                batch: 3,
                ..ShardOpts::default()
            };
            let flat = serve_sharded_opts(
                make_executor,
                *shards,
                &plan,
                frames.clone(),
                &flat_opts,
            )
            .map_err(|e| e.to_string())?;
            if flat.aggregate.dropped != 0 {
                return Err(format!("flat drops: {}", flat.aggregate.dropped));
            }
            for cap in [0usize, *tight_cap, usize::MAX] {
                for prefetch in [false, true] {
                    for policy in [EvictPolicy::Affinity, EvictPolicy::Lru] {
                        let mut cfg = TierConfig::for_device(
                            &device, cap, prefetch,
                        );
                        cfg.policy = policy;
                        let opts = ShardOpts {
                            tier: Some(cfg),
                            ..flat_opts.clone()
                        };
                        let report = serve_sharded_opts(
                            make_executor,
                            *shards,
                            &plan,
                            frames.clone(),
                            &opts,
                        )
                        .map_err(|e| e.to_string())?;
                        if report.aggregate.dropped != 0 {
                            return Err(format!(
                                "tier cap={cap} dropped {}",
                                report.aggregate.dropped
                            ));
                        }
                        if report.results.len() != flat.results.len() {
                            return Err(format!(
                                "{} tiered results vs {} flat (cap={cap})",
                                report.results.len(),
                                flat.results.len()
                            ));
                        }
                        for (got, want) in
                            report.results.iter().zip(&flat.results)
                        {
                            if got.id != want.id
                                || got.predictions != want.predictions
                            {
                                return Err(format!(
                                    "frame {} diverged at cap={cap} \
                                     prefetch={prefetch} policy={policy:?}: \
                                     {:?} vs flat {:?}",
                                    want.id, got.predictions, want.predictions
                                ));
                            }
                        }
                        let tc = report
                            .tier
                            .ok_or("tier enabled but counters missing")?;
                        if tc.hits + tc.misses == 0 {
                            return Err(format!(
                                "no tier traffic at cap={cap}"
                            ));
                        }
                        if cap == 0 && tc.hits != 0 {
                            return Err(format!(
                                "capacity-0 tier reported {} hits",
                                tc.hits
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Multi-producer ingest in front of the work-stealing scheduler: for
/// random source splits, random per-source pacing, K producers and a
/// handicapped (skewed) shard, per-source conservation
/// `delivered + dropped == offered` holds exactly, nothing is dropped
/// when the injector is deep enough, and the served predictions match
/// the single-producer single-executor loop frame-for-frame — the
/// ingest tier changes *when* frames arrive, never *what* is computed.
#[test]
fn prop_multi_producer_ingest_matches_single_producer() {
    let archs = builtin_archs();
    let arch = archs["cnn5"].clone();
    let device = Device::msp430();
    let graph = antler::taskgraph::TaskGraph::new(
        3,
        vec![1, 3, 4],
        vec![
            antler::taskgraph::Partition(vec![0, 0, 0]),
            antler::taskgraph::Partition(vec![0, 0, 0]),
            antler::taskgraph::Partition(vec![0, 0, 1]),
            antler::taskgraph::Partition::singletons(3),
        ],
    )
    .unwrap();
    prop_check(
        "multi-producer-ingest",
        6,
        |rng| {
            let n_sources = gen::usize_in(rng, 2, 5); // 2..=4 sources
            let counts: Vec<usize> =
                (0..n_sources).map(|_| gen::usize_in(rng, 3, 11)).collect();
            let pace_us: Vec<u64> =
                (0..n_sources).map(|_| rng.below(3) as u64 * 400).collect();
            let k = gen::usize_in(rng, 1, n_sources + 1);
            let handicap_shard = rng.below(3);
            let seed = rng.next_u64();
            (counts, pace_us, k, handicap_shard, seed)
        },
        |(counts, pace_us, k, handicap_shard, seed)| {
            let ncls = vec![2usize; 3];
            let mut wrng = Pcg32::seed(*seed);
            let store = GraphWeights::init(&graph, &arch, &ncls, &mut wrng);
            // unique ids across sources: source s owns s*1000 + i
            let sources: Vec<Source> = counts
                .iter()
                .enumerate()
                .map(|(s, &c)| {
                    let frames: Vec<(u64, Tensor)> = (0..c as u64)
                        .map(|i| {
                            let data =
                                (0..256).map(|_| wrng.gauss()).collect();
                            (
                                s as u64 * 1000 + i,
                                Tensor::new(vec![1, 16, 16, 1], data),
                            )
                        })
                        .collect();
                    let mut src =
                        Source::flood(&format!("src{s}"), frames);
                    if pace_us[s] > 0 {
                        src.interval = Some(
                            std::time::Duration::from_micros(pace_us[s]),
                        );
                    }
                    src
                })
                .collect();
            let total: usize = counts.iter().sum();
            let all: Vec<(u64, Tensor)> = sources
                .iter()
                .flat_map(|s| s.frames.iter().cloned())
                .collect();
            let plan = ServePlan {
                order: vec![0, 1, 2],
                conditional: vec![(0, 2)],
            };
            let make_executor = |_s: usize| {
                Ok(BlockExecutor::new(
                    ReferenceBackend::new(),
                    device.clone(),
                    arch.clone(),
                    graph.clone(),
                    ncls.clone(),
                    store.clone(),
                ))
            };

            // baseline: one executor, one producer, one frame at a time
            let mut ex =
                make_executor(0).map_err(|e: anyhow::Error| e.to_string())?;
            let (tx, rx) = std::sync::mpsc::channel();
            for (id, x) in all {
                tx.send(Frame::new(id, x))
                    .map_err(|_| "feed failed".to_string())?;
            }
            drop(tx);
            let (mut base, _) =
                run_executor(&mut ex, &plan, rx).map_err(|e| e.to_string())?;
            base.sort_by_key(|r| r.id);

            // candidate: K producers, 3 shards (one handicapped), deep
            // injector so nothing can be dropped, adaptive batching on
            let opts = ShardOpts {
                queue_depth: total + 8,
                batch: 4,
                adaptive_batch: true,
                handicap: Some((
                    *handicap_shard,
                    std::time::Duration::from_micros(500),
                )),
                ..ShardOpts::default()
            };
            let (report, ingest) = serve_sharded_sources(
                make_executor,
                3,
                &plan,
                sources,
                *k,
                &opts,
            )
            .map_err(|e| e.to_string())?;

            // per-source conservation, exact
            for (s, sr) in ingest.sources.iter().enumerate() {
                if sr.offered != counts[s] {
                    return Err(format!(
                        "source {s} offered {} != {}",
                        sr.offered, counts[s]
                    ));
                }
                if sr.delivered + sr.dropped() != sr.offered {
                    return Err(format!(
                        "source {s} leaks: {} + {} != {}",
                        sr.delivered,
                        sr.dropped(),
                        sr.offered
                    ));
                }
            }
            // deep injector + no slack: nothing shed at ingest
            if ingest.dropped() != 0 {
                return Err(format!("unexpected drops: {}", ingest.dropped()));
            }
            // aggregate conservation
            if report.aggregate.frames + report.aggregate.dropped != total {
                return Err(format!(
                    "aggregate leaks: {} + {} != {total}",
                    report.aggregate.frames, report.aggregate.dropped
                ));
            }
            // frame-for-frame parity with the single-producer baseline
            if report.results.len() != base.len() {
                return Err(format!(
                    "{} multi-producer results vs {} baseline",
                    report.results.len(),
                    base.len()
                ));
            }
            for (got, want) in report.results.iter().zip(&base) {
                if got.id != want.id || got.predictions != want.predictions {
                    return Err(format!(
                        "frame {} diverged under multi-producer ingest: \
                         {:?} vs {:?}",
                        want.id, got.predictions, want.predictions
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Class-aware admission (`QosClass::admit_at`) sheds strictly in
/// priority order. Replaying the listener's admission rule against a
/// pure integer queue model over random arrival/service schedules:
/// admission is monotone in backlog (never resumes as the queue grows),
/// at any backlog where a protected class is refused every less
/// protected class is refused too, realtime is never refused by class
/// policy at all — so the only way a realtime frame drops is a
/// physically full injector, a point where best-effort and batch were
/// already being shed.
#[test]
fn prop_qos_shedding_never_drops_realtime_before_best_effort() {
    use antler::coordinator::QosClass;
    let (rt, be, bt) =
        (QosClass::Realtime, QosClass::BestEffort, QosClass::Batch);
    prop_check(
        "qos-shedding-order",
        60,
        |rng| {
            let capacity = gen::usize_in(rng, 1, 129); // 1..=128
            let n = gen::usize_in(rng, 40, 300);
            // one event = (arriving class, frames serviced just before)
            let events: Vec<(usize, usize)> =
                (0..n).map(|_| (rng.below(3), rng.below(4))).collect();
            (capacity, events)
        },
        |(capacity, events)| {
            let cap = *capacity;
            let mut backlog = 0usize;
            // lowest backlog at which each class (ALL order) was refused
            let mut shed_floor = [usize::MAX; 3];
            for &(which, serviced) in events {
                backlog = backlog.saturating_sub(serviced);
                // monotone: refusal at b implies refusal at b+1
                for cls in QosClass::ALL {
                    if !cls.admit_at(backlog, cap)
                        && cls.admit_at(backlog + 1, cap)
                    {
                        return Err(format!(
                            "{cls} refused at backlog {backlog} but admitted \
                             at {} (cap {cap})",
                            backlog + 1
                        ));
                    }
                }
                // priority order, pointwise: batch admitted ⇒ best-effort
                // admitted ⇒ realtime admitted
                if bt.admit_at(backlog, cap) && !be.admit_at(backlog, cap) {
                    return Err(format!(
                        "batch admitted but best-effort refused at backlog \
                         {backlog}/{cap}"
                    ));
                }
                if be.admit_at(backlog, cap) && !rt.admit_at(backlog, cap) {
                    return Err(format!(
                        "best-effort admitted but realtime refused at \
                         backlog {backlog}/{cap}"
                    ));
                }
                // realtime is never refused by class policy
                if !rt.admit_at(backlog, cap) {
                    return Err(format!(
                        "class policy refused realtime at backlog \
                         {backlog}/{cap}"
                    ));
                }
                // the queue itself: class shed OR hard-full ⇒ drop
                let cls = QosClass::ALL[which];
                if cls.admit_at(backlog, cap) && backlog < cap {
                    backlog += 1;
                } else {
                    shed_floor[which] = shed_floor[which].min(backlog);
                    if cls == rt {
                        // a dropped realtime frame means a physically full
                        // injector — and at that backlog both lower
                        // classes must already be shed by policy
                        if backlog < cap {
                            return Err(format!(
                                "realtime dropped below the hard cap: \
                                 backlog {backlog}/{cap}"
                            ));
                        }
                        if be.admit_at(backlog, cap)
                            || bt.admit_at(backlog, cap)
                        {
                            return Err(format!(
                                "realtime dropped at backlog {backlog}/{cap} \
                                 while a lower class was still admitted"
                            ));
                        }
                    }
                }
            }
            // whole-run ordering: at the lowest backlog where a class was
            // ever refused, the policy must already refuse every less
            // protected class (probe the rule — a lower class needn't
            // have happened to *arrive* at that backlog)
            if shed_floor[0] != usize::MAX
                && (be.admit_at(shed_floor[0], cap)
                    || bt.admit_at(shed_floor[0], cap))
            {
                return Err(format!(
                    "realtime first dropped at backlog {} where a lower \
                     class was still admitted (cap {cap})",
                    shed_floor[0]
                ));
            }
            if shed_floor[1] != usize::MAX && bt.admit_at(shed_floor[1], cap) {
                return Err(format!(
                    "best-effort first shed at backlog {} where batch was \
                     still admitted (cap {cap})",
                    shed_floor[1]
                ));
            }
            Ok(())
        },
    );
}

/// The expected-cost fitness of the solver's order is never beaten by a
/// random valid order (Held–Karp optimality spot-check under
/// conditionals).
#[test]
fn prop_held_karp_beats_random_valid_orders() {
    prop_check(
        "hk-beats-random",
        30,
        |rng| {
            let n = gen::usize_in(rng, 4, 8);
            let flat = gen::sym_cost_matrix(rng, n, 30.0);
            let cost: Vec<Vec<f64>> =
                (0..n).map(|i| flat[i * n..(i + 1) * n].to_vec()).collect();
            let perms: Vec<Vec<usize>> =
                (0..20).map(|_| gen::permutation(rng, n)).collect();
            (cost, perms)
        },
        |(cost, perms)| {
            let p = OrderingProblem::from_matrix(cost.clone());
            let sol = solve_held_karp(&p).ok_or("unconstrained must solve")?;
            for perm in perms {
                if p.is_valid(perm) && p.fitness(perm) < sol.cost - 1e-9 {
                    return Err(format!(
                        "random order {:?} ({}) beats solver ({})",
                        perm,
                        p.fitness(perm),
                        sol.cost
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Epoch-based hot-swap is exact, not approximate: for random task
/// graphs, random per-tenant plans, a random frame→tenant assignment
/// and a swap injected at a random point mid-stream, every served
/// frame's predictions equal — frame for frame — the single-executor
/// baseline of the exact plan version it was admitted under. Frames
/// offered before the publish stay on the old epoch even while the new
/// one is live; frames offered after take the new plan.
#[test]
fn prop_plan_hot_swap_matches_per_epoch_baselines() {
    let archs = builtin_archs();
    let arch = archs["cnn5"].clone();
    let device = Device::msp430();
    prop_check(
        "plan-hot-swap-per-epoch-parity",
        8,
        |rng| {
            let n = gen::usize_in(rng, 3, 6); // 3..=5 tasks
            let aff = synthetic_affinity(n, 3, rng);
            let graphs = enumerate::clustered(&aff, &[1, 3, 4], 30);
            let g = graphs[rng.below(graphs.len())].clone();
            let n_tenants = gen::usize_in(rng, 1, 4); // 1..=3 tenants
            // epoch-0 plan per tenant, plus the plan the swap publishes
            let epoch0: Vec<Vec<usize>> =
                (0..n_tenants).map(|_| gen::permutation(rng, n)).collect();
            let swap_tenant = rng.below(n_tenants) as u32;
            let swapped = gen::permutation(rng, n);
            let n_frames = gen::usize_in(rng, 6, 13);
            let tenants: Vec<u32> = (0..n_frames)
                .map(|_| rng.below(n_tenants) as u32)
                .collect();
            let swap_at = gen::usize_in(rng, 1, n_frames);
            let seed = rng.next_u64();
            (g, epoch0, swap_tenant, swapped, tenants, swap_at, seed)
        },
        |(g, epoch0, swap_tenant, swapped, tenants, swap_at, seed)| {
            let n = g.n_tasks;
            let ncls = vec![2usize; n];
            let mut wrng = Pcg32::seed(*seed);
            let store = GraphWeights::init(g, &arch, &ncls, &mut wrng);
            let frames: Vec<(u64, Tensor)> = (0..tenants.len() as u64)
                .map(|i| {
                    let data = (0..256).map(|_| wrng.gauss()).collect();
                    (i, Tensor::new(vec![1, 16, 16, 1], data))
                })
                .collect();
            let make_executor = |_s: usize| {
                Ok(BlockExecutor::new(
                    ReferenceBackend::new(),
                    device.clone(),
                    arch.clone(),
                    g.clone(),
                    ncls.clone(),
                    store.clone(),
                ))
            };

            let plans: Vec<ServePlan> = epoch0
                .iter()
                .map(|o| ServePlan::unconditional(o.clone()))
                .collect();
            let swap_plan = ServePlan::unconditional(swapped.clone());
            let registry = Arc::new(PlanRegistry::new(plans.clone()));
            let opts = ShardOpts {
                queue_depth: frames.len() + 1,
                ..ShardOpts::default()
            };
            let reg2 = Arc::clone(&registry);
            let feed_frames = frames.clone();
            let feed_tenants = tenants.clone();
            let (swap_t, swap_p, at) =
                (*swap_tenant, swap_plan.clone(), *swap_at);
            let (report, _) = serve_sharded_registry_feed(
                make_executor,
                3,
                Arc::clone(&registry),
                &opts,
                None,
                move |d| {
                    let mut dropped = 0usize;
                    for (i, (id, x)) in feed_frames.into_iter().enumerate() {
                        if i == at {
                            reg2.publish(swap_t, swap_p.clone());
                        }
                        if !d.offer(
                            Frame::new(id, x).with_tenant(feed_tenants[i]),
                        ) {
                            dropped += 1;
                        }
                    }
                    (dropped, None)
                },
            )
            .map_err(|e| e.to_string())?;
            if report.aggregate.dropped != 0 {
                return Err(format!(
                    "unexpected drops: {}",
                    report.aggregate.dropped
                ));
            }
            if report.results.len() != frames.len() {
                return Err(format!(
                    "{} results for {} frames",
                    report.results.len(),
                    frames.len()
                ));
            }

            // per-epoch baselines on a single executor: each frame must
            // match the plan version it was admitted under
            let mut ex =
                make_executor(0).map_err(|e: anyhow::Error| e.to_string())?;
            for (i, got) in report.results.iter().enumerate() {
                let tenant = tenants[i];
                let want_epoch =
                    u64::from(tenant == *swap_tenant && i >= *swap_at);
                if got.epoch != want_epoch {
                    return Err(format!(
                        "frame {i} admitted under epoch {} (want {})",
                        got.epoch, want_epoch
                    ));
                }
                let plan = if want_epoch == 1 {
                    &swap_plan
                } else {
                    &plans[tenant as usize]
                };
                let (want, _) = process_frame(
                    &mut ex,
                    plan,
                    Frame::new(got.id, frames[i].1.clone())
                        .with_tenant(tenant),
                )
                .map_err(|e| e.to_string())?;
                if got.predictions != want.predictions {
                    return Err(format!(
                        "frame {i} (tenant {tenant}, epoch {}) diverged: \
                         swap-serve {:?} vs baseline {:?}",
                        got.epoch, got.predictions, want.predictions
                    ));
                }
            }
            registry.close_check();
            Ok(())
        },
    );
}
