//! Property tests over the ordering solvers and the serve-plan
//! constraint machinery, on the `testkit::prop_check` harness
//! (`ANTLER_PROP_SEED=<seed> cargo test <name>` replays a failure).

use antler::affinity::synthetic_affinity;
use antler::coordinator::ServePlan;
use antler::device::Device;
use antler::memory::cost_matrix;
use antler::model::archs::builtin_archs;
use antler::ordering::{solve_brute, solve_held_karp, OrderingProblem};
use antler::taskgraph::enumerate;
use antler::testkit::{gen, prop_check};

/// Brute force and Held–Karp must agree on the optimal cost for every
/// small ordering instance derived from a random task graph — with and
/// without random precedence DAGs.
#[test]
fn prop_brute_and_held_karp_agree_on_random_task_graphs() {
    let archs = builtin_archs();
    let arch = archs["cnn5"].clone();
    prop_check(
        "brute-vs-held-karp",
        40,
        |rng| {
            let n = gen::usize_in(rng, 3, 7); // 3..=6 tasks
            let aff = synthetic_affinity(n, 3, rng);
            let graphs = enumerate::clustered(&aff, &[1, 3, 4], 40);
            let g = graphs[rng.below(graphs.len())].clone();
            let prec = gen::precedence_dag(rng, n, n / 2);
            (n, g, prec)
        },
        |(n, g, prec)| {
            let device = Device::msp430();
            let ncls = vec![2usize; *n];
            let c = cost_matrix(&device, &arch, g, &ncls, false);
            let p = OrderingProblem::from_matrix(c).with_precedence(prec.clone());
            match (solve_brute(&p), solve_held_karp(&p)) {
                (Some(bf), Some(hk)) => {
                    if !p.is_valid(&bf.order) {
                        return Err(format!("brute order invalid: {:?}", bf.order));
                    }
                    if !p.is_valid(&hk.order) {
                        return Err(format!("hk order invalid: {:?}", hk.order));
                    }
                    if (bf.cost - hk.cost).abs() > 1e-9 {
                        return Err(format!(
                            "cost mismatch: brute {} vs held-karp {}",
                            bf.cost, hk.cost
                        ));
                    }
                    Ok(())
                }
                (None, None) => Ok(()), // both deem it infeasible
                (bf, hk) => Err(format!(
                    "feasibility disagreement: brute {:?} vs hk {:?}",
                    bf.map(|s| s.order),
                    hk.map(|s| s.order)
                )),
            }
        },
    );
}

/// A ServePlan built from a conditional ordering solution never gates a
/// task on an undecided prerequisite: by the time the serving loop
/// consults `preds[pre]`, the prerequisite has already executed (or been
/// decided) earlier in the order — the §4.3 invariant.
#[test]
fn prop_serve_plan_conditional_respects_precedence() {
    prop_check(
        "serveplan-conditional-precedence",
        40,
        |rng| {
            let n = gen::usize_in(rng, 3, 9); // 3..=8 tasks
            let flat = gen::sym_cost_matrix(rng, n, 50.0);
            let cost: Vec<Vec<f64>> =
                (0..n).map(|i| flat[i * n..(i + 1) * n].to_vec()).collect();
            let dag = gen::precedence_dag(rng, n, n);
            let cond: Vec<(usize, usize, f64)> = dag
                .iter()
                .map(|&(a, b)| (a, b, 0.25 + 0.5 * rng.f64()))
                .collect();
            (n, cost, cond)
        },
        |(n, cost, cond)| {
            let p = OrderingProblem::from_matrix(cost.clone())
                .with_conditional(cond.clone());
            let sol = solve_held_karp(&p)
                .ok_or_else(|| "acyclic DAG must be feasible".to_string())?;
            if !p.is_valid(&sol.order) {
                return Err(format!("solver order invalid: {:?}", sol.order));
            }
            let plan = ServePlan {
                order: sol.order.clone(),
                conditional: cond.iter().map(|&(a, b, _)| (a, b)).collect(),
            };
            // replay the server's gating loop: every prerequisite a task
            // is gated on must already be decided when the task comes up
            let mut decided = vec![false; *n];
            for &t in &plan.order {
                for &(pre, dep) in &plan.conditional {
                    if dep == t && !decided[pre] {
                        return Err(format!(
                            "task {t} gated on undecided prerequisite {pre} \
                             in order {:?}",
                            plan.order
                        ));
                    }
                }
                decided[t] = true;
            }
            Ok(())
        },
    );
}

/// The expected-cost fitness of the solver's order is never beaten by a
/// random valid order (Held–Karp optimality spot-check under
/// conditionals).
#[test]
fn prop_held_karp_beats_random_valid_orders() {
    prop_check(
        "hk-beats-random",
        30,
        |rng| {
            let n = gen::usize_in(rng, 4, 8);
            let flat = gen::sym_cost_matrix(rng, n, 30.0);
            let cost: Vec<Vec<f64>> =
                (0..n).map(|i| flat[i * n..(i + 1) * n].to_vec()).collect();
            let perms: Vec<Vec<usize>> =
                (0..20).map(|_| gen::permutation(rng, n)).collect();
            (cost, perms)
        },
        |(cost, perms)| {
            let p = OrderingProblem::from_matrix(cost.clone());
            let sol = solve_held_karp(&p).ok_or("unconstrained must solve")?;
            for perm in perms {
                if p.is_valid(perm) && p.fitness(perm) < sol.cost - 1e-9 {
                    return Err(format!(
                        "random order {:?} ({}) beats solver ({})",
                        perm,
                        p.fitness(perm),
                        sol.cost
                    ));
                }
            }
            Ok(())
        },
    );
}
