//! PJRT ↔ reference-backend parity: same weights + input ⇒ logits within
//! 1e-4 and identical argmax predictions. Compiled only with the `pjrt`
//! feature and runs only when the AOT artifacts exist (`make artifacts`);
//! the reference backend is the always-on oracle.
#![cfg(feature = "pjrt")]

use antler::model::Tensor;
use antler::runtime::{pjrt_test_engine as engine, Backend, ReferenceBackend};
use antler::util::rng::Pcg32;

fn gauss_tensor(shape: Vec<usize>, scale: f32, rng: &mut Pcg32) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::new(shape, (0..n).map(|_| rng.gauss() * scale).collect())
}

#[test]
fn layerwise_parity_on_cnn5() {
    let Some(eng) = engine() else { return };
    let rb = ReferenceBackend::new();
    let arch = eng.arch("cnn5").unwrap();
    let mut rng = Pcg32::seed(0xC0FFEE);
    let mut cur_p = gauss_tensor(vec![1, 16, 16, 1], 1.0, &mut rng);
    let mut cur_r = cur_p.clone();
    for l in 0..arch.n_layers() {
        let is_logits = arch.layers[l].is_logits();
        let ncls = is_logits.then_some(2usize);
        let shapes = arch.layers[l].param_shapes(2);
        let w = Tensor::he_init(shapes[0].clone(), &mut rng);
        let b = gauss_tensor(shapes[1].clone(), 0.1, &mut rng);
        let yp = eng.run_layer(&arch, l, ncls, &cur_p, &w, &b).unwrap();
        let yr = rb.run_layer(&arch, l, ncls, &cur_r, &w, &b).unwrap();
        assert_eq!(yp.shape, yr.shape, "layer {l} shape");
        let diff = yp.max_abs_diff(&yr);
        assert!(diff < 1e-4, "layer {l} diverged: max |Δ| = {diff}");
        cur_p = yp;
        cur_r = yr;
    }
}

#[test]
fn whole_network_eval_parity_and_argmax() {
    let Some(eng) = engine() else { return };
    let rb = ReferenceBackend::new();
    for (arch_name, ncls) in [("cnn5", 3usize), ("dnn4", 2)] {
        let arch = eng.arch(arch_name).unwrap();
        let mut rng = Pcg32::seed(0xBEEF ^ ncls as u64);
        let params: Vec<Tensor> = arch
            .flat_param_shapes(ncls)
            .into_iter()
            .map(|s| Tensor::he_init(s, &mut rng))
            .collect();
        // the PJRT eval artifact is lowered at batch 64
        let mut xshape = vec![64usize];
        xshape.extend_from_slice(&arch.input);
        let xb = gauss_tensor(xshape, 1.0, &mut rng);
        let lp = eng.eval_logits(&arch, ncls, &params, &xb).unwrap();
        let lr = rb.eval_logits(&arch, ncls, &params, &xb).unwrap();
        assert_eq!(lp.shape, lr.shape);
        let diff = lp.max_abs_diff(&lr);
        assert!(diff < 1e-4, "{arch_name}: logits max |Δ| = {diff}");
        for i in 0..64 {
            let row_p = &lp.data[i * ncls..(i + 1) * ncls];
            let row_r = &lr.data[i * ncls..(i + 1) * ncls];
            let am = |row: &[f32]| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .unwrap()
                    .0
            };
            assert_eq!(am(row_p), am(row_r), "{arch_name}: row {i} argmax");
        }
    }
}

#[test]
fn blockwise_serving_parity() {
    // the full executor stack on both backends must produce identical
    // predictions for the same graph weights
    use antler::coordinator::BlockExecutor;
    use antler::device::Device;
    use antler::taskgraph::TaskGraph;
    use antler::trainer::GraphWeights;

    let Some(eng) = engine() else { return };
    let rb = ReferenceBackend::new();
    let arch = eng.arch("cnn5").unwrap();
    let graph = TaskGraph::shared(3, vec![1, 3, 4]);
    let ncls = vec![2usize, 2, 2];
    let mut rng = Pcg32::seed(0xABBA);
    let store = GraphWeights::init(&graph, &arch, &ncls, &mut rng);
    let mut ex_p = BlockExecutor::new(
        &eng,
        Device::msp430(),
        arch.clone(),
        graph.clone(),
        ncls.clone(),
        store.clone(),
    );
    let mut ex_r = BlockExecutor::new(
        &rb,
        Device::msp430(),
        arch.clone(),
        graph,
        ncls,
        store,
    );
    for sample in 0..6u64 {
        let x = gauss_tensor(vec![1, 16, 16, 1], 1.0, &mut rng);
        for t in 0..3 {
            let (pp, _) = ex_p.run_task(sample, t, &x).unwrap();
            let (pr, _) = ex_r.run_task(sample, t, &x).unwrap();
            assert_eq!(pp, pr, "sample {sample} task {t}");
        }
    }
}
