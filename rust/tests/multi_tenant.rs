//! Multi-tenant plan registry acceptance gates (PR 10).
//!
//! * Frames route by tenant to that tenant's own compiled plan, and the
//!   served predictions match a single-executor baseline running the
//!   same per-tenant plans — routing changes *which* plan runs, never
//!   *what* a plan computes.
//! * A forced mid-stream [`PlanRegistry::publish`] hot-swap leaves the
//!   plan-epoch ledger balanced: every admitted frame retires on the
//!   exact epoch it was admitted under, old epochs drain to live = 0.
//! * The single-tenant parity pin: `--tenants 1` with no replanning is
//!   bitwise-identical to the pre-registry path (predictions and
//!   conservation counts), because the legacy entry points now route
//!   through a one-tenant registry.
//! * The cost-drift replanner, fed simulated per-task costs from the
//!   serve, publishes a new epoch when the device model's predictions
//!   are deliberately skewed away from what execution observes.

use antler::coordinator::{
    process_frame, serve_sharded_opts, serve_sharded_registry,
    serve_sharded_registry_feed, spawn_replanner, BlockExecutor, DriftConfig,
    Frame, PlanRegistry, ServePlan, ShardOpts, TenantSpec,
};
use antler::data::dataset_by_name;
use antler::device::Device;
use antler::model::Tensor;
use antler::runtime::{Backend, ReferenceBackend};
use antler::sync::Arc;
use antler::taskgraph::TaskGraph;
use antler::trainer::GraphWeights;
use antler::util::rng::Pcg32;

/// Deterministic 4-task deployment on the reference backend: every
/// executor built from the same seed serves identical predictions.
fn make_executor(_s: usize) -> anyhow::Result<BlockExecutor<ReferenceBackend>> {
    let be = ReferenceBackend::new();
    let arch = be.arch("dnn4")?;
    let graph = TaskGraph::shared(4, TaskGraph::default_bounds(4, 3));
    let ncls = vec![2usize; 4];
    let mut rng = Pcg32::seed(11);
    let store = GraphWeights::init(&graph, &arch, &ncls, &mut rng);
    Ok(BlockExecutor::new(
        be,
        Device::msp430(),
        arch,
        graph,
        ncls,
        store,
    ))
}

fn input_frames(n: usize) -> Vec<(u64, Tensor)> {
    let spec = dataset_by_name("hhar-s").unwrap();
    let ds = spec.generate(&[128], 64);
    (0..n as u64)
        .map(|i| (i, ds.x.slice_batch(i as usize % ds.len(), 1)))
        .collect()
}

#[test]
fn tenants_route_to_their_own_plans_and_match_the_baseline() {
    let plans = vec![
        ServePlan::unconditional(vec![0, 2]),
        ServePlan::unconditional(vec![3, 1]),
    ];
    let registry = Arc::new(PlanRegistry::new(plans.clone()));
    let frames: Vec<(u64, u32, Tensor)> = input_frames(24)
        .into_iter()
        .enumerate()
        .map(|(i, (id, x))| (id, (i % 2) as u32, x))
        .collect();
    let baseline_frames = frames.clone();

    let sr = serve_sharded_registry(
        make_executor,
        2,
        Arc::clone(&registry),
        frames,
        &ShardOpts::default(),
        None,
    )
    .unwrap();
    assert_eq!(sr.aggregate.frames, 24);
    assert_eq!(sr.aggregate.dropped, 0);
    assert_eq!(sr.frames_per_tenant(), vec![(0, 12), (1, 12)]);

    // single-executor baseline: each frame processed under its own
    // tenant's plan must predict identically (results are id-sorted)
    let mut ex = make_executor(0).unwrap();
    for (i, (id, tenant, x)) in baseline_frames.into_iter().enumerate() {
        let (want, _) = process_frame(
            &mut ex,
            &plans[tenant as usize],
            Frame::new(id, x).with_tenant(tenant),
        )
        .unwrap();
        let got = &sr.results[i];
        assert_eq!(got.id, id);
        assert_eq!(got.tenant, tenant, "frame {id} routed to wrong tenant");
        assert_eq!(
            got.predictions, want.predictions,
            "frame {id} diverged from its tenant's plan"
        );
        // a tenant's plan only serves its own tasks
        for (t, p) in got.predictions.iter().enumerate() {
            assert_eq!(
                p.is_some(),
                plans[tenant as usize].order.contains(&t),
                "frame {id} task {t}"
            );
        }
    }
    registry.close_check();
}

#[test]
fn mid_stream_swap_balances_the_epoch_ledger() {
    let registry = Arc::new(PlanRegistry::single(ServePlan::unconditional(
        vec![0, 1, 2, 3],
    )));
    let inputs = input_frames(20);
    let reg2 = Arc::clone(&registry);
    let (sr, _) = serve_sharded_registry_feed(
        make_executor,
        2,
        Arc::clone(&registry),
        &ShardOpts::default(),
        None,
        move |d| {
            let mut dropped = 0usize;
            for (id, x) in inputs {
                // the forced swap, mid-stream, with frames in flight:
                // frames 0..10 pinned epoch 0, 10..20 epoch 1
                if id == 10 {
                    let e = reg2
                        .publish(0, ServePlan::unconditional(vec![3, 2, 1, 0]));
                    assert_eq!(e, 1);
                }
                if !d.offer(Frame::new(id, x)) {
                    dropped += 1;
                }
            }
            (dropped, None)
        },
    )
    .unwrap();

    assert_eq!(sr.aggregate.frames, 20);
    assert_eq!(sr.aggregate.dropped, 0);
    // every frame retired on the epoch it was admitted under
    for r in &sr.results {
        assert_eq!(r.epoch, u64::from(r.id >= 10), "frame {}", r.id);
    }
    // the ledger balances per epoch: 10 admitted, 10 completed, and
    // only the latest-published epoch is still live
    assert_eq!(sr.epochs.len(), 2);
    for row in &sr.epochs {
        assert_eq!(row.tenant, 0);
        assert_eq!(row.admitted, 10, "{row:?}");
        assert_eq!(row.completed, 10, "{row:?}");
        assert_eq!(row.failed, 0, "{row:?}");
        assert_eq!(row.drained, 0, "{row:?}");
        assert_eq!(row.live, row.epoch == 1, "{row:?}");
    }
    let table = sr.epoch_table().expect("registry serve renders a table");
    assert!(table.contains("plan epochs"), "{table}");
    registry.close_check();
}

#[test]
fn single_tenant_registry_is_bitwise_identical_to_the_legacy_path() {
    let plan = ServePlan::unconditional(vec![2, 0, 3, 1]);
    let inputs = input_frames(16);

    let legacy = serve_sharded_opts(
        make_executor,
        2,
        &plan,
        inputs.clone(),
        &ShardOpts::default(),
    )
    .unwrap();

    let registry = Arc::new(PlanRegistry::single(plan));
    let tframes: Vec<(u64, u32, Tensor)> =
        inputs.into_iter().map(|(id, x)| (id, 0u32, x)).collect();
    let multi = serve_sharded_registry(
        make_executor,
        2,
        Arc::clone(&registry),
        tframes,
        &ShardOpts::default(),
        None,
    )
    .unwrap();

    // conservation is identical...
    assert_eq!(multi.aggregate.frames, legacy.aggregate.frames);
    assert_eq!(multi.aggregate.dropped, legacy.aggregate.dropped);
    assert_eq!(multi.aggregate.tasks_skipped, legacy.aggregate.tasks_skipped);
    assert_eq!(multi.results.len(), legacy.results.len());
    // ...and every frame's result is bitwise the same computation
    for (a, b) in legacy.results.iter().zip(&multi.results) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.predictions, b.predictions, "frame {}", a.id);
        assert_eq!(
            a.sim_cost.time().to_bits(),
            b.sim_cost.time().to_bits(),
            "frame {} sim time",
            a.id
        );
        assert_eq!(b.tenant, 0);
        assert_eq!(b.epoch, 0);
    }
    // the one-tenant registry books exactly one balanced epoch row
    assert_eq!(multi.epochs.len(), 1);
    assert_eq!(multi.epochs[0].admitted, 16);
    assert_eq!(multi.epochs[0].completed, 16);
    registry.close_check();
}

#[test]
fn replanner_publishes_a_new_epoch_under_forced_drift() {
    // the spec's cost matrix is deliberately skewed: switching into
    // task 0 is claimed 100x more expensive than observed execution
    // will report, so the drift check must fire once warmed up
    let n = 4usize;
    let cost: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..n).map(|j| if j == 0 { 100.0 } else { 1.0 }).collect())
        .collect();
    let registry = Arc::new(PlanRegistry::single(ServePlan::unconditional(
        vec![0, 1, 2, 3],
    )));
    let specs = vec![TenantSpec {
        tenant: 0,
        tasks: vec![0, 1, 2, 3],
        cost,
        precedence: vec![],
        conditional: vec![],
    }];
    let cfg = DriftConfig { threshold: 0.05, min_samples: 4, alpha: 1.0 };
    let (obs_tx, replanner) =
        spawn_replanner(Arc::clone(&registry), specs, cfg);

    let frames: Vec<(u64, u32, Tensor)> = input_frames(24)
        .into_iter()
        .map(|(id, x)| (id, 0u32, x))
        .collect();
    let sr = serve_sharded_registry(
        make_executor,
        2,
        Arc::clone(&registry),
        frames,
        &ShardOpts::default(),
        Some(obs_tx),
    )
    .unwrap();
    // the serve dropped the last observation sender; the replanner
    // drains and exits with every publish it made
    let events = replanner.join().unwrap();

    assert_eq!(sr.aggregate.frames, 24);
    assert!(
        !events.is_empty(),
        "forced drift must publish at least one replan"
    );
    assert_eq!(events[0].tenant, 0);
    assert_eq!(events[0].epoch, 1);
    assert!(events[0].max_drift > cfg.threshold);
    assert!(registry.current(0).epoch >= 1);
    // whatever mix of epochs served frames, custody balanced
    registry.close_check();
    for row in &sr.epochs {
        assert_eq!(
            row.admitted,
            row.completed + row.failed + row.drained,
            "{row:?}"
        );
    }
}
