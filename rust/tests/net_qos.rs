//! Acceptance tests for the framed network front-end (`coordinator::net`):
//! many concurrent loopback connections across all three QoS classes
//! into a sharded serve, with exact per-connection and aggregate
//! conservation, class-ordered shedding, and hangup accounting.
//!
//! The load test's zero-realtime-drop claim is an arithmetic guarantee,
//! not a timing hope. With `queue_depth = 80`: best-effort admits only
//! while `backlog * 4 < 240` (backlog ≤ 59) and batch only while
//! `backlog * 2 < 80` (backlog ≤ 39), so non-realtime traffic alone
//! cannot push the backlog past 60 — plus at most `producers - 1 = 3`
//! overshoot from concurrent admission probes → 63. Only 16 realtime
//! frames exist in the whole run, so a realtime push never sees more
//! than 63 + 15 = 78 < 80 queued: the hard cap cannot refuse it, in any
//! interleaving.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::thread;
use std::time::Duration;

use anyhow::Result;

use antler::coordinator::wire::{encode_frame, WireFrame};
use antler::coordinator::{
    serve_net, BlockExecutor, NetOpts, QosClass, ServePlan, ShardOpts,
};
use antler::device::Device;
use antler::runtime::{Backend, ReferenceBackend};
use antler::taskgraph::{Partition, TaskGraph};
use antler::trainer::GraphWeights;
use antler::util::rng::Pcg32;

fn make_executor(_s: usize) -> Result<BlockExecutor<ReferenceBackend>> {
    let backend = ReferenceBackend::new();
    let arch = backend.arch("cnn5")?;
    let graph = TaskGraph::new(
        3,
        vec![1, 3, 4],
        vec![
            Partition(vec![0, 0, 0]),
            Partition(vec![0, 0, 0]),
            Partition(vec![0, 0, 1]),
            Partition::singletons(3),
        ],
    )?;
    let ncls = vec![2, 2, 2];
    let mut rng = Pcg32::seed(7);
    let store = GraphWeights::init(&graph, &arch, &ncls, &mut rng);
    Ok(BlockExecutor::new(
        backend,
        Device::msp430(),
        arch,
        graph,
        ncls,
        store,
    ))
}

/// A well-formed wire record the test executor accepts.
fn record(id: u64, tenant: u32, qos: QosClass, deadline_us: u32) -> Vec<u8> {
    let mut rng = Pcg32::seed(id ^ 0x5eed);
    encode_frame(&WireFrame {
        id,
        tenant,
        qos,
        deadline_us,
        shape: vec![1, 16, 16, 1],
        data: (0..256).map(|_| rng.gauss() as f32).collect(),
    })
}

/// Class and frame count for connection `c` in the load test: 16
/// realtime connections with one frame each, 24 best-effort and 24
/// batch connections with 12 frames each — 592 frames total.
fn load_mix(c: u32) -> (QosClass, u64) {
    match c {
        0..=15 => (QosClass::Realtime, 1),
        16..=39 => (QosClass::BestEffort, 12),
        _ => (QosClass::Batch, 12),
    }
}

/// 64 concurrent connections across all three classes into a 2-shard
/// serve with a deliberately small injector: exact conservation per
/// connection and in aggregate, zero realtime drops (see the module doc
/// for why that is arithmetic, not luck), and nonzero best-effort and
/// batch backpressure drops.
#[test]
fn qos_shedding_under_load_across_64_connections() {
    const CONNS: u32 = 64;
    const TOTAL: usize = 16 + 24 * 12 + 24 * 12; // 592
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let clients: Vec<_> = (0..CONNS)
        .map(|c| {
            thread::spawn(move || {
                let mut s = TcpStream::connect(addr).unwrap();
                let (qos, n) = load_mix(c);
                for i in 0..n {
                    let rec = record(u64::from(c) * 100 + i, c, qos, 0);
                    s.write_all(&rec).unwrap();
                }
            })
        })
        .collect();

    let plan = ServePlan::unconditional(vec![0, 1, 2]);
    let net = NetOpts {
        producers: 4,
        max_conns: CONNS as usize,
        qos: true,
        accept_grace: Duration::from_secs(10),
    };
    let opts = ShardOpts {
        queue_depth: 80,
        batch: 4,
        // slow one shard slightly so the injector actually backs up
        handicap: Some((0, Duration::from_micros(300))),
        ..ShardOpts::default()
    };
    let (sr, nr) = serve_net(make_executor, 2, &plan, listener, &net, &opts)
        .unwrap();
    for c in clients {
        c.join().unwrap();
    }

    // every connection reported, none truncated, each exactly conserved
    assert_eq!(nr.conns.len(), CONNS as usize);
    assert_eq!(nr.dropped_truncated(), 0);
    for c in &nr.conns {
        assert_eq!(
            c.delivered + c.dropped(),
            c.offered,
            "connection {} leaks frames",
            c.conn
        );
        // accept order is arbitrary, so match expectations by tenant
        let (_, want) = load_mix(c.tenant);
        assert_eq!(
            c.offered, want as usize,
            "tenant {} offered the wrong count",
            c.tenant
        );
    }

    // aggregate conservation, across the socket boundary into the
    // scheduler: everything offered is either served or accounted drop
    assert_eq!(nr.offered(), TOTAL);
    assert_eq!(nr.delivered() + nr.dropped(), TOTAL);
    assert_eq!(sr.aggregate.frames, nr.delivered());
    assert_eq!(sr.aggregate.frames + sr.aggregate.dropped, TOTAL);

    // class rows cover every decoded record
    let class_offered: usize = nr.classes.iter().map(|cl| cl.offered).sum();
    assert_eq!(class_offered, TOTAL);

    // the QoS contract: realtime is never shed …
    let rt = nr.class(QosClass::Realtime);
    assert_eq!(rt.offered, 16);
    assert_eq!(rt.dropped(), 0, "a realtime frame was dropped");
    assert_eq!(rt.delivered, 16);
    // … while lower classes take the backpressure
    assert!(
        nr.class(QosClass::BestEffort).dropped_backpressure > 0,
        "no best-effort backpressure drops — the injector never backed up"
    );
    assert!(
        nr.class(QosClass::Batch).dropped_backpressure > 0,
        "no batch backpressure drops — the injector never backed up"
    );
}

/// Abrupt mid-record disconnects: every connection hangs up halfway
/// through its final record, and the remainder is counted as one
/// offered, truncated frame — conservation survives the hangup on every
/// connection and in aggregate.
#[test]
fn qos_conservation_survives_abrupt_disconnects() {
    const CONNS: u32 = 8;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let clients: Vec<_> = (0..CONNS)
        .map(|c| {
            thread::spawn(move || {
                let mut s = TcpStream::connect(addr).unwrap();
                for i in 0..3u64 {
                    let rec = record(
                        u64::from(c) * 100 + i,
                        c,
                        QosClass::BestEffort,
                        0,
                    );
                    s.write_all(&rec).unwrap();
                }
                // start a fourth record and hang up mid-frame
                let partial =
                    record(u64::from(c) * 100 + 3, c, QosClass::BestEffort, 0);
                s.write_all(&partial[..partial.len() / 2]).unwrap();
            })
        })
        .collect();

    let plan = ServePlan::unconditional(vec![0, 1, 2]);
    let net = NetOpts {
        producers: 2,
        max_conns: CONNS as usize,
        qos: true,
        accept_grace: Duration::from_secs(10),
    };
    // deep injector: nothing may be shed, so the only drops are the
    // hangup remainders
    let opts = ShardOpts { queue_depth: 1024, ..ShardOpts::default() };
    let (sr, nr) = serve_net(make_executor, 2, &plan, listener, &net, &opts)
        .unwrap();
    for c in clients {
        c.join().unwrap();
    }

    assert_eq!(nr.conns.len(), CONNS as usize);
    for c in &nr.conns {
        assert_eq!(c.offered, 4, "3 whole records + the unfinished one");
        assert_eq!(c.dropped_truncated, 1, "hangup remainder must be counted");
        assert_eq!(
            c.delivered + c.dropped(),
            c.offered,
            "connection {} lost its hangup remainder",
            c.conn
        );
    }
    assert_eq!(nr.offered(), 4 * CONNS as usize);
    assert_eq!(nr.dropped_truncated(), CONNS as usize);
    // truncated frames carry no class; the class rows plus the
    // truncated bucket cover everything offered
    let class_offered: usize = nr.classes.iter().map(|cl| cl.offered).sum();
    assert_eq!(class_offered + nr.dropped_truncated(), nr.offered());
    // the whole records all made it through the deep injector
    assert_eq!(nr.delivered(), 3 * CONNS as usize);
    assert_eq!(sr.aggregate.frames, nr.delivered());
}
