"""AOT pipeline: entry enumeration, HLO text validity, manifest schema."""

import json
import os

import jax.numpy as jnp
import pytest

from compile import aot, model as M

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_entry_inventory_complete():
    names = [meta["name"] for _, _, _, meta in
             (lambda gen: [(n, f, s, dict(m, name=n)) for n, f, s, m in gen])(
                 aot.build_entries())]
    # every arch has train+eval per ncls and per-layer b1/b32 artifacts
    for arch in M.ARCHS:
        for ncls in M.NCLS_BY_ARCH[arch]:
            assert f"train_{arch}_c{ncls}" in names
            assert f"eval_{arch}_c{ncls}" in names
        for i, (kind, _) in enumerate(M.ARCHS[arch]["layers"]):
            if kind == "logits":
                for ncls in M.NCLS_BY_ARCH[arch]:
                    assert f"layer_{arch}_{i}_c{ncls}_b1" in names
            else:
                assert f"layer_{arch}_{i}_b1" in names
                assert f"layer_{arch}_{i}_b32" in names
    assert len(names) == len(set(names))


def test_lower_one_layer_hlo_text():
    for name, fn, specs, meta in aot.build_entries():
        if name == "layer_dnn4_0_b1":
            text = aot.lower_entry(fn, specs)
            assert text.startswith("HloModule")
            assert "f32[1,128]" in text
            return
    pytest.fail("entry not found")


def test_arch_manifest_macs():
    m = aot.arch_manifest()
    # cnn5 conv1: 16*16*3*3*1*8
    assert m["cnn5"]["layers"][0]["macs_per_sample"] == 16 * 16 * 9 * 8
    # dense layer macs = din*dout
    assert m["cnn5"]["layers"][2]["macs_per_sample"] == 256 * 64
    for arch in M.ARCHS:
        assert m[arch]["ncls"] == M.NCLS_BY_ARCH[arch]


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_on_disk_matches_entries():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    names = {e["name"] for e in man["entries"]}
    expected = {n for n, _, _, _ in aot.build_entries()}
    assert names == expected
    for e in man["entries"]:
        assert os.path.exists(os.path.join(ARTIFACTS, e["file"])), e["file"]
