"""L2 correctness: architecture shapes, Pallas/ref forward parity,
train_step learns, parameter layout matches the manifest contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.mark.parametrize("arch", list(M.ARCHS))
def test_param_shapes_consistent(arch):
    shapes = M.param_shapes(arch, 2)
    assert len(shapes) == 2 * len(M.ARCHS[arch]["layers"])
    params = M.init_params(arch, 2, jax.random.PRNGKey(0))
    for p, s in zip(params, shapes):
        assert tuple(p.shape) == tuple(s)


@pytest.mark.parametrize("arch", list(M.ARCHS))
@pytest.mark.parametrize("ncls", [2, 3])
def test_forward_shapes(arch, ncls):
    params = M.init_params(arch, ncls, jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2),
                          (4,) + tuple(M.ARCHS[arch]["input"]))
    logits = M.forward(arch, ncls, x, params)
    assert logits.shape == (4, ncls)


@pytest.mark.parametrize("arch", list(M.ARCHS))
def test_pallas_matches_ref_forward(arch):
    """Serving path (pallas) and reference graph agree end to end."""
    params = M.init_params(arch, 2, jax.random.PRNGKey(3))
    x = jax.random.normal(jax.random.PRNGKey(4),
                          (3,) + tuple(M.ARCHS[arch]["input"]))
    got = M.forward(arch, 2, x, params, use_pallas=True)
    want = M.forward(arch, 2, x, params, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", list(M.ARCHS))
def test_train_mode_matches_eval_forward(arch):
    """The training graph's forward equals the serving forward (so weights
    trained through it are valid for the Pallas serving path)."""
    params = M.init_params(arch, 2, jax.random.PRNGKey(5))
    x = jax.random.normal(jax.random.PRNGKey(6),
                          (3,) + tuple(M.ARCHS[arch]["input"]))
    got = M.forward(arch, 2, x, params, train_mode=True)
    want = M.forward(arch, 2, x, params, use_pallas=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_layer_shapes_chain():
    """Per-layer activation shapes chain correctly through each arch."""
    for arch, spec in M.ARCHS.items():
        prev_out = tuple(spec["input"])
        for i in range(len(spec["layers"])):
            _, ain, aout = M.layer_shapes(arch, i, 2)
            assert ain == prev_out
            prev_out = aout
        assert prev_out == (2,)


def test_train_step_reduces_loss():
    arch, ncls = "dnn4", 2
    key = jax.random.PRNGKey(7)
    params = M.init_params(arch, ncls, key)
    # separable synthetic data
    x = jax.random.normal(jax.random.PRNGKey(8), (M.BATCH_TRAIN, 128))
    y = (x[:, 0] > 0).astype(jnp.int32)
    x = x + 2.0 * y[:, None]
    lr = jnp.float32(0.05)
    losses = []
    for _ in range(30):
        out = M.train_step(arch, ncls, x, y, lr, *params)
        losses.append(float(out[0]))
        params = list(out[1:])
    assert losses[-1] < losses[0] * 0.5, losses[:3] + losses[-3:]


def test_train_step_param_count():
    arch, ncls = "cnn5", 3
    params = M.init_params(arch, ncls, jax.random.PRNGKey(9))
    x = jnp.zeros((M.BATCH_TRAIN,) + tuple(M.ARCHS[arch]["input"]))
    y = jnp.zeros((M.BATCH_TRAIN,), jnp.int32)
    out = M.train_step(arch, ncls, x, y, jnp.float32(0.01), *params)
    assert len(out) == 1 + len(params)
    for new, old in zip(out[1:], params):
        assert new.shape == old.shape


def test_loss_is_cross_entropy():
    """Uniform logits -> loss == log(ncls)."""
    arch, ncls = "dnn4", 2
    params = [jnp.zeros(s) for s in M.param_shapes(arch, ncls)]
    x = jnp.zeros((8, 128))
    y = jnp.zeros((8,), jnp.int32)
    loss = M.loss_fn(arch, ncls, params, x, y)
    np.testing.assert_allclose(float(loss), np.log(ncls), rtol=1e-5)
