"""L1 correctness: Pallas kernels vs the pure-jnp oracle (kernels/ref.py).

Hypothesis sweeps shapes/dtypes per the repro brief; assert_allclose
against ref. These tests are the CORE correctness signal for everything the
rust runtime later executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


# ---------------------------------------------------------------- dense ---

@given(
    m=st.integers(1, 48),
    k=st.integers(1, 160),
    n=st.integers(1, 96),
    act=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_dense_matches_ref(m, k, n, act, seed):
    x = _rand(seed, (m, k), jnp.float32)
    w = _rand(seed + 1, (k, n), jnp.float32)
    b = _rand(seed + 2, (n,), jnp.float32)
    got = K.dense(x, w, b, act)
    want = K.ref.dense(x, w, b, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@given(
    m=st.integers(1, 32),
    k=st.integers(1, 96),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, seed):
    x = _rand(seed, (m, k), jnp.float32)
    w = _rand(seed + 1, (k, n), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(K.matmul(x, w)), np.asarray(K.ref.matmul(x, w)),
        rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dense_dtypes(dtype):
    x = _rand(0, (8, 40), dtype)
    w = _rand(1, (40, 24), dtype)
    b = _rand(2, (24,), dtype)
    got = np.asarray(K.dense(x, w, b, True), dtype=np.float32)
    want = np.asarray(
        K.ref.dense(x.astype(jnp.float32), w.astype(jnp.float32),
                    b.astype(jnp.float32), True))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_dense_flattens_trailing_dims():
    x = _rand(3, (4, 4, 4, 16), jnp.float32)
    w = _rand(4, (256, 8), jnp.float32)
    b = _rand(5, (8,), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(K.dense(x, w, b, True)),
        np.asarray(K.ref.dense(x, w, b, True)), rtol=1e-4, atol=1e-4)


@given(m=st.integers(1, 16), k=st.integers(1, 64), n=st.integers(1, 48),
       act=st.booleans(), seed=st.integers(0, 2**16))
def test_dense_vjp_matches_ref(m, k, n, act, seed):
    x = _rand(seed, (m, k), jnp.float32)
    w = _rand(seed + 1, (k, n), jnp.float32)
    b = _rand(seed + 2, (n,), jnp.float32)

    def f_pallas(x, w, b):
        return K.dense(x, w, b, act).sum()

    def f_ref(x, w, b):
        return K.ref.dense(x, w, b, act).sum()

    g = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------- conv ---

@given(
    b=st.integers(1, 4),
    h=st.sampled_from([4, 6, 8, 12, 16]),
    w=st.sampled_from([4, 6, 8, 12, 16]),
    cin=st.integers(1, 8),
    cout=st.integers(1, 12),
    ksz=st.sampled_from([1, 3, 5]),
    act=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_conv2d_matches_ref(b, h, w, cin, cout, ksz, act, seed):
    x = _rand(seed, (b, h, w, cin), jnp.float32)
    wt = _rand(seed + 1, (ksz, ksz, cin, cout), jnp.float32)
    bias = _rand(seed + 2, (cout,), jnp.float32)
    got = K.conv2d(x, wt, bias, act)
    want = K.ref.conv2d(x, wt, bias, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_conv2d_asymmetric_kernel():
    x = _rand(0, (2, 8, 8, 3), jnp.float32)
    wt = _rand(1, (1, 3, 3, 5), jnp.float32)
    bias = _rand(2, (5,), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(K.conv2d(x, wt, bias, True)),
        np.asarray(K.ref.conv2d(x, wt, bias, True)), rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------- pool ---

@given(
    b=st.integers(1, 4),
    h=st.sampled_from([2, 4, 8, 16]),
    w=st.sampled_from([2, 4, 8, 16]),
    c=st.integers(1, 16),
    seed=st.integers(0, 2**16),
)
def test_maxpool_matches_ref(b, h, w, c, seed):
    x = _rand(seed, (b, h, w, c), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(K.maxpool2x2(x)), np.asarray(K.ref.maxpool2x2(x)),
        rtol=1e-6, atol=1e-6)


@given(
    b=st.integers(1, 3),
    hw=st.sampled_from([4, 8, 16]),
    cin=st.integers(1, 4),
    cout=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
def test_conv_pool_matches_ref(b, hw, cin, cout, seed):
    x = _rand(seed, (b, hw, hw, cin), jnp.float32)
    wt = _rand(seed + 1, (3, 3, cin, cout), jnp.float32)
    bias = _rand(seed + 2, (cout,), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(K.conv_pool(x, wt, bias)),
        np.asarray(K.ref.conv_pool(x, wt, bias)), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ edge cases --

def test_dense_zero_input():
    x = jnp.zeros((4, 10))
    w = _rand(0, (10, 6), jnp.float32)
    b = _rand(1, (6,), jnp.float32)
    np.testing.assert_allclose(np.asarray(K.dense(x, w, b, False)),
                               np.tile(np.asarray(b), (4, 1)),
                               rtol=1e-6, atol=1e-6)


def test_leaky_relu_negative_side():
    x = -jnp.ones((2, 4))
    w = jnp.eye(4)
    b = jnp.zeros((4,))
    got = np.asarray(K.dense(x, w, b, True))
    np.testing.assert_allclose(got, -0.01 * np.ones((2, 4)), rtol=1e-6,
                               atol=1e-6)
