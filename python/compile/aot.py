"""AOT lowering: every L2 entry point -> HLO *text* artifact + manifest.

HLO text (NOT lowered.compiler_ir().serialize() / jax.export bytes) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the rust crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts (per DESIGN.md):
  layer_{arch}_{i}[_c{ncls}]_b{batch}.hlo.txt   (x, w, b) -> (y,)
  train_{arch}_c{ncls}.hlo.txt                  (x, y, lr, *params) -> (loss, *new)
  eval_{arch}_c{ncls}.hlo.txt                   (x, *params) -> (logits,)
plus manifest.json describing shapes for the rust loader.

Usage: python -m compile.aot --out ../rust/artifacts   (from python/)
(the rust crate root is rust/, so default_artifacts_dir() resolves to
rust/artifacts when cargo runs — write artifacts there or set
ANTLER_ARTIFACTS)
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def lower_entry(fn, arg_specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*arg_specs))


def build_entries():
    """Yield (name, fn, arg_specs, meta) for every artifact."""
    for arch, spec in M.ARCHS.items():
        ncls_list = M.NCLS_BY_ARCH[arch]
        nlayers = len(spec["layers"])
        for i, (kind, cfg) in enumerate(spec["layers"]):
            cls_variants = ncls_list if kind == "logits" else [None]
            for ncls in cls_variants:
                eff = ncls if ncls is not None else 2
                pshapes, ain, aout = M.layer_shapes(arch, i, eff)
                for batch in (M.BATCH_SERVE, M.BATCH_PROFILE):
                    suffix = f"_c{ncls}" if ncls is not None else ""
                    name = f"layer_{arch}_{i}{suffix}_b{batch}"
                    args = [_spec((batch,) + ain)] + [_spec(s) for s in pshapes]
                    meta = {
                        "kind": "layer", "arch": arch, "layer": i,
                        "layer_kind": kind, "ncls": ncls, "batch": batch,
                        "inputs": [list(a.shape) for a in args],
                        "outputs": [[batch] + list(aout)],
                    }
                    yield name, M.layer_entry(arch, i, eff), args, meta
        for ncls in ncls_list:
            ps = [_spec(s) for s in M.param_shapes(arch, ncls)]
            x_train = _spec((M.BATCH_TRAIN,) + tuple(spec["input"]))
            y_train = _spec((M.BATCH_TRAIN,), jnp.int32)
            lr = _spec((), jnp.float32)
            name = f"train_{arch}_c{ncls}"
            meta = {
                "kind": "train", "arch": arch, "ncls": ncls,
                "batch": M.BATCH_TRAIN,
                "inputs": ([list(x_train.shape), list(y_train.shape), []]
                           + [list(p.shape) for p in ps]),
                "outputs": [[]] + [list(p.shape) for p in ps],
            }
            yield name, M.train_entry(arch, ncls), [x_train, y_train, lr] + ps, meta

            x_eval = _spec((M.BATCH_EVAL,) + tuple(spec["input"]))
            name = f"eval_{arch}_c{ncls}"
            meta = {
                "kind": "eval", "arch": arch, "ncls": ncls,
                "batch": M.BATCH_EVAL,
                "inputs": [list(x_eval.shape)] + [list(p.shape) for p in ps],
                "outputs": [[M.BATCH_EVAL, ncls]],
            }
            yield name, M.eval_entry(arch, ncls), [x_eval] + ps, meta


def arch_manifest():
    out = {}
    for arch, spec in M.ARCHS.items():
        layers = []
        shape = tuple(spec["input"])
        for i, (kind, cfg) in enumerate(spec["layers"]):
            pshapes, ain, aout = M.layer_shapes(arch, i, 2)
            if kind == "conv_pool":
                # conv output (pre-pool) spatial size = input spatial size
                macs = ain[0] * ain[1] * cfg["kh"] * cfg["kw"] * cfg["cin"] * cfg["cout"]
            else:
                macs = cfg["din"] * (cfg["dout"] or 2)
            layers.append({
                "kind": kind, "cfg": cfg, "in": list(ain), "out": list(aout),
                "macs_per_sample": macs,
            })
        out[arch] = {
            "input": list(spec["input"]),
            "layers": layers,
            "ncls": M.NCLS_BY_ARCH[arch],
        }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../rust/artifacts")
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names (debugging)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"version": 1, "archs": arch_manifest(), "entries": []}
    t0 = time.time()
    count = 0
    for name, fn, specs, meta in build_entries():
        if args.only and args.only not in name:
            continue
        path = os.path.join(args.out, name + ".hlo.txt")
        text = lower_entry(fn, specs)
        with open(path, "w") as f:
            f.write(text)
        meta = dict(meta)
        meta["name"] = name
        meta["file"] = name + ".hlo.txt"
        meta["sha256"] = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["entries"].append(meta)
        count += 1
        print(f"[{time.time() - t0:7.1f}s] {name} ({len(text)} chars)",
              file=sys.stderr)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {count} artifacts + manifest.json to {args.out}",
          file=sys.stderr)


if __name__ == "__main__":
    main()
