"""L1 Pallas kernels for the Antler common network architectures."""

from . import ref  # noqa: F401
from .conv2d import conv2d  # noqa: F401
from .dense import dense, matmul  # noqa: F401
from .pool import conv_pool, maxpool2x2  # noqa: F401
