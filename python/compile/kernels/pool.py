"""Pallas 2x2 max-pooling kernel (stride 2), batch-gridded like conv2d."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool_kernel(x_ref, o_ref):
    _, h, w, c = x_ref.shape
    x = x_ref[0]
    o_ref[0] = x.reshape(h // 2, 2, w // 2, 2, c).max(axis=(1, 3))


def maxpool2x2(x):
    """x: (B, H, W, C) with even H, W -> (B, H/2, W/2, C)."""
    bsz, h, w, c = x.shape
    assert h % 2 == 0 and w % 2 == 0, x.shape
    return pl.pallas_call(
        _pool_kernel,
        grid=(bsz,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, h // 2, w // 2, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h // 2, w // 2, c), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))


def conv_pool(x, w, b):
    """Fused "conv layer" of the common architecture on the Pallas path."""
    from .conv2d import conv2d as _conv2d

    return maxpool2x2(_conv2d(x, w, b, activation=True))
