"""Pallas direct-convolution kernel (same padding, stride 1) + maxpool.

The MCU implementation of the paper walks the image in SRAM with the
weights streamed from FRAM; the TPU adaptation tiles over the batch grid —
each program instance holds one padded input image, the full (KH,KW,Cin,
Cout) filter bank, and the (H,W,Cout) accumulator in VMEM. For the paper's
layer sizes (<= 32x32x32) that working set is ~0.3 MiB, comfortably within
VMEM; the KH*KW static unroll turns the conv into MXU-shaped (H*W, Cin) @
(Cin, Cout) contractions.

interpret=True throughout (CPU PJRT cannot execute Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, kh: int, kw: int,
                 activation: bool):
    """One batch element: x_ref (1, H+kh-1, W+kw-1, Cin) pre-padded."""
    _, hp, wp, cin = x_ref.shape
    _, h, w, cout = o_ref.shape
    x = x_ref[0]
    acc = jnp.zeros((h * w, cout), dtype=jnp.float32)
    for dh in range(kh):
        for dw in range(kw):
            patch = x[dh:dh + h, dw:dw + w, :].reshape(h * w, cin)
            acc += jnp.dot(patch, w_ref[dh, dw],
                           preferred_element_type=jnp.float32)
    y = acc.reshape(h, w, cout) + b_ref[...]
    if activation:
        y = jnp.where(y > 0, y, ref.LEAKY_SLOPE * y)
    o_ref[0] = y


def conv2d(x, w, b, activation=True):
    """Same-padded stride-1 conv, NHWC / HWIO, fused bias + leaky-ReLU."""
    bsz, h, wd, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2, (x.shape, w.shape)
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))
    hp, wp = h + kh - 1, wd + kw - 1
    return pl.pallas_call(
        functools.partial(_conv_kernel, kh=kh, kw=kw, activation=activation),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, hp, wp, cin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, cout), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((1, cout), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, wd, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, wd, cout), jnp.float32),
        interpret=True,
    )(xp.astype(jnp.float32), w.astype(jnp.float32),
      b.reshape(1, cout).astype(jnp.float32))
