"""Pallas dense (fully-connected) kernel — the MXU-shaped matmul hot path.

TPU adaptation of the paper's MCU dense layer (DESIGN.md
§Hardware-Adaptation): the MCU streams FRAM->SRAM weight pages; here the
BlockSpec grid expresses the analogous HBM->VMEM schedule. The contraction
is tiled (block_m x block_k) @ (block_k x block_n) with an f32 accumulator
held in the output block across the K steps of the grid — the canonical
systolic-friendly layout.

`interpret=True` everywhere: the CPU PJRT client cannot run Mosaic
custom-calls (see /opt/xla-example/README.md), so kernels lower to plain
HLO and correctness/structure are what we validate here; device timing in
the benchmarks comes from the L3 cost models.

The kernel carries a custom VJP whose backward pass is also expressed with
the same Pallas matmul, so `jax.grad` through a dense layer stays on the
kernel path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default VMEM-friendly tile sizes. For the paper's layer sizes (K,N <= 512)
# a (32, 128, 128) tiling keeps the working set
# (bm*bk + bk*bn + bm*bn) * 4B  <= ~80 KiB, far below a 16 MiB VMEM budget,
# leaving room for double buffering; see DESIGN.md §Perf.
BLOCK_M = 32
BLOCK_K = 128
BLOCK_N = 128


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """Grid (Mi, Nj, Kk); accumulates partial products into the output block."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def matmul(x, w, *, block_m=BLOCK_M, block_k=BLOCK_K, block_n=BLOCK_N):
    """Tiled Pallas matmul: (M, K) @ (K, N) -> (M, N), f32 accumulate."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bk, bn = min(block_m, m), min(block_k, k), min(block_n, n)
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp.astype(jnp.float32), wp.astype(jnp.float32))
    return out[:m, :n]


def _bias_act_kernel(y_ref, b_ref, o_ref, *, activation: bool):
    y = y_ref[...] + b_ref[...]
    if activation:
        y = jnp.where(y > 0, y, ref.LEAKY_SLOPE * y)
    o_ref[...] = y


def _bias_act(y, b, activation: bool):
    m, n = y.shape
    return pl.pallas_call(
        functools.partial(_bias_act_kernel, activation=activation),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((m, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(y, b.reshape(1, n).astype(jnp.float32))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, activation=True):
    """Dense layer on the Pallas path: leaky_relu(x @ w + b) (or no act).

    Accepts (B, ...) inputs; flattens trailing dims (the architecture's
    flatten-into-fc1 step).
    """
    y, _ = _dense_fwd(x, w, b, activation)
    return y


def _dense_fwd(x, w, b, activation):
    x2 = x.reshape(x.shape[0], -1)
    pre = _bias_act(matmul(x2, w), b, False)
    y = _bias_act(pre, jnp.zeros_like(b), True) if activation else pre
    return y, (x2, w, pre, x.shape)


def _dense_bwd(activation, res, g):
    x2, w, pre, xshape = res
    if activation:
        g = g * jnp.where(pre > 0, 1.0, ref.LEAKY_SLOPE)
    # Backward matmuls stay on the Pallas kernel path.
    dx = matmul(g, w.T).reshape(xshape)
    dw = matmul(x2.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)
