"""Pure-jnp reference oracle for the Pallas kernels (L1 correctness signal).

Every kernel in this package must match these functions to float32
tolerance under pytest/hypothesis sweeps (python/tests/test_kernel.py).
The training graph (model.train_step) also uses the conv reference for its
backward pass — see DESIGN.md, Substitutions.
"""

import jax
import jax.numpy as jnp

LEAKY_SLOPE = 0.01


def leaky_relu(x):
    return jnp.where(x > 0, x, LEAKY_SLOPE * x)


def dense(x, w, b, activation=True):
    """y = x @ w + b, optionally leaky-ReLU. x: (B, K) or (B, ...) flattened."""
    x = x.reshape(x.shape[0], -1)
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    return leaky_relu(y) if activation else y


def matmul(x, w):
    """Plain matmul (used by the dense kernel's custom VJP)."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def conv2d(x, w, b, activation=True):
    """Same-padded stride-1 conv. x: (B, H, W, Cin) NHWC; w: (KH, KW, Cin, Cout)."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    y = y + b
    return leaky_relu(y) if activation else y


def maxpool2x2(x):
    """2x2 max pooling, stride 2. x: (B, H, W, C) with even H, W."""
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def conv_pool(x, w, b):
    """The fused "conv layer" of the common architecture: conv+bias+leaky+pool."""
    return maxpool2x2(conv2d(x, w, b, activation=True))
