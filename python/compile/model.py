"""L2 — the Antler common network architectures as JAX per-layer blocks.

The paper (§2.1) instantiates ONE common architecture per application
domain and trains it per task; task graphs then share *prefixes* of the
layer list. To let the rust coordinator (L3) implement block sharing,
load-skipping and branch-point activation caching, every layer is lowered
to its own HLO artifact (weights are runtime arguments), plus a
whole-network forward for batch eval and a `train_step` that returns the
SGD-updated parameters.

Architectures mirror Table 2 / §7 at reduced input resolution:
  cnn5 — "5-layer CNN, 2 conv + 3 dense" (audio / LeNet-5 class)
  cnn7 — "7-layer CNN, 3 conv + 4 dense" (image / §7.2)
  dnn4 — 4 dense layers (IMU / DeepSense-lite analog)

Forward layers call the L1 Pallas kernels; the conv backward pass uses the
jnp reference (kernels.ref) — see DESIGN.md Substitutions. Dense layers
differentiate through the Pallas kernel via its custom VJP.
"""

import functools

import jax
import jax.numpy as jnp

from . import kernels as K

# ---------------------------------------------------------------------------
# Architecture specs. A layer is (kind, cfg); `dout == 0` on the logits layer
# means "number of classes, chosen at instantiation time".
# ---------------------------------------------------------------------------

ARCHS = {
    "cnn5": {
        "input": (16, 16, 1),
        "layers": [
            ("conv_pool", {"kh": 3, "kw": 3, "cin": 1, "cout": 8}),
            ("conv_pool", {"kh": 3, "kw": 3, "cin": 8, "cout": 16}),
            ("dense", {"din": 4 * 4 * 16, "dout": 64}),
            ("dense", {"din": 64, "dout": 32}),
            ("logits", {"din": 32, "dout": 0}),
        ],
    },
    "cnn7": {
        "input": (32, 32, 1),
        "layers": [
            ("conv_pool", {"kh": 3, "kw": 3, "cin": 1, "cout": 8}),
            ("conv_pool", {"kh": 3, "kw": 3, "cin": 8, "cout": 16}),
            ("conv_pool", {"kh": 3, "kw": 3, "cin": 16, "cout": 32}),
            ("dense", {"din": 4 * 4 * 32, "dout": 128}),
            ("dense", {"din": 128, "dout": 64}),
            ("dense", {"din": 64, "dout": 32}),
            ("logits", {"din": 32, "dout": 0}),
        ],
    },
    "dnn4": {
        "input": (128,),
        "layers": [
            ("dense", {"din": 128, "dout": 64}),
            ("dense", {"din": 64, "dout": 64}),
            ("dense", {"din": 64, "dout": 32}),
            ("logits", {"din": 32, "dout": 0}),
        ],
    },
}


def layer_shapes(arch: str, idx: int, ncls: int):
    """(param shapes, input activation shape, output activation shape),
    activation shapes without the batch dim."""
    spec = ARCHS[arch]
    kind, cfg = spec["layers"][idx]
    # activation shape entering layer idx
    shape = tuple(spec["input"])
    for k, c in spec["layers"][:idx]:
        shape = _out_shape(k, c, shape, ncls)
    out = _out_shape(kind, cfg, shape, ncls)
    if kind == "conv_pool":
        pshapes = [(cfg["kh"], cfg["kw"], cfg["cin"], cfg["cout"]),
                   (cfg["cout"],)]
    else:
        dout = cfg["dout"] or ncls
        pshapes = [(cfg["din"], dout), (dout,)]
    return pshapes, shape, out


def _out_shape(kind, cfg, in_shape, ncls):
    if kind == "conv_pool":
        h, w, _ = in_shape
        return (h // 2, w // 2, cfg["cout"])
    dout = cfg["dout"] or ncls
    return (dout,)


def param_shapes(arch: str, ncls: int):
    """Flat list of parameter shapes [w0, b0, w1, b1, ...]."""
    out = []
    for i in range(len(ARCHS[arch]["layers"])):
        out.extend(layer_shapes(arch, i, ncls)[0])
    return out


def init_params(arch: str, ncls: int, key):
    """He-style init, flat [w0, b0, ...] list (matches the rust WeightStore)."""
    params = []
    for shp in param_shapes(arch, ncls):
        if len(shp) > 1:
            fan_in = 1
            for d in shp[:-1]:
                fan_in *= d
            key, sub = jax.random.split(key)
            params.append(jax.random.normal(sub, shp, jnp.float32)
                          * jnp.sqrt(2.0 / fan_in))
        else:
            params.append(jnp.zeros(shp, jnp.float32))
    return params


# ---------------------------------------------------------------------------
# Layer forward functions
# ---------------------------------------------------------------------------

def layer_apply(kind: str, x, w, b, *, use_pallas=True):
    R = K if use_pallas else K.ref
    if kind == "conv_pool":
        if use_pallas:
            return K.conv_pool(x, w, b)
        return K.ref.conv_pool(x, w, b)
    if kind == "dense":
        return R.dense(x, w, b, True)
    if kind == "logits":
        return R.dense(x, w, b, False)
    raise ValueError(kind)


def forward(arch: str, ncls: int, x, params, *, use_pallas=True,
            train_mode=False):
    """Whole-network forward. In train_mode convs use the jnp reference
    (differentiable); dense stays on the Pallas custom-VJP path."""
    i = 0
    for kind, _ in ARCHS[arch]["layers"]:
        w, b = params[i], params[i + 1]
        if train_mode and kind == "conv_pool":
            x = K.ref.conv_pool(x, w, b)
        else:
            x = layer_apply(kind, x, w, b, use_pallas=use_pallas)
        i += 2
    return x


def loss_fn(arch, ncls, params, x, y):
    """Mean softmax cross-entropy; y: int32 labels."""
    logits = forward(arch, ncls, x, params, train_mode=True)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1).squeeze(-1)
    return jnp.mean(nll)


def train_step(arch: str, ncls: int, x, y, lr, *params):
    """One SGD step. Returns (loss, *updated_params) — the L3 trainer
    simply swaps the returned tensors into the block weight store."""
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(arch, ncls, p, x, y))(list(params))
    new = [p - lr * g for p, g in zip(params, grads)]
    return (loss, *new)


def eval_logits(arch: str, ncls: int, x, *params):
    """Batch forward on the Pallas path (serving parity) -> logits."""
    return (forward(arch, ncls, x, list(params), use_pallas=True),)


def layer_entry(arch: str, idx: int, ncls: int):
    """The (x, w, b) -> (y,) function lowered per layer artifact."""
    kind, _ = ARCHS[arch]["layers"][idx]

    def fn(x, w, b):
        return (layer_apply(kind, x, w, b, use_pallas=True),)

    return fn


def train_entry(arch: str, ncls: int):
    def fn(x, y, lr, *params):
        return train_step(arch, ncls, x, y, lr, *params)

    return fn


def eval_entry(arch: str, ncls: int):
    def fn(x, *params):
        return eval_logits(arch, ncls, x, *params)

    return fn


# Class-count requirements per architecture (datasets: one-vs-rest binary
# tasks; deployments: §7.1 audio {2,11,5,3}, §7.2 image {2,5,3}).
NCLS_BY_ARCH = {
    "cnn5": [2, 3, 5, 11],
    "cnn7": [2, 3, 5],
    "dnn4": [2],
}

BATCH_SERVE = 1
BATCH_PROFILE = 32
BATCH_TRAIN = 32
BATCH_EVAL = 64
