#!/usr/bin/env bash
# CI gate. The suite must never pass vacuously: the default build has no
# PJRT feature, so every engine test runs on the pure-Rust reference
# backend — zero artifact-gated skips.
#
#   ./ci.sh            # tier-1 gate (whole suite on the reference backend)
#                      # + bench compile check + clippy (GATING: findings
#                      # are fatal by default)
#   ./ci.sh --advisory # escape hatch: clippy findings warn instead of
#                      # failing (for lint drift in a newer clippy release)
#   ./ci.sh --pjrt     # additionally build+test with --features pjrt
#                      # (runs the PJRT/parity tests when artifacts exist)
set -euo pipefail
cd "$(dirname "$0")/rust"

STRICT=1
PJRT=0
for arg in "$@"; do
    case "$arg" in
        --strict) STRICT=1 ;;   # kept for compatibility; already the default
        --advisory) STRICT=0 ;;
        --pjrt) PJRT=1 ;;
    esac
done

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
# the default build has no pjrt feature, so this whole suite runs on the
# reference backend — engine tests cannot skip
cargo test -q

# benches are harness=false binaries that cargo test does not compile;
# without this they rot silently
echo "== benches compile: cargo bench --no-run =="
cargo bench --no-run

# clippy on the default feature set — gating by default (a finding fails
# CI). `--advisory` is the escape hatch for lint drift in a newer clippy
# release: findings warn, the gate passes.
echo "== clippy: cargo clippy -- -D warnings =="
if command -v cargo-clippy >/dev/null 2>&1 || cargo clippy --version >/dev/null 2>&1; then
    if cargo clippy -- -D warnings; then
        echo "clippy clean"
    elif [[ "$STRICT" == 1 ]]; then
        echo "clippy findings (fatal; ./ci.sh --advisory to downgrade)"
        exit 1
    else
        echo "WARNING: clippy findings above (advisory mode)"
    fi
else
    echo "(clippy not installed; skipped)"
fi

if [[ "$PJRT" == 1 ]]; then
    echo "== pjrt feature build =="
    cargo build --release --features pjrt
    cargo test -q --features pjrt
    if [[ -f "${ANTLER_ARTIFACTS:-artifacts}/manifest.json" ]]; then
        echo "== pjrt backend + parity tests (artifacts found) =="
        ANTLER_BACKEND=pjrt cargo test -q --features pjrt
    else
        echo "(no artifacts at ${ANTLER_ARTIFACTS:-artifacts}; parity tests self-skip)"
    fi
fi

echo "CI OK"
