#!/usr/bin/env bash
# CI gate. The suite must never pass vacuously: the default build has no
# PJRT feature, so every engine test runs on the pure-Rust reference
# backend — zero artifact-gated skips.
#
#   ./ci.sh            # tier-1 gate (whole suite on the reference backend)
#                      # + bench compile check + custom lint + clippy
#                      # (GATING: findings are fatal by default)
#   ./ci.sh --advisory # escape hatch: clippy findings warn instead of
#                      # failing (for lint drift in a newer clippy release)
#   ./ci.sh --pjrt     # additionally build+test with --features pjrt
#                      # (runs the PJRT/parity tests when artifacts exist)
#   ./ci.sh --loom     # model-checking lane: exhaustively interleave the
#                      # steal-queue / CloseOnDrop / mark_dead / ingest
#                      # barrier / pool-shutdown protocols under loom.
#                      # Stable-toolchain, so GATING — except when the
#                      # loom crate cannot be fetched (offline builder),
#                      # which degrades to a loud advisory skip.
#   ./ci.sh --miri     # advisory: Miri over the non-threaded unit tests
#                      # (UB check). Skips loudly without nightly+miri.
#   ./ci.sh --tsan     # advisory: ThreadSanitizer over the test suite
#                      # (-Zsanitizer=thread). Skips loudly w/o nightly.
#   ./ci.sh --analyzer-only
#                      # fast pre-commit lane: just the semantic lint
#                      # gate (cargo run -p pallas-analyzer, rules
#                      # A1-A5), falling back to tools/lint.sh with a
#                      # loud advisory when cargo is unavailable.
#
# See CONCURRENCY.md for what each lane proves and how to run it locally.
set -euo pipefail
cd "$(dirname "$0")/rust"

STRICT=1
PJRT=0
LOOM=0
MIRI=0
TSAN=0
ANALYZER_ONLY=0
for arg in "$@"; do
    case "$arg" in
        --strict) STRICT=1 ;;   # kept for compatibility; already the default
        --advisory) STRICT=0 ;;
        --pjrt) PJRT=1 ;;
        --loom) LOOM=1 ;;
        --miri) MIRI=1 ;;
        --tsan) TSAN=1 ;;
        --analyzer-only) ANALYZER_ONLY=1 ;;
    esac
done

# The semantic lint gate: pallas-analyzer (tools/analyzer) parses
# rust/src and enforces rules A1-A5 (facade, hot-path panics, wait
# annotations, guard-across-blocking, custody exhaustiveness) — see
# CONCURRENCY.md §Static gates. Gating when cargo exists; otherwise a
# LOUD advisory fallback to the grep approximation (tools/lint.sh),
# which cannot check A4/A5 at all.
run_analyzer() {
    echo "== analyzer: cargo run -p pallas-analyzer (gating, rules A1-A5) =="
    if command -v cargo >/dev/null 2>&1; then
        cargo run --release -q -p pallas-analyzer
    else
        echo "WARNING: cargo unavailable — semantic rules A1-A5 NOT checked."
        echo "         Falling back to the grep approximation (tools/lint.sh);"
        echo "         run './ci.sh --analyzer-only' on a machine with a Rust"
        echo "         toolchain before merging."
        ../tools/lint.sh
    fi
}

# Teeth check: seed one violation per rule into a scratch copy of the
# tree and assert the gate fails AND names the right rule — the same
# discipline the grep gates got in PR 6. The A2 payload is appended
# AFTER wire.rs's test module on purpose: the awk fallback goes blind
# past the first test marker, the analyzer's item-level spans do not.
analyzer_teeth() {
    echo "== analyzer teeth: seeded A1-A5 violations must fail the gate =="
    cargo build --release -q -p pallas-analyzer
    local bin="${CARGO_TARGET_DIR:-../target}/release/pallas-analyzer"
    local rule tmp out
    for rule in A1 A2 A3 A4 A5; do
        tmp=$(mktemp -d)
        mkdir -p "$tmp/rust"
        cp -r src "$tmp/rust/src"
        case "$rule" in
            A1) echo 'use std::{collections::BTreeMap, sync::Mutex as TeethMutex};' \
                >> "$tmp/rust/src/util/mod.rs" ;;
            A2) echo 'pub fn teeth_a2(v: &[u32]) -> u32 { v[0] }' \
                >> "$tmp/rust/src/coordinator/wire.rs" ;;
            A3) echo 'pub fn teeth_a3(cv: &Cv, g: G) -> G { cv.wait(g) }' \
                >> "$tmp/rust/src/util/mod.rs" ;;
            A4) echo 'pub fn teeth_a4(m: &M) { let g = lock_unpoisoned(m); sleep(D); drop(g); }' \
                >> "$tmp/rust/src/util/mod.rs" ;;
            A5) echo 'pub fn teeth_a5(a: Admission) -> u32 { match a { Admission::Delivered => 1, _ => 0 } }' \
                >> "$tmp/rust/src/util/mod.rs" ;;
        esac
        if out=$("$bin" "$tmp" 2>&1); then
            echo "analyzer teeth FAILED: seeded $rule violation passed the gate"
            rm -rf "$tmp"
            exit 1
        fi
        if ! grep -q ": $rule:" <<<"$out"; then
            echo "analyzer teeth FAILED: seeded $rule violation not reported as $rule"
            echo "$out"
            rm -rf "$tmp"
            exit 1
        fi
        rm -rf "$tmp"
        echo "  teeth($rule): gate fails as it must"
    done
}

if [[ "$ANALYZER_ONLY" == 1 ]]; then
    run_analyzer
    echo "analyzer-only lane OK"
    exit 0
fi

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
# the default build has no pjrt feature, so this whole suite runs on the
# reference backend — engine tests cannot skip
cargo test -q

# the two-tier weight-memory battery, invoked BY NAME so a rename or an
# accidental #[ignore] can never silently drop the parity gate: the
# eviction-policy unit suite, the executor/serve/shard parity tests, and
# the property test that pins tiered serving to the flat baseline at
# every capacity. cargo exits 0 on a filter that matches nothing, so the
# gate also demands that at least one test actually ran.
echo "== weight-tier gate: parity + eviction suites (named) =="
tier_gate() {
    local log
    log=$(cargo test -q "$@" 2>&1) || { echo "$log"; exit 1; }
    if ! echo "$log" | grep -qE '^test result: ok\. [1-9]'; then
        echo "$log"
        echo "weight-tier gate FAILED: no tests matched '$*'"
        exit 1
    fi
}
tier_gate --lib memory::tier::
tier_gate --lib tiered_
tier_gate --test props prop_tiered_serving_matches_flat_baseline

# the network-QoS battery, same by-name rule: wire-format + admission
# unit suites, the listener integration tests, the 64-connection
# shedding acceptance test, and the shedding-order property test. A
# rename or an accidental #[ignore] fails the gate rather than
# silently dropping coverage.
echo "== network-QoS gate: wire/listener/shedding suites (named) =="
tier_gate --lib coordinator::wire::
tier_gate --lib coordinator::net::
tier_gate --test net_qos qos_
tier_gate --test props prop_qos_shedding_never_drops_realtime_before_best_effort

# the multi-tenant registry battery, same by-name rule: the registry and
# replanner unit suites, the tenant-routing / hot-swap / parity-pin
# acceptance tests, and the per-epoch hot-swap property test. The
# single-tenant parity pin inside tests/multi_tenant.rs is the contract
# that the registry refactor changed no pre-existing behavior.
echo "== multi-tenant gate: registry/replan/hot-swap suites (named) =="
tier_gate --lib coordinator::registry::
tier_gate --lib coordinator::replan::
tier_gate --test multi_tenant
tier_gate --test props prop_plan_hot_swap_matches_per_epoch_baselines

# benches are harness=false binaries that cargo test does not compile;
# without this they rot silently
echo "== benches compile: cargo bench --no-run =="
cargo bench --no-run

# the semantic lint gate (rules A1-A5) + its seeded-violation teeth
run_analyzer
if command -v cargo >/dev/null 2>&1; then
    analyzer_teeth
fi

# the grep fallback still runs in the default lane — it is nearly free,
# and running it here is what keeps the fallback honest (a rule that
# drifts from the analyzer shows up as a disagreement, not silently)
echo "== custom lint (fallback parity): tools/lint.sh =="
../tools/lint.sh

# clippy on the default feature set — gating by default (a finding fails
# CI). `--advisory` is the escape hatch for lint drift in a newer clippy
# release: findings warn, the gate passes.
echo "== clippy: cargo clippy -- -D warnings =="
if command -v cargo-clippy >/dev/null 2>&1 || cargo clippy --version >/dev/null 2>&1; then
    if cargo clippy -- -D warnings; then
        echo "clippy clean"
    elif [[ "$STRICT" == 1 ]]; then
        echo "clippy findings (fatal; ./ci.sh --advisory to downgrade)"
        exit 1
    else
        echo "WARNING: clippy findings above (advisory mode)"
    fi
else
    echo "(clippy not installed; skipped)"
fi

if [[ "$LOOM" == 1 ]]; then
    # Release profile on purpose: loom state spaces are large, and the
    # debug-assertions custody ledgers (coordinator::audit) are compiled
    # out so the model checks the protocol, not the auditor. The `loom_`
    # filter matters: non-loom tests are cfg'd out under --cfg loom, and
    # loom primitives panic outside a model anyway.
    echo "== loom lane: RUSTFLAGS=--cfg loom cargo test --release --lib loom_ =="
    loom_log=$(mktemp)
    if RUSTFLAGS="--cfg loom" cargo test --release --lib loom_ 2>&1 | tee "$loom_log"; then
        echo "loom models pass"
    elif grep -qE 'failed to (fetch|download|get)|network|offline|error: no matching package' "$loom_log"; then
        # a target-gated dep (loom) is only fetched for this lane; an
        # offline builder cannot gate on it — skip LOUDLY, not silently
        echo "WARNING: loom lane SKIPPED — loom crate unfetchable (offline?)"
        echo "         run './ci.sh --loom' on a networked machine before merging"
    else
        echo "loom lane FAILED (a model found an interleaving bug or build broke)"
        rm -f "$loom_log"
        exit 1
    fi
    rm -f "$loom_log"
fi

if [[ "$MIRI" == 1 ]]; then
    # Advisory: Miri needs nightly + the miri component. Interpreted
    # execution is far too slow for the threaded serving tests, so the
    # lane covers the pure single-threaded modules — the kernels the
    # serving stack computes with and the auditor itself.
    echo "== miri lane (advisory): nightly miri over non-threaded unit tests =="
    if rustup +nightly component list 2>/dev/null | grep -q 'miri.*(installed)'; then
        if cargo +nightly miri test --lib \
            audit:: model:: taskgraph:: ordering:: affinity:: memory:: util::; then
            echo "miri clean"
        else
            echo "WARNING: miri findings above (advisory lane)"
        fi
    else
        echo "WARNING: miri lane SKIPPED — nightly toolchain with miri not installed"
        echo "         (rustup toolchain install nightly; rustup +nightly component add miri)"
    fi
fi

if [[ "$TSAN" == 1 ]]; then
    # Advisory: TSan needs nightly (-Zsanitizer=thread) and a std built
    # for the sanitizer. Complements loom: loom exhausts small modeled
    # schedules, TSan samples real ones across the whole suite.
    echo "== tsan lane (advisory): -Zsanitizer=thread over the test suite =="
    if rustup +nightly target list 2>/dev/null | grep -q '(installed)'; then
        host=$(rustc -vV | sed -n 's/^host: //p')
        if RUSTFLAGS="-Zsanitizer=thread" cargo +nightly test -q \
            -Zbuild-std --target "$host"; then
            echo "tsan clean"
        else
            echo "WARNING: tsan findings above (advisory lane)"
        fi
    else
        echo "WARNING: tsan lane SKIPPED — nightly toolchain not installed"
        echo "         (rustup toolchain install nightly --component rust-src)"
    fi
fi

if [[ "$PJRT" == 1 ]]; then
    echo "== pjrt feature build =="
    cargo build --release --features pjrt
    cargo test -q --features pjrt
    if [[ -f "${ANTLER_ARTIFACTS:-artifacts}/manifest.json" ]]; then
        echo "== pjrt backend + parity tests (artifacts found) =="
        ANTLER_BACKEND=pjrt cargo test -q --features pjrt
    else
        echo "(no artifacts at ${ANTLER_ARTIFACTS:-artifacts}; parity tests self-skip)"
    fi
fi

echo "CI OK"
