#!/usr/bin/env bash
# CI gate. The suite must never pass vacuously: the default build has no
# PJRT feature, so every engine test runs on the pure-Rust reference
# backend — zero artifact-gated skips.
#
#   ./ci.sh            # tier-1 gate (whole suite on the reference backend)
#   ./ci.sh --pjrt     # additionally build+test with --features pjrt
#                      # (runs the PJRT/parity tests when artifacts exist)
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
# the default build has no pjrt feature, so this whole suite runs on the
# reference backend — engine tests cannot skip
cargo test -q

if [[ "${1:-}" == "--pjrt" ]]; then
    echo "== pjrt feature build =="
    cargo build --release --features pjrt
    cargo test -q --features pjrt
    if [[ -f "${ANTLER_ARTIFACTS:-artifacts}/manifest.json" ]]; then
        echo "== pjrt backend + parity tests (artifacts found) =="
        ANTLER_BACKEND=pjrt cargo test -q --features pjrt
    else
        echo "(no artifacts at ${ANTLER_ARTIFACTS:-artifacts}; parity tests self-skip)"
    fi
fi

echo "CI OK"
