#!/usr/bin/env bash
# CI gate. The suite must never pass vacuously: the default build has no
# PJRT feature, so every engine test runs on the pure-Rust reference
# backend — zero artifact-gated skips.
#
#   ./ci.sh            # tier-1 gate (whole suite on the reference backend)
#                      # + bench compile check + clippy (advisory)
#   ./ci.sh --strict   # clippy findings become fatal
#   ./ci.sh --pjrt     # additionally build+test with --features pjrt
#                      # (runs the PJRT/parity tests when artifacts exist)
set -euo pipefail
cd "$(dirname "$0")/rust"

STRICT=0
PJRT=0
for arg in "$@"; do
    case "$arg" in
        --strict) STRICT=1 ;;
        --pjrt) PJRT=1 ;;
    esac
done

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
# the default build has no pjrt feature, so this whole suite runs on the
# reference backend — engine tests cannot skip
cargo test -q

# benches are harness=false binaries that cargo test does not compile;
# without this they rot silently
echo "== benches compile: cargo bench --no-run =="
cargo bench --no-run

# clippy on the default feature set. Advisory by default so that lint
# drift in a newer clippy release cannot break the tier-1 gate; --strict
# (the mode CI proper should run) makes findings fatal.
echo "== clippy: cargo clippy -- -D warnings =="
if command -v cargo-clippy >/dev/null 2>&1 || cargo clippy --version >/dev/null 2>&1; then
    if cargo clippy -- -D warnings; then
        echo "clippy clean"
    elif [[ "$STRICT" == 1 ]]; then
        echo "clippy findings (fatal under --strict)"
        exit 1
    else
        echo "WARNING: clippy findings above (advisory; ./ci.sh --strict gates on them)"
    fi
else
    echo "(clippy not installed; skipped)"
fi

if [[ "$PJRT" == 1 ]]; then
    echo "== pjrt feature build =="
    cargo build --release --features pjrt
    cargo test -q --features pjrt
    if [[ -f "${ANTLER_ARTIFACTS:-artifacts}/manifest.json" ]]; then
        echo "== pjrt backend + parity tests (artifacts found) =="
        ANTLER_BACKEND=pjrt cargo test -q --features pjrt
    else
        echo "(no artifacts at ${ANTLER_ARTIFACTS:-artifacts}; parity tests self-skip)"
    fi
fi

echo "CI OK"
