//! Fixture battery: every rule must flag its known-bad fixture at
//! exactly the `//~ RULE` marker lines (no more, no less) and stay
//! silent on its known-good twin. A final test pins the real tree
//! clean, so a regression in either the rules or the tree fails
//! `cargo test -p pallas-analyzer`.

use std::collections::BTreeSet;
use std::path::Path;

use pallas_analyzer::analyze_sources;
use pallas_analyzer::config::Config;

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

/// `//~ RULE` markers → set of (1-based line, rule).
fn markers(src: &str) -> BTreeSet<(usize, String)> {
    src.lines()
        .enumerate()
        .filter_map(|(i, l)| l.split("//~").nth(1).map(|m| (i + 1, m.trim().to_string())))
        .collect()
}

fn run(name: &str) -> (BTreeSet<(usize, String)>, BTreeSet<(usize, String)>) {
    let src = fixture(name);
    let cfg = Config::fixtures(name);
    let found = analyze_sources(&[(name.to_string(), src.clone())], &cfg)
        .into_iter()
        .map(|f| (f.line, f.rule.to_string()))
        .collect();
    (markers(&src), found)
}

fn assert_exact(name: &str) {
    let (want, got) = run(name);
    assert!(!want.is_empty(), "bad fixture {name} declares no //~ markers");
    assert_eq!(want, got, "fixture {name}: findings != markers");
}

fn assert_clean(name: &str) {
    let (want, got) = run(name);
    assert!(want.is_empty(), "good fixture {name} must not declare //~ markers");
    assert!(got.is_empty(), "fixture {name}: unexpected findings {got:?}");
}

#[test]
fn a1_bad_flags_every_import_evasion() {
    assert_exact("a1_bad.rs");
}

#[test]
fn a1_good_passes() {
    assert_clean("a1_good.rs");
}

#[test]
fn a2_bad_flags_hot_path_panics_including_after_test_mod() {
    assert_exact("a2_bad.rs");
}

#[test]
fn a2_good_passes() {
    assert_clean("a2_good.rs");
}

#[test]
fn a3_bad_flags_unannotated_and_unresolvable_waits() {
    assert_exact("a3_bad.rs");
}

#[test]
fn a3_good_passes() {
    assert_clean("a3_good.rs");
}

#[test]
fn a4_bad_flags_guards_across_blocking() {
    assert_exact("a4_bad.rs");
}

#[test]
fn a4_good_passes() {
    assert_clean("a4_good.rs");
}

#[test]
fn a5_bad_flags_custody_wildcards() {
    assert_exact("a5_bad.rs");
}

#[test]
fn a5_good_passes() {
    assert_clean("a5_good.rs");
}

#[test]
fn a5_epoch_bad_flags_epoch_outcome_wildcards() {
    assert_exact("a5_epoch_bad.rs");
}

#[test]
fn a5_epoch_good_passes() {
    assert_clean("a5_epoch_good.rs");
}

#[test]
fn real_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
    let findings = pallas_analyzer::analyze_tree(&root).expect("scan rust/src");
    let rendered: Vec<String> = findings.iter().map(|f| f.render()).collect();
    assert!(rendered.is_empty(), "tree findings:\n{}", rendered.join("\n"));
}
