//! pallas-analyzer — semantic lint gate for the Antler serving crate.
//!
//! Supersedes the grep/awk rules in `tools/lint.sh` (which remains the
//! documented no-toolchain fallback) with five rules that need real
//! structure: use-tree expansion, item-level test-cfg spans,
//! statement-attached annotations, guard liveness, and match-arm
//! shape. See `rules.rs` for the rule catalogue and CONCURRENCY.md
//! §Static gates for the table.
//!
//! ## Why a hand-rolled lexer instead of `syn`
//!
//! This repo's tooling must build offline with whatever the container
//! ships — the same constraint that made `loom` a target-gated dep in
//! the main crate. Pulling `syn` in would make the *gate itself*
//! unbuildable exactly where it is needed most (CI boxes without a
//! crates.io mirror), so the analyzer is dependency-free: a small
//! Rust lexer (comments, raw/byte strings, char-vs-lifetime) plus a
//! structural layer (test regions, statement attachment) that is
//! sufficient for the five rules without being a full parser. The
//! trade-off is explicit: we parse token shape, not types — e.g. A4
//! recognises guards by their binding expression (`lock_unpoisoned` /
//! `.lock(`), not by their type. The fixture battery in
//! `tests/fixtures.rs` pins the behaviour of every rule on known-bad
//! and known-good inputs, and `ci.sh` seeds violations into a scratch
//! tree to prove the gate has teeth end-to-end.

pub mod config;
pub mod lexer;
pub mod model;
pub mod rules;

use std::path::Path;

use config::Config;
use model::FileModel;
use rules::{Ctx, Finding};

/// Analyze a set of (relative path, source) pairs under one config.
/// This is the core entry point; both the CLI tree walk and the
/// fixture tests go through it.
pub fn analyze_sources(sources: &[(String, String)], cfg: &Config) -> Vec<Finding> {
    let models: Vec<FileModel> = sources
        .iter()
        .map(|(rel, src)| FileModel::build(rel, src))
        .collect();
    let ctx = Ctx::scan(&models);
    let mut out = Vec::new();
    for m in &models {
        out.extend(rules::analyze_file(m, cfg, &ctx));
    }
    out.sort_by(|a, b| (a.file.clone(), a.line, a.rule).cmp(&(b.file.clone(), b.line, b.rule)));
    out
}

/// Walk `<root>/rust/src` and analyze every `.rs` file with the tree
/// config. Returns findings with paths rendered `rust/src/<rel>`.
pub fn analyze_tree(root: &Path) -> std::io::Result<Vec<Finding>> {
    let src_root = root.join("rust").join("src");
    let mut files = Vec::new();
    collect_rs(&src_root, &src_root, &mut files)?;
    files.sort();
    let mut sources = Vec::new();
    for rel in &files {
        let text = std::fs::read_to_string(src_root.join(rel))?;
        sources.push((rel.clone(), text));
    }
    let cfg = Config::tree();
    let mut findings = analyze_sources(&sources, &cfg);
    for f in &mut findings {
        f.file = format!("rust/src/{}", f.file);
    }
    Ok(findings)
}

fn collect_rs(base: &Path, dir: &Path, out: &mut Vec<String>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(base, &path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            let rel = path
                .strip_prefix(base)
                .expect("walk stays under base")
                .to_string_lossy()
                .replace('\\', "/");
            out.push(rel);
        }
    }
    Ok(())
}
