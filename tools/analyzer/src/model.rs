//! Structural layer over the token stream: per-line code/comment maps,
//! *item-level* test regions (the semantic upgrade over lint.sh's
//! "stop at the first test-cfg marker" — an item appended after a test
//! module is still production code here), and annotation attachment
//! (a `lint:allow(...)` / `loom-verified:` comment counts only when it
//! is attached to the statement containing the finding, not merely
//! within an 8-line window).

use crate::lexer::{lex, Kind, Tok};

pub struct FileModel {
    /// Path relative to the scanned source root, e.g.
    /// `coordinator/shard.rs`.
    pub rel: String,
    /// Full token stream, comments included.
    pub toks: Vec<Tok>,
    /// Indices into `toks` of the non-comment tokens.
    pub code: Vec<usize>,
    /// 1-based: line carries at least one code token.
    pub line_is_code: Vec<bool>,
    /// 1-based: line carries at least one comment token.
    pub line_has_comment: Vec<bool>,
    /// 1-based: concatenated comment text, attributed to the comment's
    /// first line.
    pub line_comment: Vec<String>,
    /// 1-based: line lies inside a `#[cfg(...test...)]` / `#[test]`
    /// item span or a `mod tests` / `mod loom_tests` body.
    pub test_line: Vec<bool>,
}

impl FileModel {
    pub fn build(rel: &str, src: &str) -> FileModel {
        let toks = lex(src);
        let nlines = src.lines().count() + 2;
        let mut line_is_code = vec![false; nlines + 1];
        let mut line_has_comment = vec![false; nlines + 1];
        let mut line_comment = vec![String::new(); nlines + 1];
        let code: Vec<usize> = toks
            .iter()
            .enumerate()
            .filter(|(_, t)| t.kind != Kind::Comment)
            .map(|(i, _)| i)
            .collect();
        for t in &toks {
            for l in t.line..=t.end_line.min(nlines) {
                if t.kind == Kind::Comment {
                    line_has_comment[l] = true;
                } else {
                    line_is_code[l] = true;
                }
            }
            if t.kind == Kind::Comment {
                line_comment[t.line].push_str(&t.text);
                line_comment[t.line].push(' ');
            }
        }
        let mut m = FileModel {
            rel: rel.to_string(),
            toks,
            code,
            line_is_code,
            line_has_comment,
            line_comment,
            test_line: vec![false; nlines + 1],
        };
        m.mark_test_regions();
        m
    }

    pub fn tok(&self, code_idx: usize) -> &Tok {
        &self.toks[self.code[code_idx]]
    }

    pub fn ncode(&self) -> usize {
        self.code.len()
    }

    /// Two puncts forming a glued pair (`::`, `=>`) — consecutive char
    /// offsets.
    fn glued(&self, a: usize, b: usize) -> bool {
        self.tok(b).pos == self.tok(a).pos + 1
    }

    /// `code[i], code[i+1]` spell `::`.
    pub fn is_path_sep(&self, i: usize) -> bool {
        i + 1 < self.ncode()
            && self.tok(i).is_punct(':')
            && self.tok(i + 1).is_punct(':')
            && self.glued(i, i + 1)
    }

    // ----------------------------------------------------- test regions

    /// Attribute starting at code index `i` (`#` `[`): return
    /// (index one past the closing `]`, attribute is test-gating).
    fn parse_attr(&self, i: usize) -> (usize, bool) {
        let mut j = i + 2; // past `#` `[`
        let mut depth = 1i32; // bracket depth of the attr itself
        let mut paren_stack: Vec<String> = Vec::new();
        let mut pending: Option<String> = None;
        let mut is_test = false;
        while j < self.ncode() && depth > 0 {
            let t = self.tok(j);
            if t.is_punct('[') {
                depth += 1;
            } else if t.is_punct(']') {
                depth -= 1;
            } else if t.is_punct('(') {
                paren_stack.push(pending.take().unwrap_or_default());
            } else if t.is_punct(')') {
                paren_stack.pop();
            } else if t.kind == Kind::Ident {
                if t.text == "test" && !paren_stack.iter().any(|p| p == "not") {
                    is_test = true;
                }
                pending = Some(t.text.clone());
            }
            j += 1;
        }
        (j, is_test)
    }

    /// From code index `i` (first token of an item after its
    /// attributes), return the code index of the item's last token:
    /// either a `;` at depth 0 or the `}` matching its first body `{`.
    fn item_end(&self, i: usize) -> usize {
        let mut j = i;
        let mut depth = 0i32;
        while j < self.ncode() {
            let t = self.tok(j);
            if t.is_punct('(') || t.is_punct('[') {
                depth += 1;
            } else if t.is_punct(')') || t.is_punct(']') {
                depth -= 1;
            } else if t.is_punct('{') {
                if depth == 0 {
                    // match to the closing brace
                    let mut b = 1i32;
                    let mut k = j + 1;
                    while k < self.ncode() && b > 0 {
                        if self.tok(k).is_punct('{') {
                            b += 1;
                        } else if self.tok(k).is_punct('}') {
                            b -= 1;
                        }
                        k += 1;
                    }
                    return k.saturating_sub(1);
                }
                depth += 1;
            } else if t.is_punct('}') {
                depth -= 1;
            } else if t.is_punct(';') && depth == 0 {
                return j;
            }
            j += 1;
        }
        self.ncode().saturating_sub(1)
    }

    fn mark_span_test(&mut self, from_line: usize, to_line: usize) {
        for l in from_line..=to_line.min(self.test_line.len() - 1) {
            self.test_line[l] = true;
        }
    }

    fn mark_test_regions(&mut self) {
        let mut k = 0usize;
        let mut pending_test = false;
        let mut pending_line = 0usize;
        while k < self.ncode() {
            let t = self.tok(k);
            if t.is_punct('#') && k + 1 < self.ncode() && self.tok(k + 1).is_punct('[') {
                let (after, is_test) = self.parse_attr(k);
                if is_test && !pending_test {
                    pending_test = true;
                    pending_line = t.line;
                }
                k = after;
                continue;
            }
            if pending_test {
                let end = self.item_end(k);
                let (a, b) = (pending_line, self.tok(end).end_line);
                self.mark_span_test(a, b);
                pending_test = false;
                k = end + 1;
                continue;
            }
            // an un-cfg'd `mod tests` / `mod loom_tests` body is a test
            // region too (matches the grep fallback's convention)
            if t.is_ident("mod")
                && k + 1 < self.ncode()
                && matches!(self.tok(k + 1).text.as_str(), "tests" | "loom_tests")
                && self.tok(k + 1).kind == Kind::Ident
            {
                let end = self.item_end(k);
                let (a, b) = (t.line, self.tok(end).end_line);
                self.mark_span_test(a, b);
                k = end + 1;
                continue;
            }
            k += 1;
        }
    }

    // ------------------------------------------------------- attachment

    /// Code index of the first token of the statement containing
    /// `code_idx`. Walks backward to the nearest `;`, `=>`, or
    /// unmatched opening bracket at depth 0. Lenient by construction:
    /// chained calls, multi-line builders and `match` scrutinees stay
    /// inside one span.
    pub fn stmt_first(&self, code_idx: usize) -> usize {
        let mut depth = 0i32;
        let mut j = code_idx;
        while j > 0 {
            let t = self.tok(j - 1);
            if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') {
                depth += 1;
            } else if t.is_punct('(') || t.is_punct('[') || t.is_punct('{') {
                if depth == 0 {
                    return j;
                }
                depth -= 1;
            } else if t.is_punct(';') && depth == 0 {
                return j;
            } else if t.is_punct('>')
                && depth == 0
                && j >= 2
                && self.tok(j - 2).is_punct('=')
                && self.glued(j - 2, j - 1)
            {
                // a match arm's `=>` bounds the arm body
                return j;
            }
            j -= 1;
        }
        0
    }

    /// All comment text attached to the statement containing
    /// `code_idx`: the contiguous comment-only run immediately above
    /// the statement's first line, plus every comment between the
    /// statement's first line and the finding's line (inclusive — a
    /// trailing same-line comment counts).
    pub fn attached_comments(&self, code_idx: usize) -> String {
        let first = self.stmt_first(code_idx);
        let start_line = self.tok(first).line;
        let end_line = self.tok(code_idx).line;
        let mut text = String::new();
        let mut l = start_line.saturating_sub(1);
        while l >= 1 && !self.line_is_code[l] && self.line_has_comment[l] {
            text.push_str(&self.line_comment[l]);
            if l == 1 {
                break;
            }
            l -= 1;
        }
        for l in start_line..=end_line.min(self.line_comment.len() - 1) {
            text.push_str(&self.line_comment[l]);
        }
        text
    }

    /// Does the statement containing `code_idx` carry the given
    /// annotation?
    pub fn allowed(&self, code_idx: usize, annotation: &str) -> bool {
        self.attached_comments(code_idx).contains(annotation)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cfg_test_item_spans_are_test_regions() {
        let src = "\
fn prod() { x.unwrap(); }
#[cfg(all(test, not(loom)))]
mod tests {
    fn t() { y.unwrap(); }
}
fn appended_after_tests() { z.unwrap(); }
";
        let m = FileModel::build("f.rs", src);
        assert!(!m.test_line[1]);
        assert!(m.test_line[2] && m.test_line[3] && m.test_line[4] && m.test_line[5]);
        // the item AFTER the test module is production code — the case
        // the awk window gets wrong
        assert!(!m.test_line[6]);
    }

    #[test]
    fn cfg_not_test_is_not_a_test_region() {
        let src = "#[cfg(not(test))]\nfn prod() { x.unwrap(); }\n";
        let m = FileModel::build("f.rs", src);
        assert!(!m.test_line[2]);
    }

    #[test]
    fn test_attr_marks_single_fn() {
        let src = "#[test]\nfn t() { x.unwrap(); }\nfn prod() {}\n";
        let m = FileModel::build("f.rs", src);
        assert!(m.test_line[1] && m.test_line[2]);
        assert!(!m.test_line[3]);
    }

    #[test]
    fn attachment_covers_statement_not_window() {
        let src = "\
// lint:allow(panic) — reason
let row = ids
    .iter()
    .position(|id| id == w)
    .expect(\"present\");
let other = q.unwrap();
";
        let m = FileModel::build("f.rs", src);
        // find the expect token
        let expect_idx =
            (0..m.ncode()).find(|&i| m.tok(i).is_ident("expect")).unwrap();
        assert!(m.allowed(expect_idx, "lint:allow(panic)"));
        let unwrap_idx =
            (0..m.ncode()).find(|&i| m.tok(i).is_ident("unwrap")).unwrap();
        // the annotation above the FIRST statement is not attached to
        // the second one
        assert!(!m.allowed(unwrap_idx, "lint:allow(panic)"));
    }

    #[test]
    fn trailing_comment_attaches() {
        let src = "shape[0] = n; // lint:allow(panic) — rank >= 1\n";
        let m = FileModel::build("f.rs", src);
        let idx = (0..m.ncode()).find(|&i| m.tok(i).is_punct('[')).unwrap();
        assert!(m.allowed(idx, "lint:allow(panic)"));
    }
}
