//! What the rules scan. Paths are relative to the scanned source root
//! (`rust/src`). Kept in one place so the analyzer and the grep
//! fallback (`tools/lint.sh`) can be diffed against each other — the
//! rule table in CONCURRENCY.md §Static gates mirrors this file.

pub struct Config {
    /// Files under this prefix are the concurrency facade: the one
    /// sanctioned home for raw `std::sync` / `std::thread` (A1) and
    /// for the primitive wait the facade itself wraps (A3, A4).
    pub facade_prefix: String,
    /// The per-frame serving files: A2's hot-path panic ban applies
    /// here. Mirrors `hot_files` in tools/lint.sh R2 (plus the two
    /// debug-per-frame files lint.sh historically skipped:
    /// coordinator/executor.rs and coordinator/audit.rs).
    pub hot_files: Vec<String>,
    /// Enums whose `match` sites carry conservation accounting: a
    /// wildcard arm over these silently swallows a future variant and
    /// breaks `delivered + stale + backpressure + truncated == offered`
    /// (A5). Extend this list when a ledger transition enum lands.
    pub custody_enums: Vec<String>,
}

impl Config {
    /// The real tree's configuration.
    pub fn tree() -> Config {
        Config {
            facade_prefix: "sync/".into(),
            hot_files: vec![
                "coordinator/shard.rs".into(),
                "coordinator/ingest.rs".into(),
                "coordinator/server.rs".into(),
                "coordinator/net.rs".into(),
                "coordinator/wire.rs".into(),
                "coordinator/executor.rs".into(),
                "coordinator/audit.rs".into(),
                "coordinator/registry.rs".into(),
                "coordinator/replan.rs".into(),
                "exec/pool.rs".into(),
                "memory/tier.rs".into(),
            ],
            custody_enums: vec![
                "Admission".into(),
                "QosClass".into(),
                "EvictPolicy".into(),
                "SegmentAction".into(),
                "EpochOutcome".into(),
            ],
        }
    }

    /// Fixture configuration: every fixture file is treated as hot so
    /// A2 applies, with the same custody enums.
    pub fn fixtures(rel: &str) -> Config {
        let mut c = Config::tree();
        c.hot_files = vec![rel.to_string()];
        c
    }

    pub fn is_facade(&self, rel: &str) -> bool {
        rel.starts_with(&self.facade_prefix)
    }

    pub fn is_hot(&self, rel: &str) -> bool {
        self.hot_files.iter().any(|h| h == rel)
    }
}
