use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") {
        eprintln!(
            "pallas-analyzer — semantic lint gate (rules A1–A5) for rust/src\n\
             \n\
             usage: pallas-analyzer [REPO_ROOT]\n\
             \n\
             REPO_ROOT defaults to the repository containing this tool.\n\
             Scans REPO_ROOT/rust/src, prints `file:line: rule: message`\n\
             per finding, exits 1 if there are any. Rule table:\n\
             CONCURRENCY.md §Static gates; fallback: tools/lint.sh."
        );
        return ExitCode::SUCCESS;
    }
    let root: PathBuf = match args.first() {
        Some(p) => PathBuf::from(p),
        // tools/analyzer/../.. == repo root
        None => PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join(".."),
    };
    let findings = match pallas_analyzer::analyze_tree(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("pallas-analyzer: cannot scan {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    for f in &findings {
        println!("{}", f.render());
    }
    if findings.is_empty() {
        eprintln!("pallas-analyzer: clean (rules A1-A5, {})", root.join("rust/src").display());
        ExitCode::SUCCESS
    } else {
        eprintln!("pallas-analyzer: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}
